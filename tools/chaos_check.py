"""CI chaos gate: the scan path under deterministic fault injection.

Runs the Q6/Q12 file scans and the dataset smoke shape twice — once
clean, once under a fixed transient-only ``FaultPlan`` — and repeats
the sweep over the fused late-materialization path (DESIGN.md §7),
where checksums must trip *before* corrupt bytes can reach a fused
kernel.  Fails unless:

  * every faulted run's result is **bit-identical** to its clean run
    (transient faults must heal invisibly),
  * the faulted runs actually recovered work (``retries > 0`` — a chaos
    run that injected nothing gates nothing),
  * no fragment was quarantined (transient faults never quarantine),
  * checksum verification costs <= ``CHAOS_CRC_THRESHOLD`` (default 5%)
    wall on the same scan measured min-of-rounds with verification
    toggled off, plus a small absolute slack for tiny-SF scheduler noise.

Everything is seeded: a failure here replays exactly with
``FaultPlan(seed=CHAOS_SEED, ...)`` (tools/chaos_check.py --help).

Usage:
    PYTHONPATH=src JAX_PLATFORMS=cpu python tools/chaos_check.py
"""

from __future__ import annotations

import argparse
import os
import struct
import sys
import tempfile
import time


def _clear_decoded_caches():
    from repro.core.compression import chunk_decompress_memo
    from repro.core.scheduler import clear_delivered_windows
    from repro.dataset.result_cache import clear_all_result_caches
    from repro.kernels.dict_decode import dict_cache_clear
    chunk_decompress_memo().clear()
    dict_cache_clear()
    clear_delivered_windows()
    clear_all_result_caches()


def _fault_plan(seed: int):
    from repro.core.faults import FaultPlan
    # transient-only: every fault heals on retry by construction
    return FaultPlan(seed=seed, io_error=0.30, short_read=0.15,
                     bit_flip=0.15, latency=0.05, decode_error=0.15,
                     latency_seconds=0.001, transient=True)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sf", type=float,
                    default=float(os.environ.get("CHAOS_SF", "0.005")))
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("CHAOS_SEED", "20260808")))
    ap.add_argument("--rounds", type=int,
                    default=int(os.environ.get("CHAOS_ROUNDS", "3")))
    ap.add_argument("--crc-threshold", type=float,
                    default=float(os.environ.get("CHAOS_CRC_THRESHOLD",
                                                 "0.05")))
    ap.add_argument("--crc-slack-us", type=float, default=5_000.0,
                    help="absolute wall slack for the CRC gate (tiny-SF "
                         "scheduler noise floor)")
    args = ap.parse_args()

    from repro.core.config import ACCELERATOR_OPTIMIZED
    from repro.core.compression import set_verify_checksums
    from repro.core.query import Q12_ORDERS_COLUMNS, q6, q12
    from repro.core.scan import open_scanner
    from repro.data import tpch
    from repro.dataset import write_dataset

    failures: list[str] = []
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=3_000,
                                        target_pages_per_chunk=2)

    with tempfile.TemporaryDirectory(prefix="chaos_") as root:
        tpch.write_tpch(root, sf=args.sf, config=cfg, seed=1, threads=2)
        lpath = os.path.join(root, "lineitem.tab")
        opath = os.path.join(root, "orders.tab")
        line, _ = tpch.generate_tables(sf=args.sf, seed=1,
                                       include_strings=False)
        ds = write_dataset(line, os.path.join(root, "ds"), cfg,
                           partition_by="l_shipdate", how="range",
                           fragments=4)

        def open_l(plan=None):
            return open_scanner(lpath, decode_backend="host",
                                fault_plan=plan)

        def open_o(plan=None):
            return open_scanner(opath, columns=Q12_ORDERS_COLUMNS,
                                decode_backend="host", fault_plan=plan)

        # -- clean reference runs --------------------------------------
        q6_clean, _ = q6(open_l(), overlapped=True, decode_workers=2)
        q12_clean, _, _ = q12(open_l(), open_o(), decode_workers=2)
        ds_clean, _ = q6(ds, prune=True, window=4,
                         open_opts={"decode_backend": "host"})

        # -- seeded chaos runs (transient-only) ------------------------
        total_retries = 0
        _clear_decoded_caches()
        q6_chaos, rep6 = q6(open_l(_fault_plan(args.seed)),
                            overlapped=True, decode_workers=2)
        total_retries += rep6.metrics.retries
        _clear_decoded_caches()
        q12_chaos, repb, repp = q12(open_l(_fault_plan(args.seed + 1)),
                                    open_o(_fault_plan(args.seed + 2)),
                                    decode_workers=2)
        total_retries += repb.metrics.retries + repp.metrics.retries
        _clear_decoded_caches()
        ds_chaos, repd = q6(
            ds, prune=True, window=4,
            open_opts={"decode_backend": "host",
                       "fault_plan": _fault_plan(args.seed + 3)})
        total_retries += repd.retries

        if q6_chaos != q6_clean:
            failures.append(f"q6 under chaos diverged: "
                            f"{q6_chaos!r} != {q6_clean!r}")
        if q12_chaos != q12_clean:
            failures.append(f"q12 under chaos diverged: "
                            f"{q12_chaos!r} != {q12_clean!r}")
        if ds_chaos != ds_clean:
            failures.append(f"dataset q6 under chaos diverged: "
                            f"{ds_chaos!r} != {ds_clean!r}")
        if total_retries <= 0:
            failures.append("chaos run recovered nothing (retries == 0): "
                            "the fault plan injected no observable work")
        if repd.fragments_quarantined:
            failures.append(f"transient faults quarantined "
                            f"{repd.fragments_quarantined} fragment(s): "
                            f"{repd.quarantined}")
        print(f"[chaos] q6/q12/dataset bit-identical under seeded faults "
              f"(retries={total_retries}, "
              f"quarantined={repd.fragments_quarantined})")

        # -- fused path under the same seeded fault sweep (§7) ---------
        # Checksums are verified *before* any payload feeds a fused
        # kernel (_fused_payload_task), so an injected bit flip raises
        # ChecksumError, heals under retry, and the fused result stays
        # bit-identical to the clean fused run.
        q6f_clean, _ = q6(open_l(), overlapped=True, decode_workers=2,
                          fused=True)
        q12f_clean, _, _ = q12(open_l(), open_o(), decode_workers=2,
                               fused=True)
        dsf_clean, _ = q6(ds, prune=True, window=4, fused=True,
                          open_opts={"decode_backend": "host"})

        fused_retries = 0
        _clear_decoded_caches()
        q6f_chaos, rep6f = q6(open_l(_fault_plan(args.seed + 4)),
                              overlapped=True, decode_workers=2,
                              fused=True)
        fused_retries += rep6f.metrics.retries
        crc_hits = rep6f.metrics.checksum_failures
        _clear_decoded_caches()
        q12f_chaos, repbf, reppf = q12(open_l(_fault_plan(args.seed + 5)),
                                       open_o(_fault_plan(args.seed + 6)),
                                       decode_workers=2, fused=True)
        fused_retries += repbf.metrics.retries + reppf.metrics.retries
        _clear_decoded_caches()
        dsf_chaos, repdf = q6(
            ds, prune=True, window=4, fused=True,
            open_opts={"decode_backend": "host",
                       "fault_plan": _fault_plan(args.seed + 7)})
        fused_retries += repdf.retries

        if struct.pack("<d", q6f_chaos) != struct.pack("<d", q6f_clean):
            failures.append(f"fused q6 under chaos diverged: "
                            f"{q6f_chaos!r} != {q6f_clean!r}")
        if q12f_chaos != q12f_clean:
            failures.append(f"fused q12 under chaos diverged: "
                            f"{q12f_chaos!r} != {q12f_clean!r}")
        if dsf_chaos != dsf_clean:
            failures.append(f"fused dataset q6 under chaos diverged: "
                            f"{dsf_chaos!r} != {dsf_clean!r}")
        if fused_retries <= 0:
            failures.append("fused chaos legs recovered nothing "
                            "(retries == 0)")
        if repdf.fragments_quarantined:
            failures.append(f"fused transient faults quarantined "
                            f"{repdf.fragments_quarantined} fragment(s)")
        print(f"[chaos] fused q6/q12/dataset bit-identical under seeded "
              f"faults (retries={fused_retries}, crc_failures={crc_hits}, "
              f"quarantined={repdf.fragments_quarantined})")

        # -- multi-tenant leg (§11): faults neither starve nor poison --
        # A bronze tenant's transiently faulted scan shares a windowed
        # ScanService with a gold tenant's repeats.  Gold's repeat must
        # be served bit-identically (the delivered-result window keeps
        # working — no starvation by the faulted sibling), bronze must
        # heal bit-identically, and the faulted scan must never publish
        # into the window (fault-injection scans are excluded from the
        # share identity; retried row groups never re-register either).
        import threading as _threading

        from repro.core.scheduler import ScanService

        _clear_decoded_caches()
        tsvc = ScanService(workers=2, window_bytes=64 << 20)
        try:
            tsvc.register_tenant("gold", weight=4)
            tsvc.register_tenant("bronze", weight=1)
            g1, _ = q6(open_l(), overlapped=True, decode_workers=2,
                       service=tsvc, tenant="gold")
            entries_before = tsvc.window_entries
            tenant_out: dict[str, tuple] = {}

            def _bronze_leg():
                tenant_out["bronze"] = q6(
                    open_l(_fault_plan(args.seed + 9)), overlapped=True,
                    decode_workers=2, service=tsvc, tenant="bronze")

            bt = _threading.Thread(target=_bronze_leg, daemon=True)
            bt.start()
            g2, grep2 = q6(open_l(), overlapped=True, decode_workers=2,
                           service=tsvc, tenant="gold")
            bt.join(timeout=120)
            if "bronze" not in tenant_out:
                failures.append("tenant leg: bronze's faulted scan never "
                                "finished (starved or wedged)")
                b_acc, b_rep = None, None
            else:
                b_acc, b_rep = tenant_out["bronze"]
            if g2 != g1:
                failures.append(f"tenant leg: gold repeat diverged beside "
                                f"a faulted sibling: {g2!r} != {g1!r}")
            if g1 != q6_clean:
                failures.append(f"tenant leg: gold diverged from clean: "
                                f"{g1!r} != {q6_clean!r}")
            if b_acc is not None and b_acc != q6_clean:
                failures.append(f"tenant leg: bronze under chaos "
                                f"diverged: {b_acc!r} != {q6_clean!r}")
            if b_rep is not None and b_rep.metrics.retries <= 0:
                failures.append("tenant leg: bronze recovered nothing "
                                "(retries == 0)")
            if tsvc.window_hits <= 0:
                failures.append("tenant leg: gold repeat never hit the "
                                "delivered-result window")
            if tsvc.window_entries > entries_before:
                failures.append(f"tenant leg: the faulted scan grew the "
                                f"window ({entries_before} -> "
                                f"{tsvc.window_entries} entries) — "
                                f"poisoning channel open")
            print(f"[chaos] tenant leg: gold window-served "
                  f"(hits={tsvc.window_hits}) beside bronze chaos "
                  f"(retries="
                  f"{b_rep.metrics.retries if b_rep else 'n/a'}), "
                  f"no window poisoning")
        finally:
            tsvc.shutdown()

        # -- distributed leg (§8): one device's shard faults, heals ----
        # Shard 0's fragments (the same fragments whatever the device
        # count) get the transient plan via the per-fragment open_opts
        # hook; the 2-device run must stay bit-identical to the clean
        # 2-device run with zero quarantined fragments.
        import numpy as np

        from repro.dataset import plan_dataset_scan, run_distributed_scan
        from repro.parallel.sharding import contiguous_shards

        dplan = plan_dataset_scan(ds)
        lo, hi = contiguous_shards(
            [max(1, f.stored_bytes) for f in dplan.fragments], 2)[0]

        def _dist(open_opts_for=None):
            _clear_decoded_caches()
            return run_distributed_scan(
                dplan,
                lambda acc, i, cols: (acc or 0.0) + float(
                    np.asarray(cols["l_extendedprice"].array,
                               dtype=np.float64).sum()),
                lambda a, b: a + b, devices=2,
                open_opts={"decode_backend": "host"},
                open_opts_for=open_opts_for)

        dist_clean, _ = _dist()
        dist_chaos, repx = _dist(
            lambda pos, frag: {"fault_plan": _fault_plan(args.seed + 8)}
            if lo <= pos < hi else None)
        if struct.pack("<d", dist_chaos) != struct.pack("<d", dist_clean):
            failures.append(f"distributed q6 under shard-0 chaos "
                            f"diverged: {dist_chaos!r} != {dist_clean!r}")
        if repx.retries <= 0:
            failures.append("distributed chaos leg recovered nothing "
                            "(retries == 0)")
        if repx.fragments_quarantined:
            failures.append(f"distributed transient faults quarantined "
                            f"{repx.fragments_quarantined} fragment(s)")
        print(f"[chaos] distributed d2 bit-identical with shard-0 faults "
              f"(retries={repx.retries}, "
              f"quarantined={repx.fragments_quarantined})")

        # -- trace leg: injected faults must be visible as spans -------
        # Re-run the faulted Q6 with the flight recorder on (DESIGN.md
        # §10): the seeded transient faults must surface as
        # fault_injected instants and recovery must surface as
        # requeue / retry_attempt events — a chaos run whose trace shows
        # no fault activity means the recorder lost the failure story.
        from repro.core import trace as trace_mod

        _clear_decoded_caches()
        tr = trace_mod.enable()
        tr.clear()
        q6_traced, rept = q6(open_l(_fault_plan(args.seed)),
                             overlapped=True, decode_workers=2)
        names = {e.name for e in tr.events()}
        trace_mod.disable()
        trace_mod.reset()
        if q6_traced != q6_clean:
            failures.append(f"traced chaos q6 diverged: {q6_traced!r} "
                            f"!= {q6_clean!r}")
        if "fault_injected" not in names:
            failures.append(f"traced chaos run shows no fault_injected "
                            f"events (saw {sorted(names)})")
        if not names & {"requeue", "retry_attempt"}:
            failures.append(f"traced chaos run shows no recovery spans "
                            f"(requeue/retry_attempt; saw "
                            f"{sorted(names)})")
        print(f"[chaos] trace leg: faults visible as spans "
              f"(retries={rept.metrics.retries}, "
              f"events={rept.metrics.trace_events})")

        # -- CRC verification overhead gate ----------------------------
        def best_wall() -> float:
            best = float("inf")
            for _ in range(max(1, args.rounds)):
                _clear_decoded_caches()
                sc = open_l()
                t0 = time.perf_counter()
                q6(sc, overlapped=True, decode_workers=2)
                best = min(best, time.perf_counter() - t0)
            return best

        on_wall = best_wall()
        prev = set_verify_checksums(False)
        try:
            off_wall = best_wall()
        finally:
            set_verify_checksums(prev)
        budget = off_wall * (1.0 + args.crc_threshold) \
            + args.crc_slack_us * 1e-6
        print(f"[chaos] crc overhead: verify-on {on_wall * 1e6:.0f}us vs "
              f"verify-off {off_wall * 1e6:.0f}us "
              f"(budget {budget * 1e6:.0f}us, min of {args.rounds} rounds)")
        if on_wall > budget:
            failures.append(
                f"checksum verification exceeds its budget: "
                f"{on_wall * 1e6:.0f}us > {budget * 1e6:.0f}us "
                f"(verify-off {off_wall * 1e6:.0f}us "
                f"+{args.crc_threshold * 100:.0f}% "
                f"+{args.crc_slack_us:.0f}us slack)")

    if failures:
        print("[chaos] FAIL")
        for f in failures:
            print(" ", f)
        return 1
    print("[chaos] ok — transient faults heal bit-identically and "
          "verification stays within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
