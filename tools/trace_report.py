"""Post-mortem analysis of one flight-recorder export (DESIGN.md §10).

Consumes the Chrome/Perfetto trace-event JSON written by
``core/trace.py`` (``Tracer.export``) and answers the question the raw
timeline can't: *which stage bounds this scan?*  Three views:

  validate   schema check — required keys, known phase types, no
             negative timestamps/durations, balanced begin/end pairs.
  buckets    every instrumented span is attributed to exactly one of
             ``fetch`` / ``decompress`` / ``decode`` / ``consume`` by a
             fixed priority (consume > decode > decompress > fetch —
             overlapped work counts toward the *latest* pipeline stage,
             which is the one that would have to shrink for wall time
             to improve); uncovered run time is ``stall``.  The five
             buckets partition the run wall exactly.
  report     run wall (from the outermost scan span), the bucket
             breakdown, per-row-group critical-path chains
             (fetch → decode items → consume), an effective-bandwidth
             breakdown (stored bytes fetched, logical bytes consumed),
             a per-tenant wall attribution (DESIGN.md §11 — spans the
             scheduler tagged with ``args.tenant``; untagged work is
             charged to the shared ``-`` tenant), and the named
             bottleneck stage — the largest bucket.

Usage:
    python tools/trace_report.py TRACE.json [--json]

``--json`` prints the machine-readable report (tools/trace_check.py
consumes it); the default is a human summary.  Exit code is non-zero
when the trace fails validation.
"""

from __future__ import annotations

import argparse
import json
import sys

VALID_PH = ("X", "i", "M", "B", "E")

#: span name → attribution bucket; structural spans (scan / fragment /
#: dataset_scan / …) frame the timeline and are deliberately unmapped
BUCKET_OF = {
    "fetch": "fetch", "storage_read": "fetch",
    "decompress": "decompress",
    "open": "decode", "transition": "decode", "decode": "decode",
    "fused": "decode", "finalize": "decode", "decode_rg": "decode",
    "consume": "consume",
}

#: attribution priority, latest pipeline stage first (module docstring)
PRIORITY = ("consume", "decode", "decompress", "fetch")

#: outermost structural spans, in precedence order — the run wall comes
#: from the widest one present
RUN_SPANS = ("distributed_scan", "dataset_scan", "scan")


def load_trace(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def validate_trace(doc: dict) -> list[str]:
    """Schema errors for one exported trace document (empty == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["trace document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    if "displayTimeUnit" not in doc:
        errors.append("missing 'displayTimeUnit'")
    open_spans: dict[tuple, int] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errors.append(f"event {i}: not an object")
            continue
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"event {i}: missing name")
            name = "?"
        ph = e.get("ph")
        if ph not in VALID_PH:
            errors.append(f"event {i} ({name}): bad ph {ph!r}")
            continue
        for key in ("pid", "tid"):
            if key not in e:
                errors.append(f"event {i} ({name}): missing {key}")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} ({name}): bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({name}): negative or missing "
                              f"dur {dur!r}")
        elif ph == "B":
            open_spans[(e.get("tid"), name)] = \
                open_spans.get((e.get("tid"), name), 0) + 1
        elif ph == "E":
            key = (e.get("tid"), name)
            if open_spans.get(key, 0) <= 0:
                errors.append(f"event {i} ({name}): E without B")
            else:
                open_spans[key] -= 1
    for (tid, name), n in open_spans.items():
        if n:
            errors.append(f"span {name} (tid {tid}): {n} unclosed B")
    return errors


def _x_events(doc: dict) -> list[dict]:
    return [e for e in doc.get("traceEvents", [])
            if e.get("ph") == "X" and isinstance(e.get("ts"), (int, float))
            and isinstance(e.get("dur"), (int, float))]


def _extent(doc: dict) -> tuple[float, float]:
    """(lo, hi) µs: the outermost structural span when present, else the
    envelope of every complete event."""
    xs = _x_events(doc)
    if not xs:
        return 0.0, 0.0
    for name in RUN_SPANS:
        spans = [e for e in xs if e["name"] == name]
        if spans:
            top = max(spans, key=lambda e: e["dur"])
            return float(top["ts"]), float(top["ts"] + top["dur"])
    lo = min(e["ts"] for e in xs)
    hi = max(e["ts"] + e["dur"] for e in xs)
    return float(lo), float(hi)


def attribute_buckets(doc: dict) -> dict:
    """Partition the run extent into the five buckets (µs).

    A coordinate sweep over every bucketed span: each elementary
    interval is charged to the highest-priority bucket covering it, or
    ``stall`` when nothing does.  Sums are exact — the values add up to
    ``wall_us`` to float precision.
    """
    lo, hi = _extent(doc)
    out = {b: 0.0 for b in PRIORITY}
    out["stall"] = 0.0
    out["wall_us"] = hi - lo
    if hi <= lo:
        return out
    deltas: dict[float, dict[str, int]] = {}
    for e in _x_events(doc):
        b = BUCKET_OF.get(e["name"])
        if b is None:
            continue
        s = max(lo, float(e["ts"]))
        t = min(hi, float(e["ts"] + e["dur"]))
        if t <= s:
            continue
        deltas.setdefault(s, {}).setdefault(b, 0)
        deltas[s][b] += 1
        deltas.setdefault(t, {}).setdefault(b, 0)
        deltas[t][b] -= 1
    active = {b: 0 for b in PRIORITY}
    prev = lo
    for t in sorted(set(deltas) | {hi}):
        seg = min(t, hi) - prev
        if seg > 0:
            for b in PRIORITY:
                if active[b] > 0:
                    out[b] += seg
                    break
            else:
                out["stall"] += seg
        for b, d in deltas.get(t, {}).items():
            active[b] += d
        prev = min(t, hi)
    return out


def critical_path(doc: dict) -> dict:
    """Per-row-group serial chains (fetch → decode items → consume, µs)
    and the longest one — the chain a latency optimization must shorten
    first."""
    chains: dict[tuple, dict] = {}
    for e in _x_events(doc):
        args = e.get("args") or {}
        if "rg" not in args:
            continue
        b = BUCKET_OF.get(e["name"])
        if b is None:
            continue
        key = (args.get("scan", "?"), args["rg"])
        c = chains.setdefault(key, {"scan": key[0], "rg": key[1],
                                    "fetch": 0.0, "decompress": 0.0,
                                    "decode": 0.0, "consume": 0.0})
        c[b] += float(e["dur"])
    rgs = sorted(chains.values(),
                 key=lambda c: (c["scan"], c["rg"]))
    for c in rgs:
        c["total"] = c["fetch"] + c["decompress"] + c["decode"] \
            + c["consume"]
    longest = max(rgs, key=lambda c: c["total"], default=None)
    return {"chains": rgs, "longest": longest}


def bandwidth(doc: dict) -> dict:
    """Effective-bandwidth breakdown over the run extent: stored bytes
    moved by the storage layer vs logical bytes delivered to consume."""
    lo, hi = _extent(doc)
    wall_s = max(1e-12, (hi - lo) * 1e-6)
    stored = sum(int((e.get("args") or {}).get("bytes", 0))
                 for e in _x_events(doc)
                 if e["name"] == "storage_read")
    logical = sum(int((e.get("args") or {}).get("logical_bytes", 0))
                  for e in _x_events(doc)
                  if e["name"] == "consume")
    return {"stored_bytes": stored, "logical_bytes": logical,
            "stored_bw_mbps": stored / wall_s / 1e6,
            "effective_bw_mbps": logical / wall_s / 1e6}


def tenant_attribution(doc: dict) -> dict:
    """Per-tenant wall attribution (DESIGN.md §11).

    Every bucketed complete event is charged to the tenant named in its
    ``args`` — the scheduler tags fetch and decode-item spans with the
    owning tenant — and untagged work rides the shared ``-`` tenant,
    mirroring the weight-1 virtual tenant in the scheduler itself.
    Values are summed span-time µs, *not* exclusive wall: concurrent
    tenants overlap, so per-tenant ``busy_us`` can add up to more than
    the run wall.  ``window_hit`` instants are counted per tenant too —
    row groups a tenant received from the delivered-result window
    instead of fetching.
    """
    out: dict[str, dict] = {}

    def entry(ten: str) -> dict:
        t = out.get(ten)
        if t is None:
            t = {b: 0.0 for b in PRIORITY}
            t.update(busy_us=0.0, spans=0, window_hits=0)
            out[ten] = t
        return t

    for e in _x_events(doc):
        b = BUCKET_OF.get(e["name"])
        if b is None:
            continue
        t = entry(str((e.get("args") or {}).get("tenant", "-")))
        t[b] += float(e["dur"])
        t["busy_us"] += float(e["dur"])
        t["spans"] += 1
    for e in doc.get("traceEvents", []):
        if isinstance(e, dict) and e.get("ph") == "i" \
                and e.get("name") == "window_hit":
            entry(str((e.get("args") or {})
                      .get("tenant", "-")))["window_hits"] += 1
    return dict(sorted(out.items()))


def build_report(doc: dict) -> dict:
    """The full machine-readable report for one trace document."""
    buckets = attribute_buckets(doc)
    stage_buckets = {k: v for k, v in buckets.items() if k != "wall_us"}
    bottleneck = max(stage_buckets, key=stage_buckets.get) \
        if buckets["wall_us"] > 0 else "empty"
    events = doc.get("traceEvents", [])
    other = doc.get("otherData", {}) or {}
    counts: dict[str, int] = {}
    for e in events:
        if isinstance(e, dict) and isinstance(e.get("name"), str):
            counts[e["name"]] = counts.get(e["name"], 0) + 1
    return {
        "wall_us": buckets["wall_us"],
        "buckets_us": stage_buckets,
        "bottleneck": bottleneck,
        "bandwidth": bandwidth(doc),
        "critical_path": critical_path(doc),
        "per_tenant": tenant_attribution(doc),
        "event_counts": dict(sorted(counts.items())),
        "n_events": len(events),
        "dropped": other.get("dropped", 0),
        "registry": other.get("registry", {}),
    }


def format_report(rep: dict) -> str:
    lines = [f"wall: {rep['wall_us'] / 1e3:.3f} ms  "
             f"({rep['n_events']} events, {rep['dropped']} dropped)"]
    wall = max(1e-12, rep["wall_us"])
    for b in (*PRIORITY, "stall"):
        us = rep["buckets_us"][b]
        lines.append(f"  {b:<10} {us / 1e3:9.3f} ms  "
                     f"{100.0 * us / wall:5.1f}%")
    lines.append(f"bottleneck: {rep['bottleneck']}")
    bw = rep["bandwidth"]
    lines.append(f"bandwidth: stored {bw['stored_bw_mbps']:.1f} MB/s "
                 f"({bw['stored_bytes']} B), effective "
                 f"{bw['effective_bw_mbps']:.1f} MB/s "
                 f"({bw['logical_bytes']} B)")
    tenants = rep.get("per_tenant", {})
    if any(name != "-" for name in tenants):
        total_busy = max(1e-12, sum(t["busy_us"] for t in tenants.values()))
        for name, t in tenants.items():
            lines.append(
                f"  tenant {name:<8} {t['busy_us'] / 1e3:9.3f} ms busy "
                f"{100.0 * t['busy_us'] / total_busy:5.1f}%  "
                f"(fetch {t['fetch'] / 1e3:.3f} / decode "
                f"{(t['decompress'] + t['decode']) / 1e3:.3f} / consume "
                f"{t['consume'] / 1e3:.3f}, {t['spans']} spans, "
                f"{t['window_hits']} window hits)")
    longest = rep["critical_path"]["longest"]
    if longest:
        lines.append(f"critical path: scan={longest['scan']} "
                     f"rg={longest['rg']} total="
                     f"{longest['total'] / 1e3:.3f} ms "
                     f"(fetch {longest['fetch'] / 1e3:.3f} / decode "
                     f"{(longest['decompress'] + longest['decode']) / 1e3:.3f}"
                     f" / consume {longest['consume'] / 1e3:.3f})")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace-event JSON exported by "
                                  "core/trace.py")
    ap.add_argument("--json", action="store_true",
                    help="print the machine-readable report")
    args = ap.parse_args()
    doc = load_trace(args.trace)
    errors = validate_trace(doc)
    if errors:
        print(f"[trace_report] {args.trace}: INVALID", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    rep = build_report(doc)
    if args.json:
        print(json.dumps(rep, indent=2, sort_keys=True))
    else:
        print(format_report(rep))
    return 0


if __name__ == "__main__":
    sys.exit(main())
