"""CI bit-identity gate: fused (late materialization) vs unfused.

Builds a tiny TPC-H snapshot and diffs the fused execution against the
reference twin — the unfused path that fully materializes every column
and evaluates the *same* canonical per-page reduce (DESIGN.md §7) — on
both decode backends:

  * Q6: the float64 totals must match **bit for bit** (``struct.pack``
    hex compare, not a tolerance), on pallas and host backends.
  * Q12: the per-shipmode count dicts must be exactly equal across
    fused / reference / legacy-unfused, and match the numpy oracle.
  * Launch economy: the fused Q6 scan must issue strictly fewer kernel
    launches than the unfused scan (the whole point of fusing).
  * Both results must agree with the row-at-a-time numpy oracle within
    float tolerance (bit-identity is *within* the canonical tiling;
    the legacy unfused consume tiles differently by design).

Exit status is nonzero on any mismatch, with the differing bits printed.

Usage:
    PYTHONPATH=src JAX_PLATFORMS=cpu python tools/check_fused_identity.py
"""

from __future__ import annotations

import argparse
import os
import struct
import sys
import tempfile


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sf", type=float,
                    default=float(os.environ.get("FUSED_SF", "0.004")))
    ap.add_argument("--seed", type=int, default=21)
    args = ap.parse_args()

    import numpy as np

    from repro.core.config import ACCELERATOR_OPTIMIZED
    from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                                  Q6_COLUMNS, q6, q6_reference, q12,
                                  q12_reference)
    from repro.core.scan import open_scanner
    from repro.data import tpch
    from repro.kernels.common import kernel_launch_count

    failures: list[str] = []
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=8_000,
                                        target_pages_per_chunk=10)

    with tempfile.TemporaryDirectory(prefix="fused_id_") as root:
        metas = tpch.write_tpch(root, sf=args.sf, config=cfg,
                                seed=args.seed)
        lpath = os.path.join(root, "lineitem.tab")
        opath = os.path.join(root, "orders.tab")
        line, orders = tpch.generate_tables(sf=args.sf, seed=args.seed)
        n_rg = len(metas["lineitem"].row_groups)

        oracle6 = q6_reference(
            {c: np.asarray(line[c]) for c in Q6_COLUMNS})

        for backend in ("pallas", "host"):
            def scan6(fused, backend=backend):
                sc = open_scanner(lpath, Q6_COLUMNS,
                                  decode_backend=backend)
                n0 = kernel_launch_count()
                got, _ = q6(sc, fused=fused)
                return got, kernel_launch_count() - n0

            got_f, lf = scan6(True)
            got_r, lr = scan6("reference")
            got_u, lu = scan6(False)
            bits_f = struct.pack("<d", got_f).hex()
            bits_r = struct.pack("<d", got_r).hex()
            if bits_f != bits_r:
                failures.append(
                    f"[{backend}] q6 fused vs reference NOT bit-identical: "
                    f"{bits_f} != {bits_r} ({got_f!r} vs {got_r!r})")
            for name, val in (("fused", got_f), ("unfused", got_u)):
                if abs(val - oracle6) > 1e-4 * max(1.0, abs(oracle6)):
                    failures.append(f"[{backend}] q6 {name} vs oracle: "
                                    f"{val!r} != {oracle6!r}")
            if backend == "pallas" and lf >= lu:
                failures.append(
                    f"[pallas] fused q6 did not save launches: "
                    f"fused={lf} >= unfused={lu} over {n_rg} row groups")
            print(f"[fused-id] [{backend}] q6 bits fused={bits_f} "
                  f"ref={bits_r} launches fused={lf} ref={lr} "
                  f"unfused={lu} n_rg={n_rg}")

        oracle12 = q12_reference(
            {c: np.asarray(line[c]) for c in Q12_LINEITEM_COLUMNS},
            {c: np.asarray(orders[c]) for c in Q12_ORDERS_COLUMNS})
        for backend in ("pallas", "host"):
            def run12(fused, backend=backend):
                lsc = open_scanner(lpath, Q12_LINEITEM_COLUMNS,
                                   decode_backend=backend)
                osc = open_scanner(opath, Q12_ORDERS_COLUMNS,
                                   decode_backend=backend)
                got, _, _ = q12(lsc, osc, fused=fused)
                return got
            got_f, got_r, got_u = run12(True), run12("reference"), run12(False)
            if not (got_f == got_r == got_u == oracle12):
                failures.append(
                    f"[{backend}] q12 mismatch: fused={got_f} ref={got_r} "
                    f"unfused={got_u} oracle={oracle12}")
            print(f"[fused-id] [{backend}] q12 fused == reference == "
                  f"unfused == oracle: "
                  f"{got_f == got_r == got_u == oracle12}")

    if failures:
        print("[fused-id] FAIL")
        for f in failures:
            print(" ", f)
        return 1
    print("[fused-id] ok — fused and unfused agree bit for bit, with "
          "strictly fewer launches on the fused path")
    return 0


if __name__ == "__main__":
    sys.exit(main())
