"""CI perf-regression gate over benchmark CSVs.

Compares the current ``--smoke`` benchmark CSVs against checked-in
baselines (results/benchmarks/baselines/) and fails when the scan path's
economy regresses:

  * **wall time**: any shared row whose ``us_per_call`` grew by more than
    ``--threshold`` (default 25%) — skipped when both sides are under
    ``--min-us``, where scheduler noise dominates a tiny-SF run.  When
    both CSVs carry a ``cpu_reference`` calibration row
    (benchmarks/common.py), current walls are first normalized by the
    machine-speed ratio so a slower runner than the baseline's doesn't
    read as a regression of every row at once;
  * **counters**: any increase in a counted quantity parsed from the
    ``derived`` column (``launches=``, ``launches_per_rg=``, ``requests=``,
    ``io_requests=``, ``groups=``) — these are deterministic, so the gate
    on them is exact (an increase of even one launch fails);
  * **coverage**: a row present in the baseline but missing from the
    current run (a silently-dropped measurement reads as a pass otherwise);
  * **fused launch economy**: inside the current run itself, the fused
    late-materialization Q6 row must carry strictly fewer ``launches=``
    than its unfused twin (``FUSED_PAIRS`` — deterministic, gated exact).

Writes a markdown comparison table (``--report``) for upload as a CI
artifact and exits non-zero on any regression.

Usage:
    python tools/check_regression.py \
        --baseline results/benchmarks/baselines \
        --current results/benchmarks \
        --report regression-report.md \
        fig5_smoke.csv scan_plan_smoke.csv concurrent_smoke.csv \
        dataset_smoke.csv

Demo an injected regression (doubles one wall time, bumps one counter):
    python tools/check_regression.py --selftest
"""

from __future__ import annotations

import argparse
import os
import sys

COUNT_KEYS = ("launches", "launches_per_rg", "requests", "io_requests",
              "groups")

#: fault-recovery counters (DESIGN.md §6): parsed and shown in the report
#: but NEVER gated — a chaos run's retries are expected recovery work, not
#: a regression, and their absence from older baselines must not trip the
#: dropped-counter check either
INFO_KEYS = ("retries", "checksum_failures", "timeouts",
             "fragments_quarantined",
             # distributed-scan observability (DESIGN.md §8): prefetch
             # economics, latency percentiles, per-backend bytes, and
             # work-stealing counts — informational, never gated
             "prefetch_hits", "prefetch_misses", "io_p50_us", "io_p95_us",
             "stolen_fragments", "bytes_object", "bytes_sim", "bytes_real",
             "hidden_pct",
             # multi-tenant front end (DESIGN.md §11): per-class latency
             # percentiles, delivered-window / result-cache hit counters,
             # the concurrent arm's timing-dependent fetch count, and the
             # window-repeat row's first-run fetch count — informational,
             # never gated (the deterministic ``io_requests=`` on the
             # companion sequential rows carries the gate)
             "io_fetched", "shared_rgs", "window_hits", "io_first",
             "result_cache_hits",
             "gold_p50_us", "gold_p95_us", "gold_p99_us",
             "bronze_p50_us", "bronze_p95_us", "bronze_p99_us")


def parse_csv(path: str) -> "dict[str, tuple]":
    """name → (us_per_call, {counter: value}, tags, {info: value}) from a
    benchmark CSV.  ``tags`` are the bare (non key=value) derived tokens,
    e.g. ``sim`` / ``measured`` — ``sim`` rows are deterministic model
    times and are never machine-speed scaled.  ``info`` holds the
    INFO_KEYS counters (displayed, never gated)."""
    rows: dict[str, tuple] = {}
    with open(path) as f:
        header = f.readline()
        if not header.startswith("name,"):
            raise SystemExit(f"{path}: not a benchmark CSV")
        for line in f:
            line = line.rstrip("\n")
            if not line:
                continue
            name, us, derived = line.split(",", 2)
            counters: dict[str, float] = {}
            info: dict[str, float] = {}
            tags = set()
            for token in derived.split(";"):
                if "=" not in token:
                    if token:
                        tags.add(token)
                    continue
                k, _, v = token.partition("=")
                if k in COUNT_KEYS or k in INFO_KEYS:
                    try:
                        (counters if k in COUNT_KEYS else info)[k] = float(v)
                    except ValueError:
                        pass
            rows[name] = (float(us), counters, tags, info)
    return rows


REFERENCE_ROW = "cpu_reference"

#: cross-row invariants inside ONE current run (not vs the baseline):
#: the fused late-materialization row must launch strictly fewer kernels
#: than its unfused twin — that economy is the whole point of fusing
#: (DESIGN.md §7), and it is deterministic, so the gate is exact.
FUSED_PAIRS = (
    ("fig5_q6_optimized_pallas_fused", "fig5_q6_optimized_pallas_unfused"),
)


def fused_launch_rules(rows: dict) -> list[str]:
    """Regressions from the fused-vs-unfused cross-row launch invariant.
    Pairs where neither row is present are skipped (other CSVs); a
    half-present pair is itself a failure — a silently dropped fused row
    would otherwise disable the gate."""
    regs: list[str] = []
    for fused_name, unfused_name in FUSED_PAIRS:
        have_f, have_u = fused_name in rows, unfused_name in rows
        if not have_f and not have_u:
            continue
        if not (have_f and have_u):
            missing = unfused_name if have_f else fused_name
            regs.append(f"{missing}: missing from current run "
                        "(fused/unfused rows gate as a pair)")
            continue
        lf = rows[fused_name][1].get("launches")
        lu = rows[unfused_name][1].get("launches")
        if lf is None or lu is None:
            regs.append(f"{fused_name}: fused/unfused rows must both "
                        "carry a launches= counter")
        elif lf >= lu:
            regs.append(f"{fused_name}: launches={lf:g} not strictly "
                        f"below unfused ({lu:g}) — the fused path must "
                        "save launches")
    return regs


def speed_scale(baseline: dict, current: dict) -> float:
    """base_ref / cur_ref: multiplied into current wall times so a slower
    (or noisier) machine than the baseline's doesn't read as a regression
    of every row at once.  Clamped — a wildly different reference means
    the machines aren't comparable, and over-correcting would mask real
    regressions.  1.0 when either side lacks the reference row."""
    if REFERENCE_ROW not in baseline or REFERENCE_ROW not in current:
        return 1.0
    base_ref = baseline[REFERENCE_ROW][0]
    cur_ref = current[REFERENCE_ROW][0]
    if base_ref <= 0 or cur_ref <= 0:
        return 1.0
    return min(4.0, max(0.25, base_ref / cur_ref))


def merge_min(a: dict, b: dict) -> dict:
    """Per-row minimum wall across two runs of the same suite (counters
    ride along from whichever run was faster; they are deterministic, so
    the choice cannot hide a counter regression).  Rows present in only
    one run keep that run's value."""
    out = dict(a)
    for name, row in b.items():
        if name not in out or row[0] < out[name][0]:
            out[name] = row
    return out


def compare(baseline: dict, current: dict, threshold: float, min_us: float,
            scale: float = 1.0) -> tuple[list[str], list[list[str]]]:
    """Returns (regressions, report_rows).

    A wall regression must hold in BOTH the raw and the machine-speed
    normalized (× ``scale``, see speed_scale) reading: normalization
    exists to forgive machine differences, not to manufacture failures
    when the calibration lands in a different noise window than the rows.
    Deterministic ``sim``-tagged rows are never scaled."""
    regressions: list[str] = []
    table: list[list[str]] = []
    for name, row in sorted(baseline.items()):
        base_us, base_counts = row[0], row[1]
        if name == REFERENCE_ROW:
            continue
        if name not in current:
            regressions.append(f"{name}: missing from current run")
            table.append([name, f"{base_us:.1f}", "—", "—", "MISSING"])
            continue
        cur = current[name]
        cur_us, cur_counts = cur[0], cur[1]
        tags = cur[2] if len(cur) > 2 else set()
        row_scale = 1.0 if "sim" in tags else scale
        gated_us = min(cur_us, cur_us * row_scale)
        ratio = gated_us / base_us if base_us > 0 else float("inf")
        status = "ok"
        if gated_us > base_us * (1.0 + threshold) and (
                gated_us >= min_us or base_us >= min_us):
            status = "WALL REGRESSION"
            regressions.append(
                f"{name}: wall {base_us:.1f}us -> {gated_us:.1f}us "
                f"(+{(ratio - 1.0) * 100:.0f}% > {threshold * 100:.0f}%, "
                "raw and machine-normalized)")
        for k, base_v in base_counts.items():
            cur_v = cur_counts.get(k)
            if cur_v is None:
                # a dropped counter token would otherwise disable its gate
                status = "COUNTER MISSING"
                regressions.append(
                    f"{name}: counter {k} missing from current run "
                    "(gated counters must keep being emitted)")
            elif cur_v > base_v:
                status = "COUNTER REGRESSION"
                regressions.append(
                    f"{name}: {k} {base_v:g} -> {cur_v:g} (any increase "
                    "fails)")
        counts = ";".join(f"{k}={cur_counts.get(k, float('nan')):g}"
                          for k in base_counts) or "—"
        # informational fault-recovery counters ride along, never gated
        cur_info = cur[3] if len(cur) > 3 else {}
        info = ";".join(f"{k}={v:g}" for k, v in sorted(cur_info.items()))
        if info:
            counts = f"{counts};{info}" if counts != "—" else info
        table.append([name, f"{base_us:.1f}", f"{gated_us:.1f}",
                      counts, status])
    for name in sorted(set(current) - set(baseline)):
        if name == REFERENCE_ROW:
            continue
        table.append([name, "—", f"{current[name][0]:.1f}", "—",
                      "new (no baseline)"])
    return regressions, table


def write_report(path: str, file_tables: dict[str, list[list[str]]],
                 regressions: list[str], threshold: float) -> None:
    with open(path, "w") as f:
        f.write("# Benchmark regression gate\n\n")
        f.write(f"Wall-time threshold: +{threshold * 100:.0f}% · counter "
                "increases: any\n\n")
        if regressions:
            f.write("## REGRESSIONS\n\n")
            for r in regressions:
                f.write(f"- {r}\n")
            f.write("\n")
        else:
            f.write("No regressions detected.\n\n")
        for fname, table in file_tables.items():
            f.write(f"## {fname}\n\n")
            f.write("| name | baseline us | current us | counters | "
                    "status |\n|---|---|---|---|---|\n")
            for row in table:
                f.write("| " + " | ".join(row) + " |\n")
            f.write("\n")


def selftest() -> int:
    """Inject a regression into a synthetic pair and assert the gate trips."""
    base = {"q6_overlapped": (1000.0, {"launches": 4.0}),
            "q12_overlapped": (2000.0, {"requests": 8.0})}
    # info counters (retries, …) are informational: nonzero values in the
    # current run must not gate
    good = {"q6_overlapped": (1100.0, {"launches": 4.0}, {"measured"},
                              {"retries": 5.0, "timeouts": 1.0}),
            "q12_overlapped": (1900.0, {"requests": 8.0})}
    bad = {"q6_overlapped": (2000.0, {"launches": 4.0}),      # 2x wall
           "q12_overlapped": (1900.0, {"requests": 9.0})}     # +1 request
    ok_regs, _ = compare(base, good, 0.25, 500.0)
    bad_regs, _ = compare(base, bad, 0.25, 500.0)
    print("clean run ->", ok_regs or "no regressions")
    print("injected run ->")
    for r in bad_regs:
        print(" ", r)
    assert not ok_regs and len(bad_regs) == 2
    # fused cross-row invariant: strictly fewer launches than unfused
    pair_ok = {"fig5_q6_optimized_pallas_fused": (500.0, {"launches": 8.0}),
               "fig5_q6_optimized_pallas_unfused":
                   (900.0, {"launches": 12.0})}
    pair_bad = {"fig5_q6_optimized_pallas_fused":
                    (500.0, {"launches": 12.0}),
                "fig5_q6_optimized_pallas_unfused":
                    (900.0, {"launches": 12.0})}
    pair_half = {"fig5_q6_optimized_pallas_fused":
                     (500.0, {"launches": 8.0})}
    assert not fused_launch_rules(pair_ok)
    assert not fused_launch_rules({})          # other CSVs: no pair, no gate
    bad_pair_regs = fused_launch_rules(pair_bad)
    half_regs = fused_launch_rules(pair_half)
    print("fused pair (launches not saved) ->")
    for r in bad_pair_regs + half_regs:
        print(" ", r)
    assert len(bad_pair_regs) == 1 and len(half_regs) == 1
    print("selftest ok: gate passes clean runs and trips on injected "
          "wall/counter regressions and fused launch-economy violations")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*",
                    default=["fig5_smoke.csv", "scan_plan_smoke.csv",
                             "concurrent_smoke.csv", "dataset_smoke.csv",
                             "distributed_smoke.csv"])
    ap.add_argument("--baseline", default="results/benchmarks/baselines")
    ap.add_argument("--current", default="results/benchmarks")
    ap.add_argument("--current2", default=None,
                    help="optional second run of the same CSVs; rows are "
                         "gated on the per-row minimum wall of the two "
                         "runs, so one noisy scheduler window on a shared "
                         "runner cannot fail the gate by itself")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("REGRESSION_THRESHOLD",
                                                 "0.25")))
    ap.add_argument("--min-us", type=float, default=500.0,
                    help="skip wall gate when both sides are below this "
                         "(scheduler noise floor at smoke SF)")
    ap.add_argument("--report", default=None,
                    help="write a markdown comparison here (CI artifact)")
    ap.add_argument("--selftest", action="store_true",
                    help="demonstrate the gate on an injected regression")
    args = ap.parse_args()
    if args.selftest:
        return selftest()

    files = args.files or ["fig5_smoke.csv", "scan_plan_smoke.csv",
                           "concurrent_smoke.csv", "dataset_smoke.csv",
                           "distributed_smoke.csv"]
    all_regressions: list[str] = []
    file_tables: dict[str, list[list[str]]] = {}
    for fname in files:
        base_path = os.path.join(args.baseline, fname)
        cur_path = os.path.join(args.current, fname)
        if not os.path.exists(base_path):
            print(f"[check_regression] no baseline for {fname} — skipping "
                  "(check one in under results/benchmarks/baselines/)")
            continue
        if not os.path.exists(cur_path):
            all_regressions.append(f"{fname}: current CSV missing "
                                   f"({cur_path})")
            continue
        base_rows = parse_csv(base_path)
        cur_rows = parse_csv(cur_path)
        if args.current2:
            cur2_path = os.path.join(args.current2, fname)
            if os.path.exists(cur2_path):
                cur_rows = merge_min(cur_rows, parse_csv(cur2_path))
        scale = speed_scale(base_rows, cur_rows)
        if scale != 1.0:
            print(f"[check_regression] {fname}: machine-speed scale "
                  f"{scale:.3f} (cpu_reference rows)")
        regs, table = compare(base_rows, cur_rows, args.threshold,
                              args.min_us, scale)
        regs.extend(fused_launch_rules(cur_rows))
        all_regressions.extend(f"{fname}: {r}" for r in regs)
        file_tables[fname] = table
    if args.report:
        write_report(args.report, file_tables, all_regressions,
                     args.threshold)
        print(f"[check_regression] report -> {args.report}")
    if all_regressions:
        print("[check_regression] FAIL")
        for r in all_regressions:
            print(" ", r)
        return 1
    print("[check_regression] ok — no regressions "
          f"(threshold +{args.threshold * 100:.0f}%, counters exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
