"""CI observability gate: the flight recorder must be correct and cheap.

Runs the smoke Q6 dataset scan (the same shape the chaos gate uses)
twice — tracing off, tracing on — and fails unless (DESIGN.md §10):

  * the traced result is **bit-identical** to the untraced run and the
    gated counters (kernel launches, io_requests) are exactly equal —
    observation must not perturb the observed schedule's accounting,
  * the exported Chrome JSON passes ``tools/trace_report.py``'s schema
    validation (no negative durations, balanced spans, known phases),
  * ``trace_report`` reproduces the run's measured wall within
    ``--wall-tolerance`` (default 10%) and names a bottleneck stage,
  * tracing-on wall is within ``--threshold`` (default 5%) of
    tracing-off wall, measured min-of-rounds with a small absolute
    slack for tiny-SF scheduler noise (the CRC-gate pattern).

The gate drives the recorder explicitly (``trace.enable``/``disable``),
so it behaves identically under ``REPRO_TRACE=1`` — the CI leg sets it
to also exercise the env-resolution path on the first ``active()``.

Usage:
    PYTHONPATH=src JAX_PLATFORMS=cpu python tools/trace_check.py
"""

from __future__ import annotations

import argparse
import os
import struct
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import trace_report  # noqa: E402  (tools/ sibling, not a package)


def _clear_decoded_caches():
    from repro.core.compression import chunk_decompress_memo
    from repro.kernels.dict_decode import dict_cache_clear
    chunk_decompress_memo().clear()
    dict_cache_clear()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sf", type=float,
                    default=float(os.environ.get("TRACE_SF", "0.005")))
    ap.add_argument("--rounds", type=int,
                    default=int(os.environ.get("TRACE_ROUNDS", "3")))
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("TRACE_THRESHOLD",
                                                 "0.05")),
                    help="max tracing-on wall overhead vs tracing-off")
    ap.add_argument("--slack-us", type=float, default=5_000.0,
                    help="absolute wall slack for the overhead gate "
                         "(tiny-SF scheduler noise floor)")
    ap.add_argument("--wall-tolerance", type=float, default=0.10,
                    help="trace_report wall must match the measured "
                         "run wall within this fraction")
    args = ap.parse_args()

    from repro.core import trace
    from repro.core.config import ACCELERATOR_OPTIMIZED
    from repro.core.query import q6
    from repro.data import tpch
    from repro.dataset import write_dataset

    failures: list[str] = []
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=3_000,
                                        target_pages_per_chunk=2)
    open_opts = {"decode_backend": "host"}

    with tempfile.TemporaryDirectory(prefix="trace_") as root:
        line, _ = tpch.generate_tables(sf=args.sf, seed=1,
                                       include_strings=False)
        ds = write_dataset(line, os.path.join(root, "ds"), cfg,
                           partition_by="l_shipdate", how="range",
                           fragments=4)

        def run():
            _clear_decoded_caches()
            t0 = time.perf_counter()
            res, rep = q6(ds, prune=True, window=4, open_opts=open_opts)
            return res, rep, time.perf_counter() - t0

        # warm jits/caches so neither leg pays one-time compilation
        run()

        # -- identity leg: tracing must not change what is observed ----
        trace.disable()
        res_off, rep_off, _ = run()
        tr = trace.enable()
        tr.clear()
        res_on, rep_on, _ = run()
        trace_path = os.path.join(root, "trace_q6.json")
        tr.export(trace_path)
        trace.disable()

        if struct.pack("<d", res_on) != struct.pack("<d", res_off):
            failures.append(f"traced result diverged: {res_on!r} != "
                            f"{res_off!r}")
        if rep_on.n_kernel_launches != rep_off.n_kernel_launches:
            failures.append(
                f"tracing changed kernel launches: "
                f"{rep_on.n_kernel_launches} != "
                f"{rep_off.n_kernel_launches}")
        if rep_on.n_io_requests != rep_off.n_io_requests:
            failures.append(f"tracing changed io_requests: "
                            f"{rep_on.n_io_requests} != "
                            f"{rep_off.n_io_requests}")
        if rep_on.trace_events <= 0:
            failures.append("traced run recorded no events")
        if rep_off.trace_events != 0:
            failures.append(f"untraced run recorded "
                            f"{rep_off.trace_events} events")
        print(f"[trace] traced run bit-identical "
              f"(launches={rep_on.n_kernel_launches}, "
              f"io_requests={rep_on.n_io_requests}, "
              f"events={rep_on.trace_events})")

        # -- schema + report leg ---------------------------------------
        doc = trace_report.load_trace(trace_path)
        errors = trace_report.validate_trace(doc)
        if errors:
            failures.append(f"exported trace failed schema validation: "
                            f"{errors[:5]}")
        else:
            rep = trace_report.build_report(doc)
            measured_us = rep_on.measured_wall * 1e6
            lo = measured_us * (1.0 - args.wall_tolerance)
            hi = measured_us * (1.0 + args.wall_tolerance)
            if not lo <= rep["wall_us"] <= hi:
                failures.append(
                    f"trace_report wall {rep['wall_us']:.0f}us outside "
                    f"±{args.wall_tolerance * 100:.0f}% of measured "
                    f"{measured_us:.0f}us")
            known = ("fetch", "decompress", "decode", "consume", "stall")
            if rep["bottleneck"] not in known:
                failures.append(f"trace_report named no bottleneck "
                                f"stage: {rep['bottleneck']!r}")
            if rep["dropped"]:
                failures.append(f"smoke trace dropped {rep['dropped']} "
                                f"events (cap too small for smoke?)")
            print(f"[trace] schema ok; report wall "
                  f"{rep['wall_us'] / 1e3:.2f}ms vs measured "
                  f"{measured_us / 1e3:.2f}ms, bottleneck="
                  f"{rep['bottleneck']}")

        # -- overhead gate (min-of-rounds, CRC-gate pattern) -----------
        def best_wall() -> float:
            best = float("inf")
            for _ in range(max(1, args.rounds)):
                _, _, wall = run()
                best = min(best, wall)
            return best

        trace.disable()
        off_wall = best_wall()
        tr = trace.enable()
        tr.clear()
        on_wall = best_wall()
        trace.disable()
        trace.reset()
        budget = off_wall * (1.0 + args.threshold) \
            + args.slack_us * 1e-6
        print(f"[trace] overhead: on {on_wall * 1e6:.0f}us vs off "
              f"{off_wall * 1e6:.0f}us (budget {budget * 1e6:.0f}us, "
              f"min of {args.rounds} rounds)")
        if on_wall > budget:
            failures.append(
                f"tracing overhead exceeds its budget: "
                f"{on_wall * 1e6:.0f}us > {budget * 1e6:.0f}us "
                f"(tracing-off {off_wall * 1e6:.0f}us "
                f"+{args.threshold * 100:.0f}% "
                f"+{args.slack_us:.0f}us slack)")

    if failures:
        print("[trace] FAIL")
        for f in failures:
            print(" ", f)
        return 1
    print("[trace] ok — tracing is bit-transparent, schema-valid, "
          "reconciles with the measured wall, and stays within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
