"""Regenerates the data-driven sections of EXPERIMENTS.md from results/.

Usage: PYTHONPATH=src python tools/make_experiments.py > EXPERIMENTS.md
(narrative text lives here; tables come from results/dryrun + benchmarks)
"""

import glob
import json
import os
import sys

sys.path.insert(0, "src")

from benchmarks.roofline import analyze_cell, load_cells, markdown_table, \
    suggestion  # noqa: E402
from repro.configs import SHAPES  # noqa: E402


def dryrun_section() -> str:
    out = []
    for mesh in ("single_pod", "multi_pod"):
        files = sorted(glob.glob(f"results/dryrun/{mesh}/*.json"))
        base = [json.load(open(f)) for f in files
                if "__" in f and f.count("__") == 1]
        ok = [r for r in base if r["status"] == "ok"]
        sk = [r for r in base if r["status"] == "skipped"]
        shape = "2×16×16 (512 chips)" if mesh == "multi_pod" \
            else "16×16 (256 chips)"
        out.append(f"### {mesh} — {shape}: "
                   f"{len(ok)} compiled, {len(sk)} principled skips")
        out.append("")
        out.append("| arch | shape | compile (s) | dot FLOPs/dev | "
                   "HLO bytes/dev | collective bytes/dev | "
                   "arg bytes/dev | loop-mult exact |")
        out.append("|---|---|---|---|---|---|---|---|")
        for r in sorted(base, key=lambda x: (x["arch"], x["shape"])):
            if r["status"] == "skipped":
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — "
                           f"| — | skip: {r['reason']} |")
                continue
            mem = r["memory"].get("argument_bytes")
            out.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['compile_seconds']:.1f} | "
                f"{r['hlo']['dot_flops_per_device']:.2e} | "
                f"{r['hlo']['memory_bytes_per_device']:.2e} | "
                f"{r['collectives']['total_bytes']:.2e} | "
                f"{mem if mem is not None else 'n/a'} | "
                f"{r['hlo']['exact_loop_multipliers']} |")
        out.append("")
    return "\n".join(out)


def multipod_section() -> str:
    out = ["### Single-pod vs multi-pod (per-device terms, train_4k)",
           "",
           "Global batch is fixed (256 sequences), so doubling chips to "
           "2×16×16 should ~halve per-device FLOPs while the pod axis "
           "joins the DP all-reduce — the table shows the pod dimension "
           "actually shards:",
           "",
           "| arch | dot FLOPs/dev 1-pod | 2-pod | ratio | "
           "collective B/dev 1-pod | 2-pod |",
           "|---|---|---|---|---|---|"]
    for f in sorted(glob.glob("results/dryrun/single_pod/*__train_4k.json")):
        r1 = json.load(open(f))
        if r1.get("status") != "ok":
            continue
        f2 = f.replace("single_pod", "multi_pod")
        if not os.path.exists(f2):
            continue
        r2 = json.load(open(f2))
        if r2.get("status") != "ok" or "hlo" not in r2:
            continue
        d1 = r1["hlo"]["dot_flops_per_device"]
        d2 = r2["hlo"]["dot_flops_per_device"]
        out.append(
            f"| {r1['arch']} | {d1:.2e} | {d2:.2e} | {d2/d1:.2f} | "
            f"{r1['collectives']['total_bytes']:.2e} | "
            f"{r2['collectives']['total_bytes']:.2e} |")
    out.append("")
    return "\n".join(out)


def perf_ladder(arch: str, shape: str, variants: list) -> str:
    rows = []
    for v in ["baseline"] + variants:
        suffix = "" if v == "baseline" else f"__{v}"
        path = f"results/dryrun/single_pod/{arch}__{shape}{suffix}.json"
        if not os.path.exists(path):
            continue
        rec = json.load(open(path))
        a = analyze_cell(rec)
        if a is None:
            continue
        rows.append((v, a))
    out = [f"#### {arch} × {shape}", "",
           "| variant | compute (s) | memory (s) | collective (s) | "
           "dominant | bound (s) | Δbound vs baseline | MODEL/HLO |",
           "|---|---|---|---|---|---|---|---|"]
    base_bound = rows[0][1]["bound_s"] if rows else 1.0
    for v, a in rows:
        out.append(
            f"| {v} | {a['compute_s']:.4g} | {a['memory_s']:.4g} | "
            f"{a['collective_s']:.4g} | {a['dominant']} | "
            f"{a['bound_s']:.4g} | "
            f"{base_bound/max(a['bound_s'],1e-12):.2f}× | "
            f"{a['useful_ratio']:.2f} |")
    out.append("")
    return "\n".join(out)


def bench_csv_table(tag: str, title: str) -> str:
    path = f"results/benchmarks/{tag}.csv"
    if not os.path.exists(path):
        return f"### {title}\n\n(run `python -m benchmarks.run`)\n"
    lines = open(path).read().strip().splitlines()[1:]
    out = [f"### {title}", "", "| name | wall (µs) | derived |",
           "|---|---|---|"]
    for line in lines:
        parts = line.split(",", 2)
        if len(parts) == 3:
            out.append(f"| {parts[0]} | {float(parts[1]):.0f} | "
                       f"`{parts[2]}` |")
    out.append("")
    return "\n".join(out)


def main():
    with open("tools/experiments_narrative.md") as f:
        narrative = f.read()
    blocks = {
        "{{DRYRUN}}": dryrun_section() + "\n" + multipod_section(),
        "{{ROOFLINE}}": ("## §Roofline — single-pod 16×16, baseline\n\n"
                         + markdown_table("single_pod")),
        "{{PERF_DSV3}}": perf_ladder(
            "deepseek-v3-671b", "train_4k",
            ["dots", "moe_shmap", "shmap_dots", "shmap_dots_accum2",
             "a2a_full"]),
        "{{PERF_MIXTRAL}}": perf_ladder(
            "mixtral-8x22b", "train_4k",
            ["dots", "moe_shmap", "shmap_dots", "shmap_dots_accum2",
             "a2a_full"]),
        "{{PERF_GRANITE}}": perf_ladder(
            "granite-3-8b", "decode_32k", ["pref", "kv_int8"]),
        "{{FIG2A}}": bench_csv_table("fig2a", "Fig. 2(a) — page count"),
        "{{FIG2B}}": bench_csv_table("fig2b", "Fig. 2(b) — RG size"),
        "{{FIG3}}": bench_csv_table("fig3", "Fig. 3 — encoding "
                                    "flexibility × SSD scaling"),
        "{{FIG3C}}": bench_csv_table("fig3c", "Fig. 3 — selective "
                                     "compression"),
        "{{FIG5}}": bench_csv_table("fig5", "Fig. 5 — query level"),
        "{{SEC5}}": bench_csv_table("sec5", "§5 — rewriter overhead"),
        "{{KERNELS}}": bench_csv_table("kernels", "Decode throughput per "
                                       "encoding (host-measured)"),
    }
    for k, v in blocks.items():
        narrative = narrative.replace(k, v)
    print(narrative)


if __name__ == "__main__":
    main()
