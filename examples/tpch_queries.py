"""Paper §4 / Fig. 5: blocking vs overlapped execution at the query level.

Runs Q6 (scan-heavy) and Q12 (join) over the optimized file configuration
with both reader designs and prints the modeled walls next to the storage
lower bound.

    PYTHONPATH=src python examples/tpch_queries.py [--sf 0.02]
"""

import argparse
import tempfile

from repro.core import ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TabFileReader
from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                              Q6_COLUMNS, q6, q12)
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.core.storage import SimulatedStorage
from repro.data import tpch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        metas = tpch.write_tpch(
            d, sf=args.sf, seed=3, include_strings=False,
            config=ACCELERATOR_OPTIMIZED.replace(rows_per_rg=250_000))
        lpath, opath = metas["lineitem_path"], metas["orders_path"]

        def scanner(path, cols):
            return open_scanner(path, columns=cols, backend="sim",
                                n_lanes=1, decode_backend="host")

        # warm jits out of the timed paths
        q6(scanner(lpath, list(Q6_COLUMNS)), overlapped=False)

        meta = TabFileReader(lpath).meta
        sim = SimulatedStorage(lpath, n_lanes=1)
        bound = sum(rg.column(c).stored_bytes for rg in meta.row_groups
                    for c in Q6_COLUMNS) / sim.lane_bandwidth
        print(f"Q6  storage lower bound: {bound*1e3:7.3f} ms")
        for overlapped in (False, True):
            rev, rep = q6(scanner(lpath, list(Q6_COLUMNS)),
                          overlapped=overlapped, prune=False)
            mode = "overlapped" if overlapped else "blocking  "
            print(f"Q6  {mode} wall={rep.modeled_wall*1e3:8.3f} ms "
                  f"({rep.modeled_wall/bound:6.1f}x bound) "
                  f"revenue={rev:.2f}")
            if overlapped:
                print(f"    pipeline stages: {rep.stage_summary}")

        for overlapped in (False, True):
            res, brep, prep = q12(
                scanner(lpath, Q12_LINEITEM_COLUMNS),
                scanner(opath, Q12_ORDERS_COLUMNS), overlapped=overlapped)
            wall = brep.modeled_wall + prep.modeled_wall
            mode = "overlapped" if overlapped else "blocking  "
            print(f"Q12 {mode} wall={wall*1e3:8.3f} ms counts={res}")

        # -- the serving loop: N concurrent Q6 clients share the pool -----
        # Every overlapped scan above already ran through the process-wide
        # ScanService; submitting from several threads at once additionally
        # exercises fair round-robin scheduling and cooperative-scan
        # sharing (identical in-flight row groups decode once).
        import threading
        import time

        from repro.core.scheduler import scan_service

        svc = scan_service()
        walls = {}

        def client(k):
            t0 = time.perf_counter()
            q6(scanner(lpath, list(Q6_COLUMNS)), prune=False)
            walls[k] = time.perf_counter() - t0

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(4)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        agg = time.perf_counter() - t0
        print(f"Q6  serving loop: 4 concurrent clients in {agg*1e3:.1f} ms "
              f"(p95 {max(walls.values())*1e3:.1f} ms, "
              f"{svc.shared_rgs} row groups served cooperatively)")


if __name__ == "__main__":
    main()
