"""Dataset-layer quickstart: partitioned data lake → pruned sharded Q6.

Builds a 16-fragment range-partitioned lineitem dataset, runs Q6 through
the manifest planner + sharded ScanService executor (file pruning under
the FY1994 predicate), verifies the pruned result is bit-identical to an
unpruned full scan, then appends a badly-configured fragment and runs
online compaction behind the atomic manifest swap.

    PYTHONPATH=src python examples/tpch_dataset.py [--sf 0.02]
"""

import argparse
import os
import tempfile
import time

from repro.core import ACCELERATOR_OPTIMIZED, CPU_DEFAULT
from repro.core.query import q6, q6_rg_stats_predicate
from repro.data import tpch
from repro.dataset import (Dataset, compact_dataset, plan_compaction,
                           plan_dataset_scan, write_dataset)

SIM_OPTS = {"backend": "sim", "decode_backend": "host"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    args = ap.parse_args()
    line, _ = tpch.generate_tables(sf=args.sf, seed=3,
                                   include_strings=False)
    # size the target row group to the dataset so the 16 healthy
    # fragments aren't flagged "small" at tiny --sf
    tuned = ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=max(2_000, line.num_rows // 24),
        target_pages_per_chunk=16)

    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "lineitem_ds")
        ds = write_dataset(line, root, tuned, partition_by="l_shipdate",
                           how="range", fragments=16)
        print(f"dataset: {len(ds.fragments)} fragments, "
              f"{ds.num_rows:,} rows, {ds.stored_bytes/1e6:.1f} MB "
              f"(manifest {os.path.basename(ds.manifest_path)})")

        plan = plan_dataset_scan(ds,
                                 predicate_stats=q6_rg_stats_predicate)
        print(f"FY1994 plan: {plan.summary()}")

        # warm jits/caches, then measure
        q6(ds, prune=False, open_opts=SIM_OPTS)
        t0 = time.perf_counter()
        pruned, rep = q6(ds, prune=True, open_opts=SIM_OPTS)
        t_pruned = time.perf_counter() - t0
        t0 = time.perf_counter()
        full, _ = q6(ds, prune=False, open_opts=SIM_OPTS)
        t_full = time.perf_counter() - t0
        assert pruned == full, "pruning must not change the result"
        print(f"Q6 pruned  {t_pruned*1e3:7.2f} ms  ({rep.summary()})")
        print(f"Q6 full    {t_full*1e3:7.2f} ms  — results bit-identical")

        # a producer appends a CPU-default (misconfigured) fragment …
        ds.append_table(line.slice(0, min(10_000, line.num_rows)),
                        CPU_DEFAULT)
        cplan = plan_compaction(ds, target_config=tuned)
        print(f"compaction: {cplan.n_inputs} fragment(s) flagged "
              f"({sorted(set(cplan.reasons.values()))}) "
              f"-> {cplan.n_outputs} rewritten")
        ds, crep = compact_dataset(ds, cplan)
        print(f"compacted in {crep.seconds*1e3:.1f} ms, size ratio "
              f"{crep.size_ratio:.2f}; dataset now "
              f"{len(ds.fragments)} fragments (generation "
              f"{Dataset.load(root).generation})")


if __name__ == "__main__":
    main()
