"""End-to-end driver: train a ~100M-param LM whose batches stream out of a
TabFile corpus through the paper's configured scan path.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Defaults build a 12L/768d/12H/3072ff/32k-vocab decoder (~110M params,
fp32) and train with AdamW + warmup-cosine, checkpointing every 50 steps
(kill it mid-run and restart: it resumes from the loader cursor).  Use
``--tiny`` for a seconds-scale demo of the same path.
"""

import argparse
import os

from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.data.loader import TabLoader
from repro.data.tokens import write_corpus
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.runner import RunnerConfig, TrainRunner


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32_000,
        block_pattern=("full",), param_dtype="float32",
        compute_dtype="float32", remat="none", loss_chunk=128)


def lm_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=2_000,
        block_pattern=("full",), param_dtype="float32",
        compute_dtype="float32", remat="none", loss_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    model = Model(cfg)
    import jax
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    os.makedirs(args.workdir, exist_ok=True)
    corpus = os.path.join(args.workdir, f"corpus_{cfg.name}.tab")
    if not os.path.exists(corpus):
        n_tokens = max(4_000_000,
                       2 * args.steps * args.batch * (args.seq_len + 1))
        print(f"writing {n_tokens/1e6:.1f}M-token corpus "
              f"(TPU-aware TabFile config) -> {corpus}")
        write_corpus(corpus, n_tokens, cfg.vocab_size,
                     ACCELERATOR_OPTIMIZED.replace(
                         rows_per_rg=2_000_000,
                         target_pages_per_chunk=100), seed=0)

    loader = TabLoader(corpus, seq_len=args.seq_len,
                       batch_per_shard=args.batch)
    runner = TrainRunner(
        model,
        OptConfig(peak_lr=args.lr, warmup_steps=max(10, args.steps // 20),
                  total_steps=args.steps),
        loader, os.path.join(args.workdir, f"ckpt_{cfg.name}"),
        RunnerConfig(total_steps=args.steps, save_every=50, log_every=10,
                     fail_at_step=args.fail_at))
    out = runner.run()
    hist = out["history"]
    if hist:
        print(f"\ntrained to step {out['final_step']}: "
              f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
