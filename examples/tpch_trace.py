"""Observability quickstart: trace a sharded Q6 and name its bottleneck.

Builds a small partitioned lineitem dataset, runs Q6 through the
dataset executor with the flight recorder on (``trace=`` kwarg,
DESIGN.md §10), exports Chrome/Perfetto trace-event JSON — loadable
as-is in chrome://tracing or https://ui.perfetto.dev — and prints
``tools/trace_report.py``'s stage-bucket attribution: where the run's
wall time went (fetch / decompress / decode / consume / stall), the
per-row-group critical path, and the bottleneck stage.

    PYTHONPATH=src python examples/tpch_trace.py [--sf 0.02] [--out t.json]
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "tools"))
import trace_report  # noqa: E402

from repro.core import ACCELERATOR_OPTIMIZED
from repro.core.query import q6
from repro.data import tpch
from repro.dataset import write_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    ap.add_argument("--out", default="trace_q6.json",
                    help="trace-event JSON output path")
    args = ap.parse_args()

    line, _ = tpch.generate_tables(sf=args.sf, seed=3,
                                   include_strings=False)
    tuned = ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=max(2_000, line.num_rows // 24),
        target_pages_per_chunk=16)

    with tempfile.TemporaryDirectory() as d:
        ds = write_dataset(line, os.path.join(d, "lineitem_ds"), tuned,
                           partition_by="l_shipdate", how="range",
                           fragments=8)
        # warm jits/caches so the trace shows steady-state, not compiles
        q6(ds, prune=True, open_opts={"decode_backend": "host"})

        # trace=<path>: record this run and export Chrome JSON on return
        res, rep = q6(ds, prune=True,
                      open_opts={"decode_backend": "host"},
                      trace=args.out)
        print(f"Q6 = {res:.6f}  wall {rep.measured_wall * 1e3:.2f} ms  "
              f"({rep.trace_events} events recorded)")

    doc = trace_report.load_trace(args.out)
    errors = trace_report.validate_trace(doc)
    assert not errors, errors
    print(trace_report.format_report(trace_report.build_report(doc)))
    print(f"\ntimeline: load {args.out} in chrome://tracing or "
          f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
