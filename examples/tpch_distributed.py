"""Distributed-scan quickstart: fragments sharded across devices.

Builds a 16-fragment range-partitioned lineitem dataset, then runs Q6
through ``run_distributed_scan`` (DESIGN.md §8) three ways:

  1. devices ∈ {1, 2, 4} on the calibrated NVMe sim backend — the
     per-device ScanServices + deterministic tree reduce; every device
     count must agree **bitwise**,
  2. the same sweep on the object-store backend, whose modeled 8 ms
     per-request latency is *slept* — device workers overlap each
     other's remote waits, so wall drops as devices grow,
  3. devices=1 remote with fragment-window prefetch on — the
     prefetcher hides fetch latency behind decode instead.

Run under 4 emulated devices to see real multi-device placement:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/tpch_distributed.py [--sf 0.02]
"""

import argparse
import os
import struct
import tempfile
import time

import jax

from repro.core import ACCELERATOR_OPTIMIZED
from repro.core.query import q6
from repro.data import tpch
from repro.dataset import write_dataset

NVME_OPTS = {"backend": "sim", "decode_backend": "host"}
REMOTE_OPTS = {"backend": "object", "decode_backend": "host"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    args = ap.parse_args()
    line, _ = tpch.generate_tables(sf=args.sf, seed=3,
                                   include_strings=False)
    tuned = ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=max(2_000, line.num_rows // 24),
        target_pages_per_chunk=16)
    print(f"jax devices: {[str(d) for d in jax.devices()]}")

    with tempfile.TemporaryDirectory() as d:
        ds = write_dataset(line, os.path.join(d, "lineitem_ds"), tuned,
                           partition_by="l_shipdate", how="range",
                           fragments=16)
        print(f"dataset: {len(ds.fragments)} fragments, "
              f"{ds.num_rows:,} rows, {ds.stored_bytes / 1e6:.1f} MB")

        # warm decode-plan caches and the jitted consumer on every
        # device (jit compiles per device)
        for n in (1, 2, 4):
            q6(ds, prune=False, devices=n, open_opts=NVME_OPTS)

        print("\n# NVMe sim backend — tree reduce is device-count "
              "independent")
        ref = None
        for n in (1, 2, 4):
            t0 = time.perf_counter()
            r, rep = q6(ds, prune=False, devices=n, open_opts=NVME_OPTS)
            wall = time.perf_counter() - t0
            ref = r if ref is None else ref
            assert struct.pack("<d", r) == struct.pack("<d", ref)
            print(f"  devices={n}  {wall * 1e3:7.2f} ms  "
                  f"fragments/device={rep.device_fragments}  "
                  f"stolen={rep.stolen_fragments}  bit-identical")

        print("\n# object-store backend (8 ms modeled latency, slept) — "
              "devices overlap remote waits")
        base_wall = None
        for n in (1, 2, 4):
            t0 = time.perf_counter()
            r, rep = q6(ds, prune=False, devices=n,
                        open_opts=REMOTE_OPTS)
            wall = time.perf_counter() - t0
            assert struct.pack("<d", r) == struct.pack("<d", ref)
            base_wall = wall if base_wall is None else base_wall
            print(f"  devices={n}  {wall * 1e3:7.2f} ms  "
                  f"({base_wall / wall:4.2f}x vs d1)  "
                  f"io_p95={rep.io_p95_us / 1e3:.1f} ms")

        print("\n# prefetch hides remote latency within one device")
        t0 = time.perf_counter()
        r, rep = q6(ds, prune=False, devices=1,
                    open_opts=dict(REMOTE_OPTS, prefetch=True))
        wall = time.perf_counter() - t0
        assert struct.pack("<d", r) == struct.pack("<d", ref)
        pf_total = rep.prefetch_hidden_seconds + rep.prefetch_stall_seconds
        print(f"  devices=1  {wall * 1e3:7.2f} ms  "
              f"({base_wall / wall:4.2f}x vs prefetch-off)  "
              f"hits={rep.prefetch_hits} misses={rep.prefetch_misses}  "
              f"hidden={100 * rep.prefetch_hidden_seconds / pf_total:.0f}%"
              if pf_total else "  (no prefetchable requests)")


if __name__ == "__main__":
    main()
