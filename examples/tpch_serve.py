"""Multi-tenant serving quickstart: two tenants, one shared ScanService.

Stands up a :class:`QueryFrontEnd` (DESIGN.md §11) over a small TPC-H
lineitem file and serves Q6 for two tenants — ``gold`` at weight 4 and
``bronze`` at weight 1 with a small admission bound — to show the three
serving behaviors in one run:

  * weighted fair shares: both tenants' scans run through the same
    service; under saturation gold gets ~4x bronze's decode slots;
  * admission control: bronze's burst past ``max_active`` lands
    tickets in state ``rejected`` (typed, not an exception storm);
  * the delivered-result window: the repeat round of identical Q6
    scans is served from the window — zero storage requests.

    PYTHONPATH=src python examples/tpch_serve.py [--sf 0.01]
"""

import argparse
import tempfile

from repro.core import ACCELERATOR_OPTIMIZED
from repro.core.query import Q6_COLUMNS
from repro.core.scan import open_scanner
from repro.data import tpch
from repro.serve.engine import QueryFrontEnd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        metas = tpch.write_tpch(
            d, sf=args.sf, seed=7,
            config=ACCELERATOR_OPTIMIZED.replace(rows_per_rg=8_000,
                                                 target_pages_per_chunk=8))
        lpath = metas["lineitem_path"]

        def scanner():
            return open_scanner(lpath, columns=list(Q6_COLUMNS),
                                decode_backend="host")

        with QueryFrontEnd(workers=2) as fe:
            fe.register_tenant("gold", weight=4)
            fe.register_tenant("bronze", weight=1, max_active=2,
                               on_limit="reject")

            # round 1: interleaved submissions from both tenants; the
            # bronze burst exceeds its admission bound of 2
            tickets = []
            for k in range(6):
                tenant = "gold" if k % 2 == 0 else "bronze"
                tickets.append(fe.submit(tenant, "q6", scanner()))
            for tid in tickets:
                try:
                    fe.result(tid)
                except Exception:
                    pass  # rejected tickets re-raise; poll() shows them
            for t in fe.tickets():
                line = f"  {t['id']}  {t['tenant']:<6} {t['state']:<8}"
                if t["state"] == "done":
                    line += f" q6={t['result']:.4f}"
                elif t["error"]:
                    line += f" {t['error']}"
                print(line)
            rejected = sum(t["state"] == "rejected" for t in fe.tickets())
            print(f"round 1: {rejected} bronze submission(s) rejected at "
                  f"max_active=2")

            # round 2: identical repeats — served from the delivered-
            # result window, no storage requests
            sc = scanner()
            tid = fe.submit("gold", "q6", sc)
            res, (rep,) = fe.result(tid)
            print(f"round 2: repeat q6={res:.4f} io_requests="
                  f"{rep.metrics.n_io_requests} "
                  f"window_hits={fe.service.window_hits} "
                  f"(identical scan reused decoded row groups)")
            assert rep.metrics.n_io_requests == 0, \
                "repeat scan should be window-served"


if __name__ == "__main__":
    main()
