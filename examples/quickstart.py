"""Quickstart: the paper in one file.

Generates a TPC-H lineitem TabFile with CPU-era defaults, rewrites it with
the four accelerator-aware insights, and scans both — showing the stored-
size, page-geometry and effective-bandwidth differences (storage lanes are
the calibrated simulator; decode is measured on this host).

    PYTHONPATH=src python examples/quickstart.py [--sf 0.02] [--lanes 4]
"""

import argparse
import os
import tempfile

from repro.core import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TabFileReader,
                        TPU_CASCADE)
from repro.core.query import Q6_COLUMNS, q6
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.data import tpch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    ap.add_argument("--lanes", type=int, default=4)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        print(f"# 1. writing TPC-H sf={args.sf} with CPU-era defaults "
              f"(1 page/chunk, 122880-row RGs, V1 encodings, blind gzip)")
        metas = tpch.write_tpch(d, sf=args.sf, config=CPU_DEFAULT, seed=0,
                                include_strings=False)
        base = metas["lineitem_path"]
        print("  ", TabFileReader(base).meta.describe())

        print("# 2. rewriting with the paper's GPU/TPU-aware config "
              "(100 pages, 1M-row RGs, FLEX V1+V2, selective compression)")
        opt = os.path.join(d, "lineitem.opt.tab")
        rep = rewrite_file(base, opt, ACCELERATOR_OPTIMIZED.replace(
            rows_per_rg=1_000_000), threads=4)
        print(f"   rewrite took {rep.seconds:.2f}s "
              f"({rep.rewrite_bandwidth/1e6:.0f} logical MB/s), "
              f"size x{rep.size_ratio:.3f}")
        print("  ", TabFileReader(opt).meta.describe())

        print(f"# 3. Q6 scan, {args.lanes} simulated NVMe lanes, "
              f"overlapped reader")
        q6(open_scanner(opt, columns=list(Q6_COLUMNS),
                        decode_backend="host"), prune=False)  # warm jits
        for name, path in (("baseline", base), ("optimized", opt)):
            sc = open_scanner(path, columns=list(Q6_COLUMNS),
                              backend="sim", n_lanes=args.lanes,
                              decode_backend="host")
            rev, report = q6(sc, prune=False)
            print(f"   {name:10s} revenue={rev:14.2f} "
                  f"wall={report.modeled_wall*1e3:8.2f} ms "
                  f"effective={report.effective_bandwidth()/1e9:6.2f} GB/s")

        print("# 4. beyond-paper: TPU-native cascade codec "
              "(device-resident decompression)")
        casc = os.path.join(d, "lineitem.cascade.tab")
        rewrite_file(base, casc, TPU_CASCADE.replace(rows_per_rg=1_000_000),
                     threads=4)
        sc = open_scanner(casc, columns=list(Q6_COLUMNS), backend="sim",
                          n_lanes=args.lanes, decode_backend="host")
        rev, report = q6(sc, prune=False)
        print(f"   cascade    revenue={rev:14.2f} "
              f"wall={report.modeled_wall*1e3:8.2f} ms "
              f"effective={report.effective_bandwidth()/1e9:6.2f} GB/s")


if __name__ == "__main__":
    main()
