"""Batched serving demo: length-bucketed scheduler, prefill + greedy
decode against per-layer KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b
    (reduced smoke config of the chosen arch; all non-encoder archs work)
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.batch, max_seq=256)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.choice([16, 16, 32, 48]))   # mixed-length buckets
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = engine.generate(reqs)
    wall = time.perf_counter() - t0
    rep = engine.throughput_report(done)
    print(f"arch={args.arch} (reduced): served {rep['n_requests']} "
          f"requests / {rep['new_tokens']} new tokens in {wall:.2f}s "
          f"-> {rep['decode_tokens_per_s']:.1f} tok/s decode")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: {done[uid].tokens[:12].tolist()} ...")


if __name__ == "__main__":
    main()
