"""Failure model (DESIGN.md §6): deterministic fault injection, checksum
verification, retry/backoff/deadlines, cache-poisoning invariants, and
fragment quarantine.

The acceptance contract these tests pin down:

  * transient faults are retried and heal bit-identically (retries > 0)
  * permanent corruption always surfaces as a typed ``ChecksumError`` or
    a quarantined fragment — never a silently wrong answer
  * a crash mid-compaction leaves the dataset readable at the prior
    manifest generation
"""

import gc
import json
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core.compression import (ChecksumError, chunk_decompress_memo,
                                    set_verify_checksums)
from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.core.faults import (DeadlineExceeded, FaultPlan, FetchTimeout,
                               InjectedDecodeError, InjectedIOError,
                               FaultyStorage, ShortReadError, is_retryable)
from repro.core.overlap import run_overlapped
from repro.core.reader import read_footer
from repro.core.scan import open_scanner
from repro.core.scheduler import ScanService
from repro.core.storage import (NO_RETRY, RealStorage, RetryingStorage,
                                RetryPolicy)
from repro.core.table import Table
from repro.dataset.catalog import Dataset, write_dataset
from repro.dataset.executor import FragmentError, run_dataset_scan
from repro.dataset.planner import plan_dataset_scan
from repro.kernels.dict_decode import dict_cache_clear

CFG = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_500,
                                    target_pages_per_chunk=2)


def _clear_decoded_caches():
    """Corruption tests must start cold: a shared-cache hit legitimately
    never re-reads the corrupt bytes (verify-before-insert keeps the
    caches clean), which is correct behavior but not the path under
    test."""
    chunk_decompress_memo().clear()
    dict_cache_clear()


def _table(n=9_000, seed=0):
    rng = np.random.default_rng(seed)
    return Table({"k": rng.integers(0, 50, n).astype(np.int64),
                  "v": rng.normal(size=n).astype(np.float32)})


@pytest.fixture()
def tab_file(tmp_path):
    from repro.core.writer import write_table
    path = str(tmp_path / "t.tab")
    write_table(_table(), path, CFG)
    return path


def _sum_consume(acc, rg, cols):
    s = float(np.asarray(cols["v"].array[:cols["v"].n_values]).sum())
    return (acc or 0.0) + s


def _scan_sum(path, *, decode_workers=0, service=None, **open_kw):
    sc = open_scanner(path, columns=["v"], **open_kw)
    acc, report = run_overlapped(sc, _sum_consume,
                                 decode_workers=decode_workers,
                                 service=service)
    return acc, report


def _data_page_ranges(path, columns=None):
    """[(offset, size)] of every data page of the selected columns."""
    meta = read_footer(path)
    out = []
    for rg in meta.row_groups:
        for chunk in rg.columns:
            if columns is not None and chunk.name not in columns:
                continue
            for pg in chunk.pages:
                out.append((pg.offset, pg.stored_size))
    return out


# -- taxonomy ---------------------------------------------------------------

def test_retryable_taxonomy():
    assert is_retryable(InjectedIOError(5, "eio"))
    assert is_retryable(OSError(5, "eio"))
    assert is_retryable(ShortReadError(0, 10, 3))
    assert is_retryable(FetchTimeout(0, 10, 0.2, 0.1))
    assert is_retryable(TimeoutError("t"))
    assert is_retryable(ChecksumError("page", 1, 2))
    assert is_retryable(InjectedDecodeError("boom"))
    assert not is_retryable(DeadlineExceeded("budget"))
    assert not is_retryable(RuntimeError("logic bug"))
    assert not is_retryable(ValueError("logic bug"))


# -- FaultPlan determinism --------------------------------------------------

def test_fault_plan_replay_same_seed_same_schedule(tab_file):
    """Same seed -> same failure sequence -> same counters, independent
    of attempt bookkeeping left over from the first run (clone zeroes
    it)."""
    plan = FaultPlan(seed=11, io_error=0.4, bit_flip=0.3, short_read=0.2)
    base = RealStorage(tab_file)
    try:
        reqs = [(o, s) for o, s in _data_page_ranges(tab_file)]
        wrapped = FaultyStorage(base, plan)
        got1 = []
        for o, s in reqs:
            try:
                got1.append(wrapped.fetch(o, s))
            except OSError as e:
                got1.append(repr(e))
        c1 = plan.counters()
        assert sum(c1.values()) > 0    # the rates actually fired

        replay = plan.clone()
        wrapped2 = FaultyStorage(RealStorage(tab_file), replay)
        got2 = []
        for o, s in reqs:
            try:
                got2.append(wrapped2.fetch(o, s))
            except OSError as e:
                got2.append(repr(e))
        assert replay.counters() == c1
        assert got1 == got2            # byte-identical corruption too
    finally:
        base.close()


def test_fault_plan_transient_fires_once_per_target(tab_file):
    plan = FaultPlan(seed=3, io_error=1.0)      # every request, attempt 0
    st_ = FaultyStorage(RealStorage(tab_file), plan)
    with pytest.raises(InjectedIOError):
        st_.fetch(0, 64)
    assert st_.fetch(0, 64) == open(tab_file, "rb").read(64)
    # permanent plans fire on every attempt
    perm = FaultPlan(seed=3, io_error=1.0, transient=False)
    st2 = FaultyStorage(RealStorage(tab_file), perm)
    for _ in range(3):
        with pytest.raises(InjectedIOError):
            st2.fetch(0, 64)


# -- storage retry layer ----------------------------------------------------

def test_retrying_storage_heals_transient_io_error(tab_file):
    plan = FaultPlan(seed=1, io_error=1.0)
    st_ = RetryingStorage(FaultyStorage(RealStorage(tab_file), plan),
                          RetryPolicy(attempts=3, base_delay=0.0))
    assert st_.fetch(8, 32) == open(tab_file, "rb").read(40)[8:]
    assert st_.retry_stats.retries >= 1


def test_retrying_storage_short_read_never_returned(tab_file):
    plan = FaultPlan(seed=2, short_read=1.0)
    st_ = RetryingStorage(FaultyStorage(RealStorage(tab_file), plan),
                          RetryPolicy(attempts=3, base_delay=0.0))
    data = st_.fetch(0, 256)
    assert len(data) == 256
    assert st_.retry_stats.short_reads >= 1


def test_retrying_storage_exhausts_on_permanent_fault(tab_file):
    plan = FaultPlan(seed=1, io_error=1.0, transient=False)
    st_ = RetryingStorage(FaultyStorage(RealStorage(tab_file), plan),
                          RetryPolicy(attempts=3, base_delay=0.0))
    with pytest.raises(InjectedIOError):
        st_.fetch(8, 32)
    assert st_.retry_stats.retries == 2     # budget fully spent


def test_retrying_storage_timeout_budget(tab_file):
    plan = FaultPlan(seed=4, latency=1.0, latency_seconds=0.05)
    st_ = RetryingStorage(FaultyStorage(RealStorage(tab_file), plan),
                          RetryPolicy(attempts=3, base_delay=0.0,
                                      timeout=0.01))
    # first attempt spikes over budget -> FetchTimeout -> retry heals
    assert st_.fetch(0, 64) == open(tab_file, "rb").read(64)
    assert st_.retry_stats.timeouts >= 1


def test_retry_backoff_is_deterministic():
    p = RetryPolicy(attempts=5, base_delay=0.001, max_delay=0.01)
    sched = [p.delay(a, salt=1234) for a in range(4)]
    assert sched == [p.delay(a, salt=1234) for a in range(4)]
    assert all(d >= p.base_delay for d in sched)
    assert max(sched) <= p.max_delay * (1.0 + p.jitter)


# -- checksum verification --------------------------------------------------

def test_bit_flip_on_disk_raises_checksum_error(tab_file):
    acc0, _ = _scan_sum(tab_file)
    off, size = _data_page_ranges(tab_file, columns=["v"])[0]
    raw = open(tab_file, "rb").read()
    b = bytearray(raw)
    b[off + size // 2] ^= 0x10
    open(tab_file, "wb").write(bytes(b))
    _clear_decoded_caches()
    with pytest.raises(ChecksumError):
        _scan_sum(tab_file)
    # restored bytes scan clean again (and the caches were never
    # poisoned by the corrupt attempt — same path, same cache token)
    open(tab_file, "wb").write(raw)
    acc1, _ = _scan_sum(tab_file)
    assert acc1 == acc0


def test_corrupt_footer_raises_checksum_error(tab_file):
    raw = open(tab_file, "rb").read()
    b = bytearray(raw)
    b[-20] ^= 0x01                       # inside footer json / its crc
    open(tab_file, "wb").write(bytes(b))
    _clear_decoded_caches()
    with pytest.raises((ChecksumError, ValueError)):
        read_footer(tab_file)


def test_verification_knob_disables_checks(tab_file):
    off, size = _data_page_ranges(tab_file, columns=["v"])[0]
    b = bytearray(open(tab_file, "rb").read())
    b[off + size // 2] ^= 0x10
    open(tab_file, "wb").write(bytes(b))
    _clear_decoded_caches()
    prev = set_verify_checksums(False)
    try:
        _scan_sum(tab_file)              # may be garbage, must not raise
    except ChecksumError:
        pytest.fail("verification ran while disabled")
    except Exception:
        pass                             # decode of garbage may fail; fine
    finally:
        set_verify_checksums(prev)
        _clear_decoded_caches()


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_property_random_flips_never_silently_wrong(n_flips, seed):
    """Flip N random bytes anywhere in the data pages: every scan either
    raises ChecksumError or returns bit-identical results — never wrong
    data (the zero-silent-wrong-answer acceptance criterion)."""
    import tempfile
    from repro.core.writer import write_table
    with tempfile.TemporaryDirectory(prefix="prop_flip_") as root:
        path = os.path.join(root, "p.tab")
        write_table(_table(4_000, seed=1), path, CFG)
        clean, _ = _scan_sum(path)
        pages = _data_page_ranges(path)
        raw = bytearray(open(path, "rb").read())
        rng = np.random.default_rng(seed)
        for _ in range(n_flips):
            off, size = pages[int(rng.integers(0, len(pages)))]
            pos = off + int(rng.integers(0, size))
            raw[pos] ^= 1 << int(rng.integers(0, 8))
        open(path, "wb").write(bytes(raw))
        _clear_decoded_caches()
        try:
            acc, _ = _scan_sum(path)
        except ChecksumError:
            return                       # typed failure: acceptable
        assert acc == clean, "corruption slipped through undetected"


# -- transient faults heal bit-identically through the scan path ------------

def test_transient_faults_heal_bit_identical_inline(tab_file):
    acc0, _ = _scan_sum(tab_file)
    _clear_decoded_caches()
    plan = FaultPlan(seed=5, io_error=0.4, bit_flip=0.3, decode_error=0.3)
    acc1, rep = _scan_sum(tab_file, fault_plan=plan)
    assert acc1 == acc0
    assert rep.metrics.retries > 0
    assert plan.total_injected() > 0


def test_transient_faults_heal_bit_identical_service(tab_file):
    acc0, _ = _scan_sum(tab_file)
    svc = ScanService(workers=2, adaptive=False)
    try:
        _clear_decoded_caches()
        plan = FaultPlan(seed=6, io_error=0.4, bit_flip=0.3,
                         decode_error=0.3)
        acc1, rep = _scan_sum(tab_file, decode_workers=2, service=svc,
                              fault_plan=plan)
        assert acc1 == acc0
        assert rep.metrics.retries > 0
    finally:
        svc.shutdown()


def test_permanent_decode_fault_fails_scan_not_pool(tab_file, tmp_path):
    """A permanently corrupt scan raises; a concurrent clean scan on the
    same pool and a subsequent scan both stay correct (error isolation +
    no cache poisoning)."""
    from repro.core.writer import write_table
    clean_path = str(tmp_path / "clean.tab")
    write_table(_table(seed=9), clean_path, CFG)
    clean0, _ = _scan_sum(clean_path)
    acc0, _ = _scan_sum(tab_file)

    off, size = _data_page_ranges(tab_file, columns=["v"])[0]
    raw = open(tab_file, "rb").read()
    b = bytearray(raw)
    b[off + size // 2] ^= 0x40
    open(tab_file, "wb").write(bytes(b))

    svc = ScanService(workers=2, adaptive=False)
    try:
        _clear_decoded_caches()
        with pytest.raises(ChecksumError):
            _scan_sum(tab_file, decode_workers=2, service=svc)
        acc_clean, _ = _scan_sum(clean_path, decode_workers=2, service=svc)
        assert acc_clean == clean0
        # the corrupt attempt must not have poisoned shared caches for
        # this path: restore the bytes and rescan the same file
        open(tab_file, "wb").write(raw)
        acc1, _ = _scan_sum(tab_file, decode_workers=2, service=svc)
        assert acc1 == acc0
    finally:
        svc.shutdown()


def test_deadline_exceeded_is_typed_and_final(tab_file):
    plan = FaultPlan(seed=7, latency=1.0, latency_seconds=0.02)
    sc = open_scanner(tab_file, columns=["v"], fault_plan=plan)
    with pytest.raises(DeadlineExceeded):
        run_overlapped(sc, _sum_consume, decode_workers=0,
                       deadline=1e-6)
    svc = ScanService(workers=1, adaptive=False)
    try:
        sc2 = open_scanner(tab_file, columns=["v"], fault_plan=plan.clone())
        with pytest.raises(DeadlineExceeded):
            run_overlapped(sc2, _sum_consume, decode_workers=1,
                           service=svc, deadline=1e-6)
    finally:
        svc.shutdown()


# -- ScanHandle lifecycle ---------------------------------------------------

def test_scan_handle_double_close_idempotent(tab_file):
    svc = ScanService(workers=1, adaptive=False)
    try:
        sc = open_scanner(tab_file, columns=["v"])
        h = svc.submit(sc)
        next(h)
        h.cancel()
        h.cancel()                       # second close: no-op, no raise
        h.close()
        from repro.core.scheduler import ScanCancelled
        with pytest.raises((StopIteration, ScanCancelled)):
            next(h)
    finally:
        svc.shutdown()


def test_abandoned_handle_gc_releases_depth_credits(tab_file):
    """Dropping a handle mid-scan must not leak depth credits: after GC
    the service accepts and completes a fresh scan of the same depth."""
    svc = ScanService(workers=1, adaptive=False)
    try:
        for _ in range(3):               # would wedge by credit leak
            sc = open_scanner(tab_file, columns=["v"])
            h = svc.submit(sc, depth=1)
            next(h)                      # mid-scan: credits held
            del h, sc
            gc.collect()
        acc, _ = _scan_sum(tab_file, decode_workers=1, service=svc)
        acc0, _ = _scan_sum(tab_file)
        assert acc == acc0
    finally:
        svc.shutdown()


# -- dataset layer: quarantine, manifest recovery, orphan sweep -------------

def _mk_dataset(tmp_path, n=12_000):
    return write_dataset(_table(n), str(tmp_path / "ds"), CFG,
                         partition_by="k", how="range", fragments=4)


def _ds_scan(ds, **kw):
    plan = plan_dataset_scan(ds, columns=["v"])
    kw.setdefault("combine", lambda a, b: a + b)
    return run_dataset_scan(plan, _sum_consume, **kw)


def _corrupt_fragment(ds, idx):
    path = ds.fragment_path(ds.fragments[idx])
    meta = read_footer(path)
    chunk = next(c for c in meta.row_groups[0].columns if c.name == "v")
    pg = chunk.pages[0]
    b = bytearray(open(path, "rb").read())
    b[pg.offset + pg.stored_size // 2] ^= 0xFF
    open(path, "wb").write(bytes(b))
    return path


def test_dataset_transient_faults_heal(tmp_path):
    ds = _mk_dataset(tmp_path)
    acc0, rep0 = _ds_scan(ds)
    assert rep0.retries == 0 and rep0.complete
    _clear_decoded_caches()
    plan = FaultPlan(seed=8, io_error=0.3, bit_flip=0.2, decode_error=0.1)
    acc1, rep1 = _ds_scan(ds, open_opts={"fault_plan": plan})
    assert acc1 == acc0
    assert rep1.retries > 0 and rep1.fragments_quarantined == 0
    for key in ("retries=", "checksum_failures=", "timeouts=",
                "fragments_quarantined="):
        assert key in rep1.summary()


def test_dataset_strict_raises_structured_fragment_error(tmp_path):
    ds = _mk_dataset(tmp_path)
    _corrupt_fragment(ds, 1)
    _clear_decoded_caches()
    with pytest.raises(FragmentError) as ei:
        _ds_scan(ds)
    (failure,) = ei.value.failures
    assert failure["index"] == 1
    assert failure["fragment"] == ds.fragments[1].path
    assert failure["error_type"] == "ChecksumError"
    assert failure["attempts"] >= 1


def test_dataset_best_effort_returns_gap_manifest(tmp_path):
    ds = _mk_dataset(tmp_path)
    accs_clean, _ = _ds_scan(ds, combine=None)
    _corrupt_fragment(ds, 2)
    _clear_decoded_caches()
    accs, rep = _ds_scan(ds, combine=None, on_error="best_effort")
    assert rep.fragments_quarantined == 1 and not rep.complete
    assert rep.quarantined[0]["index"] == 2
    assert accs[2] is None               # explicit gap, not a wrong value
    for i in (0, 1, 3):
        assert accs[i] == accs_clean[i]  # other fragments bit-identical


def test_dataset_fragment_level_retry_heals(tmp_path):
    """With the inner layers' retries disabled, a transient fault is
    healed one level up: the whole fragment re-scans on fresh bytes."""
    ds = _mk_dataset(tmp_path)
    acc0, _ = _ds_scan(ds)
    _clear_decoded_caches()
    plan = FaultPlan(seed=9, io_error=1.0)    # every range, first attempt
    acc1, rep = _ds_scan(ds, retries=0,
                         open_opts={"fault_plan": plan,
                                    "retry": NO_RETRY})
    assert acc1 == acc0
    assert rep.retries > 0                    # fragment-level attempts
    assert rep.fragments_quarantined == 0


def test_manifest_recovers_from_prev_generation(tmp_path):
    ds = _mk_dataset(tmp_path)
    gen0 = ds.generation
    ds.generation += 1
    ds.save()                            # writes manifest.prev.json
    raw = open(ds.manifest_path).read()
    open(ds.manifest_path, "w").write(raw[:len(raw) // 2])   # torn write
    recovered = Dataset.open(ds.root)
    assert recovered.recovered_from
    assert recovered.generation == gen0
    # valid JSON with a corrupted field -> crc mismatch -> same recovery
    o = json.loads(raw)
    o["generation"] = 999
    open(ds.manifest_path, "w").write(json.dumps(o))
    recovered = Dataset.load(ds.root)
    assert recovered.recovered_from and recovered.generation == gen0
    # without a recovery candidate the error is typed
    os.remove(os.path.join(ds.root, "manifest.prev.json"))
    with pytest.raises(ChecksumError):
        Dataset.load(ds.root)


def test_open_sweeps_orphans_keeps_old_generations(tmp_path):
    ds = _mk_dataset(tmp_path)
    gen = ds.generation
    stale = os.path.join(ds.root, f"part-99999.g{gen}.tab")
    tmp = os.path.join(ds.root, "manifest.json.tmp.777")
    old = os.path.join(ds.root, "part-99998.g0.tab")
    for p in (stale, tmp, old):
        open(p, "wb").write(b"leftover")
    swept = Dataset.open(ds.root)
    names = set(os.listdir(ds.root))
    assert os.path.basename(stale) not in names   # crashed publication
    assert os.path.basename(tmp) not in names     # interrupted replace
    assert os.path.basename(old) in names         # keep_old input: kept
    assert {f.path for f in swept.fragments} <= names
    acc0, _ = _ds_scan(ds)
    acc1, _ = _ds_scan(swept)
    assert acc1 == acc0


def test_crash_mid_compaction_leaves_prior_generation_readable(tmp_path):
    import repro.dataset.compact as compact_mod
    ds = _mk_dataset(tmp_path)
    acc0, _ = _ds_scan(ds)
    gen0 = ds.generation
    real_writer = compact_mod.TabFileWriter

    class CrashingWriter(real_writer):
        def __init__(self, *a, **kw):
            raise RuntimeError("injected crash mid-compaction")

    compact_mod.TabFileWriter = CrashingWriter
    try:
        with pytest.raises(RuntimeError, match="mid-compaction"):
            compact_mod.compact_dataset(Dataset.load(ds.root))
    finally:
        compact_mod.TabFileWriter = real_writer
    survivor = Dataset.open(ds.root)     # open sweeps any g{gen+1} orphans
    assert survivor.generation == gen0
    assert not any(".tmp" in n for n in os.listdir(ds.root))
    _clear_decoded_caches()
    acc1, _ = _ds_scan(survivor)
    assert acc1 == acc0
