"""Dataset layer (repro.dataset): manifest round-trip, partition and
zone-map file pruning vs brute force, sharded execution determinism,
append/compaction lifecycle, and compaction atomicity."""

import json
import os

import numpy as np
import pytest

from repro.core.config import ACCELERATOR_OPTIMIZED, CPU_DEFAULT
from repro.core.query import (q6, q6_reference, q6_rg_stats_predicate, q12,
                              q12_reference)
from repro.core.reader import read_footer
from repro.data import tpch
from repro.dataset import (Dataset, compact_dataset, plan_compaction,
                           plan_dataset_scan, run_dataset_scan,
                           write_dataset)
from repro.dataset.catalog import file_column_stats

SIM_OPTS = {"backend": "sim", "decode_backend": "host"}
TUNED = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_500,
                                      target_pages_per_chunk=4)


@pytest.fixture(scope="module")
def tables():
    return tpch.generate_tables(sf=0.002, seed=42, include_strings=False)


@pytest.fixture(scope="module")
def range_ds(tables, tmp_path_factory):
    """16 range fragments on l_shipdate — the FY1994 pruning shape."""
    line, _ = tables
    root = str(tmp_path_factory.mktemp("ds_range"))
    return write_dataset(line, root, TUNED, partition_by="l_shipdate",
                         how="range", fragments=16)


def _np_cols(table):
    return {n: np.asarray(table[n]) for n in table.names}


# -- manifest ---------------------------------------------------------------

def test_manifest_round_trip_identical_plan(range_ds):
    loaded = Dataset.load(range_ds.root)
    assert loaded.to_json() == range_ds.to_json()
    p1 = plan_dataset_scan(range_ds,
                           predicate_stats=q6_rg_stats_predicate)
    p2 = plan_dataset_scan(loaded, predicate_stats=q6_rg_stats_predicate)
    assert p1.indices == p2.indices
    assert [f.path for f in p1.fragments] == [f.path for f in p2.fragments]
    assert (p1.pruned_partition, p1.pruned_stats) == \
        (p2.pruned_partition, p2.pruned_stats)


def test_manifest_records_footer_truth(range_ds):
    for frag in range_ds.fragments:
        meta = read_footer(range_ds.fragment_path(frag))
        assert frag.num_rows == meta.num_rows
        assert frag.stored_bytes == meta.stored_bytes
        assert frag.config == meta.writer_config
        assert frag.column_stats == file_column_stats(meta)
        part = frag.partition
        ship = frag.column_stats["l_shipdate"]
        assert part["lo"] == ship["min"] and part["hi"] == ship["max"]


def test_append_table_swaps_manifest_atomically(tables, tmp_path):
    line, _ = tables
    ds = write_dataset(line.slice(0, 2_000), str(tmp_path), TUNED,
                       fragments=2)
    gen0 = ds.generation
    ds.append_table(line.slice(2_000, 4_000), CPU_DEFAULT)
    assert ds.generation == gen0 + 1
    loaded = Dataset.load(ds.root)
    assert len(loaded.fragments) == 3
    assert loaded.num_rows == 4_000
    # the appended fragment carries its own (different) config fingerprint
    assert loaded.fragments[-1].config == CPU_DEFAULT.fingerprint()
    # no stray temp manifest left behind
    assert [f for f in os.listdir(ds.root) if ".tmp." in f] == []


# -- pruning ----------------------------------------------------------------

def test_range_pruning_matches_brute_force(range_ds):
    plan = plan_dataset_scan(range_ds,
                             predicate_stats=q6_rg_stats_predicate)
    # brute force: re-derive file-level stats from every footer
    expect = []
    for i, frag in enumerate(range_ds.fragments):
        stats = file_column_stats(
            read_footer(range_ds.fragment_path(frag)))
        if all(q6_rg_stats_predicate(n, s) for n, s in stats.items()):
            expect.append(i)
    assert sorted(plan.indices) == expect
    # acceptance shape: FY1994 over 16 shipdate-range fragments prunes
    # at least half the files
    assert plan.files_total == 16
    assert plan.files_pruned >= 8
    assert plan.files_scanned == len(plan.fragments) >= 1


def test_pruned_scan_bit_identical_to_full_scan(range_ds, tables):
    line, _ = tables
    pruned, rep = q6(range_ds, prune=True, open_opts=SIM_OPTS)
    full, rep_full = q6(range_ds, prune=False, open_opts=SIM_OPTS)
    assert pruned == full            # bit-identical, not just close
    assert rep.files_pruned >= 8
    assert rep_full.files_pruned == 0
    assert rep_full.n_row_groups > rep.n_row_groups
    ref = q6_reference(_np_cols(line))
    assert pruned == pytest.approx(ref, rel=1e-4)


def test_dataset_scan_deterministic_across_runs(range_ds):
    a, _ = q6(range_ds, prune=True, open_opts=SIM_OPTS)
    b, _ = q6(range_ds, prune=True, open_opts=SIM_OPTS)
    assert a == b                    # plan-order reduce, not thread order


def test_sharded_matches_sequential_fragment_loop(range_ds):
    plan = plan_dataset_scan(range_ds,
                             predicate_stats=q6_rg_stats_predicate)
    sharded, _ = q6(range_ds, prune=True, open_opts=SIM_OPTS, window=4)
    seq = None
    for frag in plan.fragments:
        sc = range_ds.open_fragment(frag, columns=plan.columns
                                    or ["l_shipdate", "l_discount",
                                        "l_quantity", "l_extendedprice"],
                                    **SIM_OPTS)
        acc, _ = q6(sc, prune=True)
        seq = acc if seq is None else seq + acc
    assert sharded == seq


def test_zone_map_pruning_without_partitioning(tables, tmp_path):
    """File-level zone maps prune even unpartitioned datasets when the
    data arrives roughly ordered (contiguous slices of a sorted table)."""
    line, _ = tables
    order = np.argsort(np.asarray(line["l_shipdate"]), kind="stable")
    cols = {n: (np.asarray(line[n])[order]) for n in line.names}
    from repro.core.table import Table
    sorted_line = Table(cols, line.schema)
    ds = write_dataset(sorted_line, str(tmp_path), TUNED, fragments=8)
    plan = plan_dataset_scan(ds, predicate_stats=q6_rg_stats_predicate)
    assert plan.pruned_partition == 0      # no partition metadata
    assert plan.pruned_stats >= 4          # zone maps carry the pruning
    pruned, _ = q6(ds, prune=True, open_opts=SIM_OPTS)
    full, _ = q6(ds, prune=False, open_opts=SIM_OPTS)
    assert pruned == full


def test_hash_partition_equality_pruning(tables, tmp_path):
    line, _ = tables
    ds = write_dataset(line, str(tmp_path), TUNED,
                       partition_by="l_orderkey", how="hash", fragments=8)
    assert ds.num_rows == line.num_rows    # no rows lost in bucketing
    key = int(np.asarray(line["l_orderkey"])[17])
    bucket = int(ds.partitioning.bucket_of([key])[0])
    plan = plan_dataset_scan(
        ds, partition_filter=lambda p: p is not None
        and p.get("bucket") == bucket)
    assert plan.files_scanned == 1
    assert plan.pruned_partition == 7
    # the key's rows all live in the surviving fragment
    sc = ds.open_fragment(plan.fragments[0], columns=["l_orderkey"],
                          decode_backend="host")
    got = np.concatenate([np.asarray(c["l_orderkey"].array)
                          for _, c in sc.scan()])
    want = np.asarray(line["l_orderkey"])
    assert (got == key).sum() == (want == key).sum() > 0


# -- executor ---------------------------------------------------------------

def test_run_dataset_scan_reports_merged_metrics(range_ds):
    plan = plan_dataset_scan(range_ds, columns=["l_shipdate"],
                             predicate_stats=q6_rg_stats_predicate)
    accs, rep = run_dataset_scan(
        plan, lambda acc, i, cols: (acc or 0) + cols["l_shipdate"].array
        .shape[0], combine=None, window=2, open_opts=SIM_OPTS)
    assert rep.files_total == 16
    assert rep.files_scanned == len(plan.fragments)
    assert rep.window == 2
    assert len(accs) == len(plan.fragments)
    assert rep.n_io_requests > 0
    assert rep.n_row_groups == sum(r.metrics.n_row_groups
                                   for r in rep.reports)
    assert sum(a for a in accs if a) == sum(f.num_rows
                                            for f in plan.fragments)
    assert rep.wall_percentile(95) >= rep.wall_percentile(50) >= 0.0
    assert "scanned=" in rep.summary()


def test_run_dataset_scan_propagates_errors(range_ds):
    plan = plan_dataset_scan(range_ds, columns=["l_shipdate"])

    def boom(acc, i, cols):
        raise RuntimeError("consume failed")

    with pytest.raises(RuntimeError, match="consume failed"):
        run_dataset_scan(plan, boom, window=3, open_opts=SIM_OPTS)


def test_q12_over_datasets(tables, tmp_path):
    line, orders = tables
    lds = write_dataset(line, str(tmp_path / "l"), TUNED,
                        partition_by="l_shipdate", how="range",
                        fragments=6)
    ods = write_dataset(orders, str(tmp_path / "o"), TUNED, fragments=3)
    res, brep, prep = q12(lds, ods, open_opts=SIM_OPTS)
    assert res == q12_reference(_np_cols(line), _np_cols(orders))
    assert prep.files_scanned == 6 and brep.files_scanned == 3


# -- compaction -------------------------------------------------------------

@pytest.fixture
def raw_ds(tables, tmp_path):
    """Misconfigured ingest shape: 12 tiny CPU-default fragments."""
    line, _ = tables
    return write_dataset(line, str(tmp_path / "raw"),
                         CPU_DEFAULT.replace(rows_per_rg=400),
                         partition_by="l_shipdate", how="range",
                         fragments=12)


def test_plan_compaction_flags_misconfigured_and_small(raw_ds):
    plan = plan_compaction(raw_ds, target_config=TUNED)
    assert set(plan.reasons.values()) == {"misconfigured"}
    assert plan.n_inputs == 12
    assert plan.n_outputs < 12          # neighbors merged …
    assert plan.n_outputs > 1           # … but capped, pruning survives
    # a fragment already at the target config but tiny is "small"
    tuned_tiny = write_dataset(
        tpch.generate_tables(sf=0.0001, seed=3,
                             include_strings=False)[0],
        raw_ds.root + "_tiny", TUNED, fragments=1)
    plan2 = plan_compaction(tuned_tiny, target_config=TUNED)
    assert plan2.reasons == {0: "small"}


def test_compaction_preserves_results_and_pruning(raw_ds, tables):
    line, _ = tables
    before, _ = q6(raw_ds, open_opts=SIM_OPTS)
    old_paths = [raw_ds.fragment_path(f) for f in raw_ds.fragments]
    ds, rep = compact_dataset(raw_ds, target_config=TUNED)
    assert rep.n_inputs == 12 and rep.n_outputs == len(ds.fragments)
    assert rep.rows == line.num_rows
    for f in ds.fragments:
        assert f.config == TUNED.fingerprint()
    assert all(not os.path.exists(p) for p in old_paths)  # gc after swap
    after, arep = q6(Dataset.load(ds.root), open_opts=SIM_OPTS)
    ref = q6_reference(_np_cols(line))
    # row-group boundaries moved, so accumulation order differs: equal to
    # the oracle, not bitwise to the pre-compaction sum
    assert after == pytest.approx(ref, rel=1e-4)
    assert before == pytest.approx(ref, rel=1e-4)
    assert arep.files_pruned > 0       # range metadata survived the merge


def test_compaction_atomicity_on_failure(raw_ds, monkeypatch):
    manifest_before = json.load(open(raw_ds.manifest_path))
    files_before = sorted(os.listdir(raw_ds.root))
    result_before, _ = q6(Dataset.load(raw_ds.root), open_opts=SIM_OPTS)

    import repro.dataset.compact as compact_mod
    calls = {"n": 0}
    real = compact_mod._merge_rewrite

    def flaky(paths, dst, config, threads):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("disk full")
        return real(paths, dst, config, threads)

    monkeypatch.setattr(compact_mod, "_merge_rewrite", flaky)
    with pytest.raises(OSError, match="disk full"):
        compact_dataset(raw_ds, target_config=TUNED)
    # the manifest never changed and the partial outputs were removed
    assert json.load(open(raw_ds.manifest_path)) == manifest_before
    assert sorted(os.listdir(raw_ds.root)) == files_before
    result_after, _ = q6(Dataset.load(raw_ds.root), open_opts=SIM_OPTS)
    assert result_after == result_before


def test_compaction_noop_when_already_tuned(tables, tmp_path):
    line, _ = tables
    ds = write_dataset(line, str(tmp_path), TUNED,
                       partition_by="l_shipdate", how="range", fragments=4)
    gen = ds.generation
    ds2, rep = compact_dataset(ds, target_config=TUNED)
    assert rep.n_inputs == 0 and rep.n_outputs == 0
    assert ds2.generation == gen       # no manifest swap on a no-op


# -- review regressions ------------------------------------------------------

def test_dataset_rejects_blocking_mode(range_ds):
    with pytest.raises(ValueError, match="always sharded"):
        q6(range_ds, overlapped=False, open_opts=SIM_OPTS)


def test_partitioning_rejects_string_keys(tmp_path):
    from repro.core.table import StringColumn, Table
    cols = {"k": StringColumn.from_pylist(["a", "b", "c"]),
            "v": np.arange(3, dtype=np.int32)}
    t = Table(cols)
    with pytest.raises(TypeError, match="numeric key"):
        write_dataset(t, str(tmp_path / "s"), TUNED, partition_by="k",
                      how="hash", fragments=2)
    with pytest.raises(TypeError, match="numeric key"):
        write_dataset(t, str(tmp_path / "s2"), TUNED, partition_by="k",
                      how="range", fragments=2)


def test_compaction_failure_removes_partial_output(raw_ds, monkeypatch):
    """A rewrite that dies MID-WRITE (partial bytes on disk) must still
    leave the dataset directory exactly as it was."""
    files_before = sorted(os.listdir(raw_ds.root))

    import repro.dataset.compact as compact_mod
    real = compact_mod._merge_rewrite
    calls = {"n": 0}

    def mid_write_fault(paths, dst, config, threads):
        calls["n"] += 1
        if calls["n"] == 2:
            with open(dst, "wb") as f:      # partial bytes hit the disk
                f.write(b"TABF0001partial")
            raise OSError("disk full mid-write")
        return real(paths, dst, config, threads)

    monkeypatch.setattr(compact_mod, "_merge_rewrite", mid_write_fault)
    with pytest.raises(OSError, match="disk full"):
        compact_dataset(raw_ds, target_config=TUNED)
    assert sorted(os.listdir(raw_ds.root)) == files_before
