"""Flight recorder + metrics registry (core/trace.py, DESIGN.md §10):
off-by-default, bounded buffers, span well-formedness under concurrent
scans, reconciliation of traced spans against ScanMetrics, bit-identity
with tracing on vs off on the fused and unfused paths, backend-aware
retry-policy defaults, and tools/trace_report.py's bucket attribution."""

import json
import os
import sys
import threading

import numpy as np
import pytest

from repro.core import trace
from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.core.overlap import run_blocking, run_overlapped
from repro.core.query import Q6_COLUMNS, q6
from repro.core.scan import Scanner, open_scanner
from repro.core.storage import (DEFAULT_RETRY_POLICY, NO_RETRY,
                                OBJECT_RETRY_POLICY, ObjectStoreStorage,
                                SimulatedStorage, backend_retry_policy)
from repro.core.table import Table
from repro.core.writer import write_table

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_report  # noqa: E402

CFG = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_500,
                                    target_pages_per_chunk=2)


@pytest.fixture(autouse=True)
def _clean_trace_state():
    """Every test starts and ends with the recorder off and the env
    unresolved — tracing state is process-global."""
    trace.reset()
    yield
    trace.reset()


def _table(n=9_000, seed=0):
    rng = np.random.default_rng(seed)
    return Table({"k": rng.integers(0, 50, n).astype(np.int64),
                  "v": rng.normal(size=n).astype(np.float32)})


@pytest.fixture()
def tab_file(tmp_path):
    path = str(tmp_path / "t.tab")
    write_table(_table(), path, CFG)
    return path


def _sum_consume(acc, rg, cols):
    s = float(np.asarray(cols["v"].array[:cols["v"].n_values]).sum())
    return (acc or 0.0) + s


# -- enablement --------------------------------------------------------------

def test_off_by_default(tab_file, monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    trace.reset()
    assert trace.active() is None
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2)
    assert trace.active() is None
    assert rep.metrics.trace_events == 0
    assert rep.metrics.registry_snapshot == {}


def test_env_var_enables(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    trace.reset()
    assert trace.active() is not None
    monkeypatch.setenv("REPRO_TRACE", "0")
    trace.reset()
    assert trace.active() is None


def test_enable_disable_idempotent():
    tr = trace.enable()
    assert trace.enable() is tr          # idempotent
    assert trace.active() is tr
    trace.disable()
    assert trace.active() is None
    tr.complete("late", "io", 0.0, 1.0)  # held reference stays usable
    assert tr.event_count() == 1


def test_request_context_enables_and_exports(tab_file, tmp_path):
    out = str(tmp_path / "run.json")
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2,
                            trace=out)
    assert trace.active() is None        # last request turned it off
    assert rep.metrics.trace_events > 0
    doc = trace_report.load_trace(out)
    assert trace_report.validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"fetch", "consume", "scan"} <= names


def test_request_none_is_noop(tab_file):
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2,
                            trace=None)
    assert trace.active() is None
    assert rep.metrics.trace_events == 0


# -- bounded buffers ---------------------------------------------------------

def test_global_cap_bounds_and_counts_drops():
    tr = trace.Tracer(cap=32)
    for i in range(100):
        tr.instant("e", "io", i=i)
    assert tr.event_count() == 32
    assert tr.dropped == 68
    assert tr.to_chrome()["otherData"]["dropped"] == 68


def test_per_scan_cap_protects_other_scans():
    tr = trace.Tracer(cap=64)            # scan_cap = 32
    for _ in range(50):
        tr.instant("e", "io", scan="chatty")
    assert tr.dropped_by_scan["chatty"] == 50 - tr.scan_cap
    tr.instant("e", "io", scan="quiet")  # still admitted
    by_scan = [e.args.get("scan") for e in tr.events()]
    assert by_scan.count("chatty") == tr.scan_cap
    assert by_scan.count("quiet") == 1


def test_clear_resets_buffer_and_drops():
    tr = trace.Tracer(cap=16)
    for _ in range(40):
        tr.instant("e", "io", scan="s")
    tr.clear()
    assert tr.event_count() == 0
    assert tr.dropped == 0
    tr.instant("e", "io", scan="s")
    assert tr.event_count() == 1


# -- metrics registry --------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = trace.MetricsRegistry()
    reg.counter_inc("a")
    reg.counter_inc("a", 4)
    reg.gauge_set("g", 7)
    for v in (1.0, 3.0, 2.0):
        reg.observe("h", v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["g"] == 7
    h = snap["histograms"]["h"]
    assert (h["count"], h["sum"], h["min"], h["max"]) == (3, 6.0, 1.0, 3.0)
    assert h["mean"] == pytest.approx(2.0)
    reg.clear()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_registry_snapshot_lands_in_scan_metrics(tab_file):
    trace.enable()
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2)
    snap = rep.metrics.registry_snapshot
    assert "scheduler.fetch_wall_s" in snap["histograms"]
    assert snap["histograms"]["scheduler.fetch_wall_s"]["count"] \
        == rep.metrics.n_row_groups


# -- reconciliation: traced spans vs ScanMetrics -----------------------------

def _spans(tr, name):
    return [e for e in tr.events() if e.name == name and e.ph == "X"]


def test_reconciliation_service_path(tab_file):
    tr = trace.enable()
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2)
    m = rep.metrics
    # the fetch span carries the same io_dt float appended to io_per_rg
    fetched = sorted(e.args["io_dt"] for e in _spans(tr, "fetch"))
    assert fetched == sorted(m.io_per_rg)
    # decode items' durations ARE the chunk_times floats -> per-RG sums
    # reconcile with decode_per_rg (fp accumulation order may differ)
    per_rg: dict[int, float] = {}
    for e in tr.events():
        if e.cat == "decode" and e.ph == "X":
            per_rg[e.args["rg"]] = per_rg.get(e.args["rg"], 0.0) + e.dur
    assert len(per_rg) == m.n_row_groups
    for dec, rg in zip(m.decode_per_rg, sorted(per_rg)):
        assert per_rg[rg] == pytest.approx(dec, rel=1e-9, abs=1e-12)
    # consume spans share their stamps with consume_seconds exactly
    assert sum(e.dur for e in _spans(tr, "consume")) \
        == pytest.approx(m.consume_seconds, rel=1e-9)
    # the whole-run span IS the measured wall
    (scan_span,) = _spans(tr, "scan")
    assert scan_span.dur == pytest.approx(rep.measured_wall, rel=1e-9)
    assert scan_span.args["mode"] == "overlapped"
    assert m.trace_events == tr.event_count()


def test_reconciliation_blocking_path(tab_file):
    tr = trace.enable()
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_blocking(sc, _sum_consume)
    m = rep.metrics
    assert sorted(e.args["io_dt"] for e in _spans(tr, "fetch")) \
        == sorted(m.io_per_rg)
    # decode_rg spans bracket scanner.decode_rg: their sum is the decode
    # stage wall (host-measured), within accumulation tolerance
    assert sum(e.dur for e in _spans(tr, "decode_rg")) \
        == pytest.approx(m.decode_wall_seconds, rel=1e-9)
    (scan_span,) = _spans(tr, "scan")
    assert scan_span.args["mode"] == "blocking"
    assert scan_span.dur == pytest.approx(rep.measured_wall, rel=1e-9)


def test_reconciliation_inline_path(tab_file):
    tr = trace.enable()
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=0)
    (scan_span,) = _spans(tr, "scan")
    assert scan_span.args["mode"] == "overlapped-inline"
    assert scan_span.dur == pytest.approx(rep.measured_wall, rel=1e-9)
    assert sum(e.dur for e in _spans(tr, "decode_rg")) \
        == pytest.approx(rep.metrics.decode_wall_seconds, rel=1e-9)


# -- well-formedness under concurrency ---------------------------------------

def test_spans_well_formed_under_concurrent_scans(tmp_path):
    paths = []
    for k in range(3):
        p = str(tmp_path / f"t{k}.tab")
        write_table(_table(seed=k), p, CFG)
        paths.append(p)
    tr = trace.enable()
    errors: list[BaseException] = []

    def one(p):
        try:
            sc = open_scanner(p, columns=["v"], decode_backend="host")
            run_overlapped(sc, _sum_consume, decode_workers=2)
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=one, args=(p,)) for p in paths]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    events = tr.events()
    assert all(e.ts >= 0 and e.dur >= 0 for e in events)
    assert all(e.ph in ("X", "i") for e in events)
    # one balanced whole-run span per scan, each attributing its file
    scans = [e for e in events if e.name == "scan"]
    assert sorted(e.args["scan"] for e in scans) == sorted(paths)
    # the export round-trips through the validator cleanly
    doc = tr.to_chrome()
    assert trace_report.validate_trace(doc) == []


def test_chrome_event_format():
    tr = trace.Tracer()
    tr.complete("s", "io", tr.epoch + 0.001, tr.epoch + 0.003, rg=1)
    tr.instant("i", "fault")
    doc = tr.to_chrome()
    span, inst = doc["traceEvents"]
    assert span["ph"] == "X"
    assert span["dur"] == pytest.approx(2_000.0)   # µs
    assert span["ts"] == pytest.approx(1_000.0)
    assert span["args"] == {"rg": 1}
    assert inst["ph"] == "i" and inst["s"] == "t"
    assert doc["displayTimeUnit"] == "ms"
    assert "registry" in doc["otherData"]


# -- bit-identity: tracing must not change results ---------------------------

@pytest.mark.parametrize("fused", [False, True])
def test_bit_identity_tracing_on_off(tmp_path_factory, fused):
    d = tmp_path_factory.mktemp("trace_q6")
    from repro.data import tpch
    tpch.write_tpch(str(d), sf=0.002, config=CFG, seed=5)
    path = str(d / "lineitem.tab")

    def run():
        sc = open_scanner(path, columns=Q6_COLUMNS,
                          decode_backend="host")
        return q6(sc, overlapped=True, decode_workers=2, fused=fused)

    res_off, rep_off = run()
    tr = trace.enable()
    res_on, rep_on = run()
    trace.disable()
    assert np.float64(res_on).tobytes() == np.float64(res_off).tobytes()
    assert rep_on.metrics.n_io_requests == rep_off.metrics.n_io_requests
    assert rep_on.metrics.trace_events > 0
    assert rep_off.metrics.trace_events == 0
    if fused:
        # the fused stage records its phase-3 items under the recorder
        names = {e.name for e in tr.events()}
        assert "fused" in names or "decode" in names


# -- backend-aware retry-policy defaults (satellite: object-store) -----------

def test_backend_retry_policy_profiles():
    assert backend_retry_policy("object") is OBJECT_RETRY_POLICY
    assert backend_retry_policy("real") is DEFAULT_RETRY_POLICY
    assert backend_retry_policy("sim") is DEFAULT_RETRY_POLICY
    assert OBJECT_RETRY_POLICY.name == "object"
    assert DEFAULT_RETRY_POLICY.name == "nvme"
    assert NO_RETRY.name == "none"
    # object-store profile: more attempts, longer backoff, wider budget
    assert OBJECT_RETRY_POLICY.attempts > DEFAULT_RETRY_POLICY.attempts
    assert OBJECT_RETRY_POLICY.base_delay > DEFAULT_RETRY_POLICY.base_delay
    assert OBJECT_RETRY_POLICY.timeout > (DEFAULT_RETRY_POLICY.timeout
                                          or 0.0)


def test_scanner_defaults_retry_policy_by_backend(tab_file):
    sc_nvme = Scanner(tab_file, columns=["v"],
                      storage=SimulatedStorage(tab_file))
    assert sc_nvme.retry.name == "nvme"
    sc_obj = Scanner(tab_file, columns=["v"],
                     storage=ObjectStoreStorage(tab_file))
    assert sc_obj.retry.name == "object"
    assert sc_obj.retry.attempts == OBJECT_RETRY_POLICY.attempts
    explicit = Scanner(tab_file, columns=["v"],
                       storage=ObjectStoreStorage(tab_file),
                       retry=NO_RETRY)
    assert explicit.retry.name == "none"


def test_retry_policy_name_lands_in_metrics(tab_file):
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2)
    assert rep.metrics.retry_policy == "nvme"
    sc2 = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep2 = run_blocking(sc2, _sum_consume)
    assert rep2.metrics.retry_policy == "nvme"


# -- trace_report ------------------------------------------------------------

def _synthetic_doc():
    """scan 0-100ms; fetch 0-20; decode 10-50; consume 50-80 →
    fetch 10ms, decode 40ms, consume 30ms, stall 20ms."""
    tr = trace.Tracer()
    e = tr.epoch
    tr.complete("scan", "scan", e, e + 0.100, scan="s")
    tr.complete("fetch", "io", e, e + 0.020, scan="s", rg=0, io_dt=0.02)
    tr.complete("decode", "decode", e + 0.010, e + 0.050, scan="s", rg=0)
    tr.complete("consume", "consume", e + 0.050, e + 0.080, scan="s",
                rg=0, logical_bytes=1_000_000)
    return tr.to_chrome()


def test_trace_report_bucket_attribution_partitions_wall():
    rep = trace_report.build_report(_synthetic_doc())
    b = rep["buckets_us"]
    assert rep["wall_us"] == pytest.approx(100_000.0, rel=1e-6)
    assert b["fetch"] == pytest.approx(10_000.0, rel=1e-6)
    assert b["decode"] == pytest.approx(40_000.0, rel=1e-6)
    assert b["consume"] == pytest.approx(30_000.0, rel=1e-6)
    assert b["stall"] == pytest.approx(20_000.0, rel=1e-6)
    assert sum(b.values()) == pytest.approx(rep["wall_us"], rel=1e-9)
    assert rep["bottleneck"] == "decode"


def test_trace_report_critical_path_and_bandwidth():
    rep = trace_report.build_report(_synthetic_doc())
    longest = rep["critical_path"]["longest"]
    assert longest["rg"] == 0
    assert longest["total"] == pytest.approx(20_000 + 40_000 + 30_000,
                                             rel=1e-6)
    bw = rep["bandwidth"]
    assert bw["logical_bytes"] == 1_000_000
    assert bw["effective_bw_mbps"] == pytest.approx(10.0, rel=1e-3)


def test_trace_report_validator_rejects_malformed():
    assert trace_report.validate_trace({"traceEvents": "nope"})
    bad_dur = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0, "dur": -5, "pid": 1, "tid": 1}],
        "displayTimeUnit": "ms"}
    assert any("dur" in e for e in trace_report.validate_trace(bad_dur))
    unbalanced = {"traceEvents": [
        {"name": "b", "ph": "B", "ts": 0, "pid": 1, "tid": 1}],
        "displayTimeUnit": "ms"}
    assert any("unclosed" in e
               for e in trace_report.validate_trace(unbalanced))
    bad_ph = {"traceEvents": [
        {"name": "x", "ph": "Q", "ts": 0, "pid": 1, "tid": 1}],
        "displayTimeUnit": "ms"}
    assert any("ph" in e for e in trace_report.validate_trace(bad_ph))


def test_trace_report_on_real_export(tab_file, tmp_path):
    out = str(tmp_path / "real.json")
    tr = trace.enable()
    sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
    _, rep = run_overlapped(sc, _sum_consume, decode_workers=2)
    tr.export(out)
    trace.disable()
    doc = trace_report.load_trace(out)
    assert trace_report.validate_trace(doc) == []
    r = trace_report.build_report(doc)
    assert r["wall_us"] == pytest.approx(rep.measured_wall * 1e6,
                                         rel=0.10)
    assert r["bottleneck"] in ("fetch", "decompress", "decode",
                               "consume", "stall")
    assert sum(r["buckets_us"].values()) \
        == pytest.approx(r["wall_us"], rel=1e-6)
    assert r["dropped"] == 0
    with open(out, encoding="utf-8") as f:
        assert json.load(f)["displayTimeUnit"] == "ms"


# -- multi-tenant attribution (DESIGN.md §11) --------------------------------

def _tenant_doc():
    """gold fetches 0-20ms and decodes 20-60ms (one window hit); the
    shared ``-`` tenant consumes 60-90ms."""
    tr = trace.Tracer()
    e = tr.epoch
    tr.complete("scan", "scan", e, e + 0.100, scan="s", tenant="gold")
    tr.complete("fetch", "io", e, e + 0.020, scan="s", rg=0,
                io_dt=0.02, tenant="gold")
    tr.complete("decode", "decode", e + 0.020, e + 0.060, scan="s",
                rg=0, tenant="gold")
    tr.instant("window_hit", "io", scan="s", rg=1, tenant="gold")
    tr.complete("consume", "consume", e + 0.060, e + 0.090, scan="s",
                rg=0, logical_bytes=1)
    return tr.to_chrome()


def test_trace_report_per_tenant_breakdown():
    rep = trace_report.build_report(_tenant_doc())
    per = rep["per_tenant"]
    assert set(per) == {"gold", "-"}
    gold = per["gold"]
    assert gold["fetch"] == pytest.approx(20_000.0, rel=1e-6)
    assert gold["decode"] == pytest.approx(40_000.0, rel=1e-6)
    assert gold["busy_us"] == pytest.approx(60_000.0, rel=1e-6)
    assert gold["spans"] == 2          # the structural scan span is not
    assert gold["window_hits"] == 1    # a bucketed work span
    shared = per["-"]
    assert shared["consume"] == pytest.approx(30_000.0, rel=1e-6)
    assert shared["busy_us"] == pytest.approx(30_000.0, rel=1e-6)
    assert shared["window_hits"] == 0
    text = trace_report.format_report(rep)
    assert "tenant gold" in text
    assert "1 window hits" in text


def test_trace_report_per_tenant_absent_without_tenants():
    rep = trace_report.build_report(_synthetic_doc())
    # untagged runs collapse onto the shared tenant and the human
    # report omits the breakdown entirely
    assert set(rep["per_tenant"]) <= {"-"}
    assert "tenant" not in trace_report.format_report(rep)


def test_tenant_tagged_spans_and_depth_gauge_live(tab_file):
    from repro.core.scheduler import ScanService
    tr = trace.enable()
    svc = ScanService(workers=2)
    svc.register_tenant("gold", weight=4, max_active=2)
    try:
        sc = open_scanner(tab_file, columns=["v"], decode_backend="host")
        _, rep = run_overlapped(sc, _sum_consume, decode_workers=2,
                                service=svc, tenant="gold")
    finally:
        svc.shutdown()
    fetches = _spans(tr, "fetch")
    assert fetches and all(e.args.get("tenant") == "gold"
                           for e in fetches)
    (scan_span,) = _spans(tr, "scan")
    assert scan_span.args["tenant"] == "gold"
    # the queue-depth gauge exists and reads 0 once the scan released
    # its admission slot
    gauges = trace.registry().snapshot()["gauges"]
    assert gauges.get("scheduler.tenant_depth.gold") == 0
    per = trace_report.build_report(tr.to_chrome())["per_tenant"]
    assert per["gold"]["spans"] > 0
    assert per["gold"]["busy_us"] > 0
    assert rep.metrics.trace_events > 0


def test_result_cache_hit_instant_and_counter(tmp_path):
    from repro.dataset.result_cache import MISS, FragmentResultCache
    tr = trace.enable()
    before = trace.registry().snapshot()["counters"]
    cache = FragmentResultCache()
    cache.put("root", 0, "f0", "fp", 1.5)
    assert cache.get("root", 0, "f0", "fp") == 1.5
    assert cache.get("root", 0, "f1", "fp") is MISS
    after = trace.registry().snapshot()["counters"]
    assert after.get("result_cache.hits", 0) \
        - before.get("result_cache.hits", 0) == 1
    assert after.get("result_cache.misses", 0) \
        - before.get("result_cache.misses", 0) == 1
    hits = [e for e in tr.events() if e.name == "result_cache_hit"]
    assert len(hits) == 1 and hits[0].args["fragment"] == "f0"


# -- dataset layer -----------------------------------------------------------

def test_dataset_scan_trace_kwarg(tmp_path):
    from repro.dataset import plan_dataset_scan, write_dataset
    from repro.dataset.executor import run_dataset_scan
    line = _table(n=6_000, seed=3)
    ds = write_dataset(line, str(tmp_path / "ds"), CFG,
                       partition_by="k", how="range", fragments=2)
    plan = plan_dataset_scan(ds, columns=["v"])
    out = str(tmp_path / "ds.json")
    _, rep = run_dataset_scan(
        plan, _sum_consume, lambda a, b: a + b, window=2,
        open_opts={"decode_backend": "host"}, trace=out)
    assert trace.active() is None
    assert rep.trace_events > 0
    assert rep.registry_snapshot
    doc = trace_report.load_trace(out)
    assert trace_report.validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"fragment", "dataset_scan"} <= names
