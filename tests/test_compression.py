import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core.compression import (Codec, cascade_compress,
                                    cascade_decompress, cascade_manifest,
                                    compress, decompress,
                                    maybe_compress_chunk)


@pytest.mark.parametrize("codec", ["gzip", "cascade"])
def test_roundtrip(codec):
    rng = np.random.default_rng(0)
    data = rng.integers(0, 5, 4096, dtype=np.uint32).tobytes()
    comp = compress(data, codec)
    out = decompress(comp, {"gzip": Codec.GZIP,
                            "cascade": Codec.CASCADE}[codec], len(data))
    assert out == data


def test_cascade_compresses_runs():
    data = np.repeat(np.arange(8, dtype=np.uint32), 4096).tobytes()
    comp = cascade_compress(data)
    assert len(comp) < len(data) / 100
    assert cascade_decompress(comp, len(data)) == data


def test_cascade_unaligned_tail():
    data = b"\x01\x02\x03"  # not word aligned
    comp = cascade_compress(data)
    assert cascade_decompress(comp, 3) == data


def test_cascade_manifest_fields():
    data = np.repeat(np.uint32(7), 1000).tobytes()
    man = cascade_manifest(cascade_compress(data))
    assert man["n_words"] == 1000
    assert man["n_runs"] == 1
    assert man["value_words"].dtype == np.uint32


def test_insight4_gate_skips_incompressible():
    """Insight 4: random pages stay uncompressed at min_gain=0.1."""
    rng = np.random.default_rng(1)
    pages = [rng.integers(0, 2 ** 32, 4096, dtype=np.uint32).tobytes()]
    codec, stored, un, st_ = maybe_compress_chunk(pages, "gzip", 0.10)
    assert codec == Codec.NONE
    assert stored[0] == pages[0]
    # and blind compression (min_gain=0) keeps gzip even when useless
    codec, stored, _, _ = maybe_compress_chunk(pages, "gzip", 0.0)
    assert codec in (Codec.GZIP, Codec.NONE)


def test_insight4_gate_keeps_compressible():
    pages = [b"\x00" * 100_000]
    codec, stored, un, st_ = maybe_compress_chunk(pages, "gzip", 0.10)
    assert codec == Codec.GZIP
    assert st_ < un / 100


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=0, max_size=2000))
def test_cascade_property(data):
    comp = cascade_compress(data)
    assert cascade_decompress(comp, len(data)) == data


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=500),
       st.integers(1, 30))
def test_cascade_runs_property(vals, repeat):
    data = np.repeat(np.array(vals, np.uint32), repeat).tobytes()
    assert cascade_decompress(cascade_compress(data), len(data)) == data
