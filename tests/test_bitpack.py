import numpy as np
import numpy.testing as npt
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core import bitpack


@pytest.mark.parametrize("width", [1, 2, 3, 5, 8, 13, 17, 24, 31, 32, 40,
                                   64])
def test_roundtrip_widths(width):
    rng = np.random.default_rng(width)
    hi = 2 ** min(width, 63)
    vals = rng.integers(0, hi, size=777, dtype=np.uint64)
    if width < 64:
        vals &= (1 << width) - 1
    words = bitpack.pack(vals, width)
    assert words.shape[0] == bitpack.packed_words(777, width)
    out = bitpack.unpack(words, width, 777)
    npt.assert_array_equal(out, vals)


def test_bit_width():
    assert bitpack.bit_width(0) == 1
    assert bitpack.bit_width(1) == 1
    assert bitpack.bit_width(2) == 2
    assert bitpack.bit_width(255) == 8
    assert bitpack.bit_width(256) == 9
    with pytest.raises(ValueError):
        bitpack.bit_width(-1)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2 ** 20 - 1), min_size=0, max_size=300),
       st.integers(20, 32))
def test_roundtrip_property(values, width):
    vals = np.array(values, dtype=np.uint64)
    out = bitpack.unpack(bitpack.pack(vals, width), width, len(values))
    npt.assert_array_equal(out, vals)


def test_group_padding_is_zero():
    vals = np.array([3], dtype=np.uint64)  # one value, 31 pad slots
    words = bitpack.pack(vals, 2)
    out = bitpack.unpack(words, 2, 32)
    assert out[0] == 3
    assert np.all(out[1:] == 0)
