"""Scan engine, overlap executor, storage model, Q6/Q12 integration."""

import numpy as np
import pytest

from repro.core import ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TPU_CASCADE
from repro.core.overlap import run_blocking, run_overlapped
from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                              Q6_COLUMNS, q6, q6_reference, q12,
                              q12_reference)
from repro.core.scan import open_scanner
from repro.core.storage import SimulatedStorage
from repro.data import tpch


@pytest.fixture(scope="module")
def tpch_files(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch")
    metas = tpch.write_tpch(str(d), sf=0.004,
                            config=ACCELERATOR_OPTIMIZED.replace(
                                rows_per_rg=8_000,
                                target_pages_per_chunk=10),
                            seed=21)
    line, orders = tpch.generate_tables(sf=0.004, seed=21)
    return metas, line, orders


@pytest.mark.parametrize("decode_backend", ["host", "pallas"])
def test_scan_matches_table(tpch_files, decode_backend):
    metas, line, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=["l_quantity",
                                                       "l_orderkey"],
                      decode_backend=decode_backend)
    got_q, got_k = [], []
    for _, cols in sc.scan():
        got_q.append(np.asarray(cols["l_quantity"].array))
        got_k.append(np.asarray(cols["l_orderkey"].array))
    np.testing.assert_array_equal(np.concatenate(got_q),
                                  np.asarray(line["l_quantity"]))
    np.testing.assert_array_equal(
        np.concatenate(got_k).astype(np.int64),
        np.asarray(line["l_orderkey"]))


def test_effective_bandwidth_accounting(tpch_files):
    metas, line, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      backend="sim", n_lanes=2, decode_backend="host")
    _, m = sc.scan_with_metrics()
    assert m.logical_bytes == sum(
        np.asarray(line[c]).nbytes for c in Q6_COLUMNS)
    assert m.stored_bytes < m.logical_bytes        # encodings help
    assert m.compression_ratio > 1.0
    assert m.overlapped_seconds <= m.blocking_seconds + 1e-9


def test_blocking_vs_overlapped_same_result(tpch_files):
    metas, _, _ = tpch_files
    sc1 = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                       decode_backend="host")
    sc2 = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                       decode_backend="host")
    r1, rep1 = q6(sc1, overlapped=False)
    r2, rep2 = q6(sc2, overlapped=True)
    assert abs(r1 - r2) < 1e-6 * max(1.0, abs(r1))
    assert rep2.modeled_wall <= rep1.modeled_wall + 1e-9


def test_q6_against_reference(tpch_files):
    metas, line, _ = tpch_files
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      decode_backend="host")
    got, _ = q6(sc)
    assert abs(got - ref) / max(1.0, abs(ref)) < 1e-5


def test_q6_kernel_path(tpch_files):
    metas, line, _ = tpch_files
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      decode_backend="pallas")
    got, _ = q6(sc, use_kernel=True)
    assert abs(got - ref) / max(1.0, abs(ref)) < 1e-4


def test_q6_pruning_safe(tpch_files):
    metas, line, _ = tpch_files
    sc1 = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                       decode_backend="host")
    sc2 = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                       decode_backend="host")
    with_prune, rep_p = q6(sc1, prune=True)
    without, rep_n = q6(sc2, prune=False)
    assert abs(with_prune - without) < 1e-6 * max(1.0, abs(without))
    assert rep_p.metrics.n_row_groups <= rep_n.metrics.n_row_groups


def test_q12_against_reference(tpch_files):
    metas, line, orders = tpch_files
    ref = q12_reference(
        {c: np.asarray(line[c]) for c in Q12_LINEITEM_COLUMNS},
        {c: np.asarray(orders[c]) for c in Q12_ORDERS_COLUMNS})
    lsc = open_scanner(metas["lineitem_path"],
                       columns=Q12_LINEITEM_COLUMNS, decode_backend="host")
    osc = open_scanner(metas["orders_path"], columns=Q12_ORDERS_COLUMNS,
                       decode_backend="host")
    got, _, _ = q12(lsc, osc)
    assert got == ref


def test_cascade_file_scans(tmp_path, tpch_files):
    _, line, _ = tpch_files
    from repro.core import write_table
    path = str(tmp_path / "casc.tab")
    write_table(line.select(Q6_COLUMNS), path,
                TPU_CASCADE.replace(rows_per_rg=10_000,
                                    target_pages_per_chunk=8))
    sc = open_scanner(path, columns=Q6_COLUMNS, decode_backend="pallas")
    got, _ = q6(sc)
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    assert abs(got - ref) / max(1.0, abs(ref)) < 1e-5


# -- storage model -----------------------------------------------------------

def test_sim_lane_scaling(tpch_files):
    metas, _, _ = tpch_files
    sizes = [1_000_000] * 8
    t1 = SimulatedStorage(metas["lineitem_path"],
                          n_lanes=1).batch_seconds(sizes)
    t4 = SimulatedStorage(metas["lineitem_path"],
                          n_lanes=4).batch_seconds(sizes)
    assert t1 / t4 == pytest.approx(4.0, rel=0.05)


def test_sim_small_io_penalty(tpch_files):
    """Insight 2: same bytes in small requests → lower bandwidth."""
    metas, _, _ = tpch_files
    s = SimulatedStorage(metas["lineitem_path"], n_lanes=1)
    big = s.batch_seconds([10_000_000])
    small = s.batch_seconds([100_000] * 100)
    assert small > big * 1.5
    assert s.effective_bandwidth(100_000) < 0.5 * s.lane_bandwidth
    assert s.effective_bandwidth(50_000_000) > 0.95 * s.lane_bandwidth


def test_overlap_error_propagates(tpch_files):
    metas, _, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      decode_backend="host")

    def bad_consume(acc, i, cols):
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        run_overlapped(sc, bad_consume)
