"""Multi-tenant front end (DESIGN.md §11): weighted fair scheduling,
admission control, the delivered-result window, the fragment result
cache, and the session API.

The fairness contract is pinned by a **deterministic scheduler
simulation**: a synthetic clock + event heap drives the *real*
``ScanService`` state machine (``_next_fetch_locked`` /
``_next_item_locked`` / ``_run_item``) single-threaded with scripted
fetch/decode durations, so dispatch-share ratios and starvation bounds
are exact properties of the scheduler — never timing flakes.

The acceptance contract:

  * a weight-4 tenant receives ~4x the row-group dispatches of a
    weight-1 tenant under saturation (within 15%), and the weight-1
    tenant never starves (bounded gap between its dispatches)
  * randomized weights / arrival orders keep shares proportional and
    delivery bit-identical to the sequential plan order (property
    tests, real hypothesis or the deterministic fallback shim)
  * over-limit submits reject with a typed error or queue until a slot
    frees, per the tenant's ``on_limit``
  * a late-arriving identical scan is served from the delivered-result
    window with strictly fewer io_requests, bit-identically; clearing
    the window restores the cold fetch count exactly
  * fragment-result-cache entries die with the manifest generation
    (swap/compaction) and survive a crash mid-compaction
"""

import heapq
import itertools
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core import scheduler as sched
from repro.core import trace
from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.core.query import Q6_COLUMNS, q6
from repro.core.scan import open_scanner
from repro.core.scheduler import (AdmissionRejected, ScanService, Tenant,
                                  clear_delivered_windows)
from repro.core.table import Table
from repro.data import tpch
from repro.dataset.catalog import Dataset, write_dataset
from repro.dataset.executor import run_dataset_scan
from repro.dataset.planner import plan_dataset_scan
from repro.dataset.result_cache import (MISS, FragmentResultCache,
                                        clear_all_result_caches)
from repro.serve.engine import QueryFrontEnd

CFG = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_500,
                                    target_pages_per_chunk=2)


# ---------------------------------------------------------------------------
# deterministic scheduler simulation
# ---------------------------------------------------------------------------

class _StubScanner:
    """Minimal scanner for the sim: ``plan`` → n row groups, instant
    fetch/decode (the sim's scripted durations model the time).  No
    ``planner`` attribute → ``share_key`` is None, so cooperative
    sharing and the delivered-result window never trigger — fairness is
    measured on real dispatches only."""

    def __init__(self, n_rgs: int):
        self.n_rgs = n_rgs

    def plan(self, predicate_stats=None, row_groups=None):
        return list(range(self.n_rgs))

    def fetch_rg(self, rg):
        return ("raw", rg), 0.0

    def decode_rg(self, rg, raws):
        return {"rg": rg}, 0.0


class _NoThreadService(ScanService):
    """A ScanService that never spawns threads: the sim driver IS the
    fetch pool and the decode pool."""

    def _ensure_threads_locked(self):
        pass

    def _spawn_to_target_locked(self):
        pass


class _Sim:
    """Single-threaded deterministic executor of the ScanService state
    machine.  One fetch slot and ``slots`` decode slots; every fetch
    takes ``fetch_dt`` synthetic seconds and every decode item
    ``dec_dt``; completions pop off an event heap in (time, insertion)
    order, so two runs of the same script are identical.

    The driver replicates ``_fetch_loop``'s post-fetch registration
    (build the _RgJob, queue its "open" item) and drains each handle
    only when its next in-order seq is already delivered — no
    condition-variable waits, no real time anywhere."""

    def __init__(self, svc: _NoThreadService, fetch_dt: float = 0.05,
                 dec_dt: float = 1.0, slots: int = 3):
        self.svc = svc
        self.fetch_dt = fetch_dt
        self.dec_dt = dec_dt
        self.slots = slots
        self.clock = 0.0
        self.heap: list[tuple] = []
        self._ctr = itertools.count()
        self.fetch_busy = False
        self.busy = 0
        self.handles: list[tuple] = []
        self.delivered: dict[str, list[int]] = {}
        #: (synthetic time, tenant name) per row-group "open" dispatch
        self.dispatch_log: list[tuple[float, str]] = []

    def submit(self, n_rgs: int, tenant: str | None, label: str,
               depth: int = 8):
        h = self.svc.submit(_StubScanner(n_rgs), tenant=tenant,
                            label=label, depth=depth)
        self.handles.append((h, label))
        self.delivered[label] = []
        return h

    def _push(self, dt: float, kind: str, payload):
        heapq.heappush(self.heap,
                       (self.clock + dt, next(self._ctr), kind, payload))

    def _try_fetch(self):
        while not self.fetch_busy:
            got = self.svc._next_fetch_locked()
            if got is None:
                return
            scan, seq, subscribed, _is_retry = got
            if subscribed:
                continue
            self.fetch_busy = True
            self._push(self.fetch_dt, "fetch", (scan, seq))

    def _fetch_done(self, scan, seq):
        self.fetch_busy = False
        if scan.dead:
            return
        raws, io_dt = scan.scanner.fetch_rg(scan.plan[seq])
        rgjob = sched._RgJob(scan, seq, scan.plan[seq], raws, io_dt, None)
        scan.ready.append(("open", rgjob, None))

    def _try_dispatch(self):
        while self.busy < self.slots:
            got = self.svc._next_item_locked(None)
            if got is None:
                return
            scan, item = got
            self.busy += 1
            if item[0] == "open":
                name = (scan.tenant.name if scan.tenant is not None
                        else "-")
                self.dispatch_log.append((self.clock, name))
            self._push(self.dec_dt, "item", (scan, item))

    def _item_done(self, scan, item):
        self.busy -= 1
        self.svc._run_item(scan, item)

    def _drain(self):
        for h, label in self.handles:
            scan = h._scan
            while not scan.finished:
                if h._next_seq >= len(scan.plan):
                    try:
                        next(h)
                    except StopIteration:
                        pass
                    break
                if h._next_seq in scan.done:
                    rg = next(h)[0]
                    self.delivered[label].append(rg)
                else:
                    break

    def _step(self):
        self._drain()
        self._try_fetch()
        self._try_dispatch()
        self._drain()

    def run(self, stop_after_dispatches: int | None = None,
            max_events: int = 500_000):
        self._step()
        n = 0
        while self.heap:
            n += 1
            assert n < max_events, "sim did not converge"
            t, _, kind, payload = heapq.heappop(self.heap)
            self.clock = t
            if kind == "fetch":
                self._fetch_done(*payload)
            else:
                self._item_done(*payload)
            self._step()
            if (stop_after_dispatches is not None
                    and len(self.dispatch_log) >= stop_after_dispatches):
                return


def _shares(log, first_n=None):
    counts: dict[str, int] = {}
    for _, name in (log if first_n is None else log[:first_n]):
        counts[name] = counts.get(name, 0) + 1
    return counts


def _max_gap(log, name):
    """Largest number of consecutive dispatches NOT won by ``name``."""
    gap = worst = 0
    for _, n in log:
        if n == name:
            worst = max(worst, gap)
            gap = 0
        else:
            gap += 1
    return worst


def test_sim_two_tenants_4_to_1_within_15pct():
    svc = _NoThreadService(workers=1, adaptive=False)
    svc.register_tenant("gold", weight=4)
    svc.register_tenant("bronze", weight=1)
    sim = _Sim(svc)
    sim.submit(200, "gold", "g0")
    sim.submit(200, "bronze", "b0")
    sim.run(stop_after_dispatches=150)
    counts = _shares(sim.dispatch_log, 150)
    ratio = counts["gold"] / counts["bronze"]
    assert 4 * 0.85 <= ratio <= 4 * 1.15, counts
    # starvation-freedom: bronze keeps landing dispatches throughout —
    # stride bounds the gap near sum(weights); 12 is generous
    assert _max_gap(sim.dispatch_log[:150], "bronze") <= 12
    # run to completion: every row group of both scans delivers in plan
    # order (bit-identical to a sequential run of each scan)
    sim.run()
    assert sim.delivered["g0"] == list(range(200))
    assert sim.delivered["b0"] == list(range(200))
    assert svc.tenant("gold").dispatches == 200
    assert svc.tenant("bronze").dispatches == 200
    assert svc.active_scans == 0


def test_sim_multi_scan_tenants_share_by_weight_not_scan_count():
    # bronze runs TWO scans, gold one: shares follow tenant weights, not
    # per-scan round-robin (2 scans must not double bronze's share)
    svc = _NoThreadService(workers=1, adaptive=False)
    svc.register_tenant("gold", weight=3)
    svc.register_tenant("bronze", weight=1)
    sim = _Sim(svc)
    sim.submit(200, "gold", "g0")
    sim.submit(150, "bronze", "b0")
    sim.submit(150, "bronze", "b1")
    sim.run(stop_after_dispatches=160)
    counts = _shares(sim.dispatch_log, 160)
    ratio = counts["gold"] / counts["bronze"]
    assert 3 * 0.8 <= ratio <= 3 * 1.2, counts
    sim.run()
    assert sim.delivered["b0"] == list(range(150))
    assert sim.delivered["b1"] == list(range(150))


def test_sim_idle_tenant_rejoins_without_burst():
    # bronze registered up front but submits late: its virtual time
    # re-syncs to the active minimum on admission, so banked idleness
    # never becomes a catch-up burst over gold
    svc = _NoThreadService(workers=1, adaptive=False)
    svc.register_tenant("gold", weight=4)
    svc.register_tenant("bronze", weight=1)
    sim = _Sim(svc)
    sim.submit(400, "gold", "g0")
    sim.run(stop_after_dispatches=80)       # gold runs alone for a while
    before = len(sim.dispatch_log)
    sim.submit(200, "bronze", "b0")
    sim.run(stop_after_dispatches=before + 60)
    window = sim.dispatch_log[before:before + 60]
    bronze_share = sum(1 for _, n in window if n == "bronze") / len(window)
    # fair share is 1/5 = 0.2; a burst would spike well above it
    assert bronze_share <= 0.35, bronze_share
    assert bronze_share > 0.0
    for h, _ in sim.handles:
        h.cancel()
    svc.shutdown()


def test_sim_untenanted_scans_ride_as_shared_weight1_tenant():
    svc = _NoThreadService(workers=1, adaptive=False)
    svc.register_tenant("gold", weight=2)
    sim = _Sim(svc)
    sim.submit(150, "gold", "g0")
    sim.submit(150, None, "u0")             # untenanted sibling
    sim.run(stop_after_dispatches=120)
    counts = _shares(sim.dispatch_log, 120)
    ratio = counts["gold"] / counts["-"]
    assert 2 * 0.8 <= ratio <= 2 * 1.2, counts
    sim.run()
    assert sim.delivered["u0"] == list(range(150))


@settings(max_examples=8)
@given(st.lists(st.integers(min_value=1, max_value=8),
                min_size=2, max_size=4),
       st.integers(min_value=0, max_value=10_000))
def test_property_shares_track_weights_any_arrival_order(weights,
                                                         order_seed):
    svc = _NoThreadService(workers=1, adaptive=False)
    names = [f"t{i}" for i in range(len(weights))]
    for name, w in zip(names, weights):
        svc.register_tenant(name, weight=w)
    order = list(range(len(weights)))
    np.random.default_rng(order_seed).shuffle(order)
    sim = _Sim(svc)
    n_rgs = 220
    for i in order:                          # randomized arrival order
        sim.submit(n_rgs, names[i], f"s{i}")
    total_w = sum(weights)
    n_obs = 200
    sim.run(stop_after_dispatches=n_obs)
    counts = _shares(sim.dispatch_log, n_obs)
    for name, w in zip(names, weights):
        got = counts.get(name, 0)
        expect = n_obs * w / total_w
        assert abs(got - expect) <= max(4, 0.25 * expect), \
            (weights, order, counts)
        # starvation-freedom under arbitrary weights
        assert got > 0
    assert _max_gap(sim.dispatch_log[:n_obs], names[weights.index(
        min(weights))]) <= 4 * total_w + 8
    # bit-identical to sequential: every scan's delivery IS its plan order
    sim.run()
    for i in range(len(weights)):
        assert sim.delivered[f"s{i}"] == list(range(n_rgs))


# ---------------------------------------------------------------------------
# admission control (real service)
# ---------------------------------------------------------------------------

def test_admission_reject_and_release():
    svc = ScanService(workers=1, adaptive=False)
    try:
        svc.register_tenant("bronze", weight=1, max_active=1,
                            on_limit="reject")
        reg = trace.registry()
        rejects0 = reg.snapshot()["counters"].get(
            "scheduler.admission_rejects", 0)
        h1 = svc.submit(_StubScanner(64), tenant="bronze", depth=1)
        with pytest.raises(AdmissionRejected):
            svc.submit(_StubScanner(4), tenant="bronze")
        assert (reg.snapshot()["counters"]["scheduler.admission_rejects"]
                == rejects0 + 1)
        assert svc.tenant("bronze").active == 1
        h1.cancel()
        assert svc.tenant("bronze").active == 0
        h2 = svc.submit(_StubScanner(4), tenant="bronze")  # slot freed
        for _ in h2:
            pass
    finally:
        svc.shutdown()


def test_admission_queue_blocks_until_slot_frees():
    svc = ScanService(workers=1, adaptive=False)
    try:
        svc.register_tenant("q", weight=1, max_active=1, on_limit="queue")
        h1 = svc.submit(_StubScanner(64), tenant="q", depth=1)
        admitted = []

        def second():
            h2 = svc.submit(_StubScanner(4), tenant="q")
            admitted.append(h2)

        t = threading.Thread(target=second, daemon=True)
        t.start()
        time.sleep(0.3)
        assert t.is_alive() and not admitted     # still queued
        h1.cancel()                              # frees the slot
        t.join(timeout=5.0)
        assert admitted, "queued submit was never admitted"
        for _ in admitted[0]:
            pass
        assert svc.tenant("q").active == 0
    finally:
        svc.shutdown()


def test_admission_unknown_tenant_auto_registers_weight1():
    svc = ScanService(workers=1, adaptive=False)
    try:
        h = svc.submit(_StubScanner(4), tenant="newcomer")
        ten = svc.tenant("newcomer")
        assert (ten.weight, ten.max_active) == (1, None)
        for _ in h:
            pass
        assert ten.dispatches == 4
    finally:
        svc.shutdown()


def test_tenant_validation():
    with pytest.raises(ValueError):
        Tenant("bad", weight=0)
    with pytest.raises(ValueError):
        Tenant("bad", on_limit="drop")
    svc = ScanService(workers=1)
    try:
        svc.register_tenant("a", weight=2)
        svc.register_tenant("a", weight=5)       # re-configure in place
        assert svc.tenant("a").weight == 5
    finally:
        svc.shutdown()


def test_slo_miss_boosts_pool_policy():
    svc = ScanService(workers=1, adaptive=True, resize_every=1,
                      max_workers=4)
    try:
        svc.register_tenant("slo", weight=1, slo_s=1e-9)  # always missed
        h1 = svc.submit(_StubScanner(4), tenant="slo")
        for _ in h1:                              # records a latency ≫ slo
            pass
        h2 = svc.submit(_StubScanner(8), tenant="slo")
        for _ in h2:                              # resizes see the miss
            pass
        snap = trace.registry().snapshot()["counters"]
        assert snap.get("scheduler.slo_boosts", 0) >= 1
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# delivered-result window (real service, real files)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_tpch(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_tenancy")
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=4_000,
                                        target_pages_per_chunk=8)
    return tpch.write_tpch(str(d), sf=0.004, config=cfg, seed=77)


def _q6_scanner(metas):
    return open_scanner(metas["lineitem_path"], columns=list(Q6_COLUMNS),
                        decode_backend="host")


def test_window_serves_repeat_scan_with_fewer_io_requests(small_tpch):
    svc = ScanService(workers=2, window_bytes=64 << 20)
    try:
        a1, r1 = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                    tenant="gold", decode_workers=2)
        a2, r2 = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                    tenant="gold", decode_workers=2)
        assert a2 == a1                              # bit-identical
        assert r2.metrics.n_io_requests < r1.metrics.n_io_requests
        assert r2.metrics.n_io_requests == 0         # fully window-served
        assert svc.window_hits > 0
        assert svc.window_entries > 0
        # cold-ladder contract: clearing the window restores the exact
        # cold fetch count (and stays bit-identical)
        clear_delivered_windows()
        assert svc.window_entries == 0
        a3, r3 = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                    tenant="gold", decode_workers=2)
        assert a3 == a1
        assert r3.metrics.n_io_requests == r1.metrics.n_io_requests
    finally:
        svc.shutdown()


def test_window_off_by_default_keeps_cold_io_counts(small_tpch):
    svc = ScanService(workers=2)                     # window_bytes=0
    try:
        _, r1 = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                   decode_workers=2)
        _, r2 = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                   decode_workers=2)
        assert r2.metrics.n_io_requests == r1.metrics.n_io_requests
        assert svc.window_hits == 0
    finally:
        svc.shutdown()


def test_concurrent_tenants_bit_identical_to_sequential(small_tpch):
    a_ref, _ = q6(_q6_scanner(small_tpch), prune=False, decode_workers=1)
    svc = ScanService(workers=2, window_bytes=0)
    try:
        svc.register_tenant("gold", weight=4)
        svc.register_tenant("bronze", weight=1)
        out: dict[str, float] = {}

        def run(tenant):
            acc, _ = q6(_q6_scanner(small_tpch), prune=False, service=svc,
                        tenant=tenant, decode_workers=2)
            out[tenant] = acc

        ts = [threading.Thread(target=run, args=(t,), daemon=True)
              for t in ("gold", "bronze", "gold", "bronze")]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60)
        assert out["gold"] == a_ref and out["bronze"] == a_ref
        assert svc.tenant("gold").dispatches >= 0  # charged via fair path
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# fragment result cache
# ---------------------------------------------------------------------------

def _table(n=9_000, seed=0):
    rng = np.random.default_rng(seed)
    return Table({"k": rng.integers(0, 50, n).astype(np.int64),
                  "v": rng.normal(size=n).astype(np.float32)})


def _mk_dataset(tmp_path, n=9_000):
    return write_dataset(_table(n), str(tmp_path / "ds"), CFG,
                         partition_by="k", how="range", fragments=4)


def _sum_consume(acc, rg, cols):
    s = float(np.asarray(cols["v"].array[:cols["v"].n_values]).sum())
    return (acc or 0.0) + s


def _ds_scan(ds, **kw):
    plan = plan_dataset_scan(ds, columns=["v"])
    kw.setdefault("combine", lambda a, b: a + b)
    return run_dataset_scan(plan, _sum_consume, **kw)


def test_result_cache_repeat_scan_hits_all_fragments(tmp_path):
    ds = _mk_dataset(tmp_path)
    cache = FragmentResultCache()
    acc1, rep1 = _ds_scan(ds, result_cache=cache, fingerprint="sum:v")
    assert rep1.result_cache_hits == 0
    assert len(cache) == len(ds.fragments)
    acc2, rep2 = _ds_scan(ds, result_cache=cache, fingerprint="sum:v")
    assert acc2 == acc1                              # bit-identical
    assert rep2.result_cache_hits == len(ds.fragments)
    assert rep2.n_io_requests == 0                   # nothing refetched
    assert cache.hits == len(ds.fragments)
    # a different predicate fingerprint never aliases
    acc3, rep3 = _ds_scan(ds, result_cache=cache, fingerprint="sum:v2")
    assert rep3.result_cache_hits == 0 and acc3 == acc1
    assert "result_cache_hits=4" in rep2.summary()


def test_result_cache_invalidated_on_manifest_swap(tmp_path):
    ds = _mk_dataset(tmp_path)
    cache = FragmentResultCache()
    acc1, _ = _ds_scan(ds, result_cache=cache, fingerprint="sum:v")
    assert len(cache) == 4
    ds.generation += 1                               # manifest swap
    ds.save()
    assert len(cache) == 0 and cache.invalidated == 4
    acc2, rep2 = _ds_scan(Dataset.load(ds.root), result_cache=cache,
                          fingerprint="sum:v")
    assert rep2.result_cache_hits == 0 and acc2 == acc1


def test_result_cache_invalidated_by_compaction(tmp_path):
    import repro.dataset.compact as compact_mod
    ds = _mk_dataset(tmp_path)
    cache = FragmentResultCache()
    acc1, _ = _ds_scan(ds, result_cache=cache, fingerprint="sum:v")
    gen0 = ds.generation
    compacted, _rep = compact_mod.compact_dataset(ds)
    if compacted.generation == gen0:
        pytest.skip("compaction plan was empty")
    # stale-generation entries died with the swap; the compacted layout
    # recomputes and stays bit-identical
    assert all(k[1] == compacted.generation for k in cache._entries)
    acc2, rep2 = _ds_scan(compacted, result_cache=cache,
                          fingerprint="sum:v")
    assert acc2 == pytest.approx(acc1, rel=1e-6)
    assert rep2.result_cache_hits == 0 or acc2 == acc1


def test_result_cache_survives_crash_mid_compaction(tmp_path):
    import repro.dataset.compact as compact_mod
    ds = _mk_dataset(tmp_path)
    cache = FragmentResultCache()
    acc1, _ = _ds_scan(ds, result_cache=cache, fingerprint="sum:v")
    assert len(cache) == 4
    real_writer = compact_mod.TabFileWriter

    class CrashingWriter(real_writer):
        def __init__(self, *a, **kw):
            raise RuntimeError("injected crash mid-compaction")

    compact_mod.TabFileWriter = CrashingWriter
    try:
        with pytest.raises(RuntimeError, match="mid-compaction"):
            compact_mod.compact_dataset(Dataset.load(ds.root))
    finally:
        compact_mod.TabFileWriter = real_writer
    # the manifest never swapped: every cached result is still valid
    assert len(cache) == 4 and cache.invalidated == 0
    survivor = Dataset.open(ds.root)
    acc2, rep2 = _ds_scan(survivor, result_cache=cache,
                          fingerprint="sum:v")
    assert acc2 == acc1
    assert rep2.result_cache_hits == 4


def test_result_cache_lru_cap_and_clear(tmp_path):
    cache = FragmentResultCache(max_entries=2)
    cache.put("/r", 1, "f0", "p", 10.0)
    cache.put("/r", 1, "f1", "p", 11.0)
    cache.put("/r", 1, "f2", "p", 12.0)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get("/r", 1, "f0", "p") is MISS     # LRU-evicted
    assert cache.get("/r", 1, "f2", "p") == 12.0
    clear_all_result_caches()
    assert len(cache) == 0


def test_q6_dataset_routes_through_result_cache(tmp_path):
    line, _orders = tpch.generate_tables(sf=0.004, seed=77)
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=4_000,
                                        target_pages_per_chunk=8)
    ds = write_dataset(line, str(tmp_path / "li_ds"), cfg,
                       partition_by="l_shipdate", how="range", fragments=3)
    cache = FragmentResultCache()
    a1, r1 = q6(ds, result_cache=cache, tenant="gold")
    a2, r2 = q6(ds, result_cache=cache, tenant="gold")
    assert a2 == a1
    assert r2.result_cache_hits > 0
    assert len(cache) > 0


# ---------------------------------------------------------------------------
# session API (serve/engine.py)
# ---------------------------------------------------------------------------

def test_frontend_submit_poll_result_round_trip(small_tpch):
    a_ref, _ = q6(_q6_scanner(small_tpch), prune=False, decode_workers=1)
    with QueryFrontEnd(workers=2) as fe:
        fe.register_tenant("gold", weight=4)
        fe.register_tenant("bronze", weight=1)
        t1 = fe.submit("gold", "q6", _q6_scanner(small_tpch), prune=False,
                       decode_workers=2)
        t2 = fe.submit("bronze", "q6", _q6_scanner(small_tpch),
                       prune=False, decode_workers=2)
        acc1, reports1 = fe.result(t1, timeout=60)
        acc2, _ = fe.result(t2, timeout=60)
        assert acc1 == a_ref and acc2 == a_ref
        assert len(reports1) == 1
        st1 = fe.poll(t1)
        assert st1["state"] == "done" and st1["tenant"] == "gold"
        assert st1["wall_s"] >= 0.0
        assert {t["id"] for t in fe.tickets("gold")} == {t1}
        # the repeat arrived after the first finished: the front end's
        # delivered-result window served it (strictly fewer io_requests)
        assert reports1[0].metrics.n_io_requests >= 0
        assert fe.service.window_hits > 0 or fe.service.shared_rgs > 0


def test_frontend_rejected_ticket(small_tpch):
    with QueryFrontEnd(workers=1) as fe:
        fe.register_tenant("full", weight=1, max_active=0,
                           on_limit="reject")
        tid = fe.submit("full", "q6", _q6_scanner(small_tpch),
                        prune=False)
        with pytest.raises(AdmissionRejected):
            fe.result(tid, timeout=30)
        assert fe.poll(tid)["state"] == "rejected"
        assert "AdmissionRejected" in fe.poll(tid)["error"]


def test_frontend_cancel_discards_result(small_tpch):
    with QueryFrontEnd(workers=1) as fe:
        tid = fe.submit("gold", "q6", _q6_scanner(small_tpch),
                        prune=False)
        if fe.cancel(tid):
            assert fe.poll(tid)["state"] == "cancelled"
            with pytest.raises(RuntimeError):
                fe.result(tid, timeout=30)
        else:                      # query already finished — still done
            assert fe.poll(tid)["state"] == "done"


def test_frontend_rejects_unknown_query(small_tpch):
    with QueryFrontEnd(workers=1) as fe:
        with pytest.raises(ValueError):
            fe.submit("gold", "q99", None)
        with pytest.raises(KeyError):
            fe.poll("t999")
