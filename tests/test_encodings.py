import numpy as np
import numpy.testing as npt
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core import encodings as enc
from repro.core.config import EncodingPolicy, FileConfig
from repro.core.schema import Field, PhysicalType
from repro.core.table import StringColumn


def _field(pt):
    return Field("c", pt)


def _roundtrip_page(encoding, values, field):
    page = enc.encode_chunk_with(encoding, values, field,
                                 [(0, len(values) if isinstance(
                                     values, StringColumn)
                                   else values.shape[0])])
    assert page is not None
    dict_vals = None
    if page.dict_page is not None:
        dict_vals = enc.decode_plain_page(
            page.dict_page.payload, page.dict_page.n_values, field,
            page.dict_page.extra)
    out = enc.decode_page(page.encoding, page.pages[0].payload,
                          page.pages[0].n_values, field,
                          page.pages[0].extra, dict_vals)
    return out


@pytest.mark.parametrize("dtype,pt", [
    (np.int32, PhysicalType.INT32), (np.int64, PhysicalType.INT64)])
def test_delta_roundtrip(dtype, pt):
    rng = np.random.default_rng(0)
    vals = np.cumsum(rng.integers(-5, 100, 5000)).astype(dtype)
    out = _roundtrip_page(enc.Encoding.DELTA_BINARY_PACKED, vals,
                          _field(pt))
    npt.assert_array_equal(out, vals)


def test_delta_large_int64():
    vals = np.array([2 ** 55, -2 ** 50, 0, 2 ** 62, -2 ** 61, 17],
                    dtype=np.int64)
    out = _roundtrip_page(enc.Encoding.DELTA_BINARY_PACKED, vals,
                          _field(PhysicalType.INT64))
    npt.assert_array_equal(out, vals)


def test_rle_roundtrip():
    vals = np.repeat(np.arange(30, dtype=np.int32), 111)
    out = _roundtrip_page(enc.Encoding.RLE, vals, _field(PhysicalType.INT32))
    npt.assert_array_equal(out, vals)


def test_rle_bool():
    rng = np.random.default_rng(1)
    vals = rng.random(4000) < 0.01
    out = _roundtrip_page(enc.Encoding.RLE, vals,
                          _field(PhysicalType.BOOLEAN))
    npt.assert_array_equal(out, vals)


@pytest.mark.parametrize("dtype,pt", [
    (np.float32, PhysicalType.FLOAT), (np.float64, PhysicalType.DOUBLE)])
def test_bss_roundtrip(dtype, pt):
    rng = np.random.default_rng(2)
    vals = rng.normal(size=3333).astype(dtype)
    out = _roundtrip_page(enc.Encoding.BYTE_STREAM_SPLIT, vals, _field(pt))
    npt.assert_array_equal(out, vals)


def test_dict_numeric_and_string():
    rng = np.random.default_rng(3)
    ints = rng.integers(0, 50, 2000).astype(np.int32)
    out = _roundtrip_page(enc.Encoding.RLE_DICTIONARY, ints,
                          _field(PhysicalType.INT32))
    npt.assert_array_equal(out, ints)
    strs = StringColumn.from_pylist([f"v{i % 9}" for i in range(500)])
    out = _roundtrip_page(enc.Encoding.RLE_DICTIONARY, strs,
                          Field("s", PhysicalType.BYTE_ARRAY))
    assert out.to_pylist() == strs.to_pylist()


def test_dlba_roundtrip():
    strs = StringColumn.from_pylist(
        [("x" * (i % 37)) + str(i) for i in range(800)])
    out = _roundtrip_page(enc.Encoding.DELTA_LENGTH_BYTE_ARRAY, strs,
                          Field("s", PhysicalType.BYTE_ARRAY))
    assert out.to_pylist() == strs.to_pylist()


def test_candidate_sets_small():
    """The paper's feasibility claim: < 5 candidates per type."""
    for pt in PhysicalType:
        if pt == PhysicalType.BYTE_ARRAY:
            f = Field("s", pt)
        else:
            f = _field(pt)
        cands = enc.candidate_encodings(f, EncodingPolicy.FLEX)
        assert 1 <= len(cands) <= 4, (pt, cands)


def test_selection_picks_smallest():
    cfg = FileConfig(encodings=EncodingPolicy.FLEX)
    # sorted ints: DELTA should beat PLAIN and DICT
    vals = np.arange(100_000, dtype=np.int64)
    ce = enc.select_chunk_encoding(vals, _field(PhysicalType.INT64),
                                   [(0, 100_000)], cfg)
    assert ce.encoding == enc.Encoding.DELTA_BINARY_PACKED
    # low-cardinality floats: DICT
    rng = np.random.default_rng(4)
    fv = rng.choice(np.array([1.5, 2.5, 3.5], np.float32), 100_000)
    ce = enc.select_chunk_encoding(fv, _field(PhysicalType.FLOAT),
                                   [(0, 100_000)], cfg)
    assert ce.encoding == enc.Encoding.RLE_DICTIONARY
    # long runs: RLE wins
    rv = np.repeat(np.arange(10, dtype=np.int32), 10_000)
    ce = enc.select_chunk_encoding(rv, _field(PhysicalType.INT32),
                                   [(0, 100_000)], cfg)
    assert ce.encoding == enc.Encoding.RLE


def test_v1_only_restricts():
    vals = np.arange(1000, dtype=np.int32)
    cfg = FileConfig(encodings=EncodingPolicy.V1_ONLY)
    ce = enc.select_chunk_encoding(vals, _field(PhysicalType.INT32),
                                   [(0, 1000)], cfg)
    assert ce.encoding in (enc.Encoding.PLAIN, enc.Encoding.RLE_DICTIONARY)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-2 ** 31 + 1, 2 ** 31 - 1), min_size=1,
                max_size=400))
def test_delta_property_int32(values):
    vals = np.array(values, dtype=np.int64)  # deltas may exceed int32
    out = _roundtrip_page(enc.Encoding.DELTA_BINARY_PACKED, vals,
                          _field(PhysicalType.INT64))
    npt.assert_array_equal(out, vals)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.text(max_size=20), min_size=1, max_size=100),
       st.sampled_from([enc.Encoding.PLAIN,
                        enc.Encoding.DELTA_LENGTH_BYTE_ARRAY,
                        enc.Encoding.RLE_DICTIONARY]))
def test_string_encodings_property(values, encoding):
    col = StringColumn.from_pylist(values)
    out = _roundtrip_page(encoding, col, Field("s", PhysicalType.BYTE_ARRAY))
    assert out.to_pylist() == col.to_pylist()
