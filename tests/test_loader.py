import numpy as np
import pytest

from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.data.loader import LoaderState, PrefetchLoader, TabLoader
from repro.data.tokens import generate_corpus, write_corpus


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("corpus") / "c.tab")
    write_corpus(path, 300_000, 5000,
                 ACCELERATOR_OPTIMIZED.replace(rows_per_rg=40_000,
                                               target_pages_per_chunk=8),
                 seed=7)
    return path


def test_batches_match_raw_stream(corpus):
    loader = TabLoader(corpus, seq_len=32, batch_per_shard=2)
    raw = loader.read_tokens(0, 300_000)
    x, y = loader.next_batch()
    np.testing.assert_array_equal(x[0], raw[:32])
    np.testing.assert_array_equal(y[0], raw[1:33])
    np.testing.assert_array_equal(x[1], raw[33:65])


def test_shards_are_disjoint_and_cover(corpus):
    l0 = TabLoader(corpus, seq_len=64, batch_per_shard=4, shard_index=0,
                   num_shards=2)
    l1 = TabLoader(corpus, seq_len=64, batch_per_shard=4, shard_index=1,
                   num_shards=2)
    x0, _ = l0.next_batch()
    x1, _ = l1.next_batch()
    raw = l0.read_tokens(0, 65 * 8)
    np.testing.assert_array_equal(x0[0], raw[:64])       # record 0
    np.testing.assert_array_equal(x1[0], raw[65:129])    # record 1
    np.testing.assert_array_equal(x0[1], raw[130:194])   # record 2


def test_resume_exact(corpus):
    a = TabLoader(corpus, seq_len=48, batch_per_shard=3)
    for _ in range(5):
        a.next_batch()
    snap = a.snapshot()
    nxt = a.next_batch()
    b = TabLoader(corpus, seq_len=48, batch_per_shard=3)
    b.restore(LoaderState.from_json(snap.to_json()))
    nxt2 = b.next_batch()
    np.testing.assert_array_equal(nxt[0], nxt2[0])
    np.testing.assert_array_equal(nxt[1], nxt2[1])


def test_epoch_wraps(corpus):
    loader = TabLoader(corpus, seq_len=1000, batch_per_shard=1)
    per_epoch = loader.records_per_shard
    first = loader.next_batch()
    loader.state.records_consumed = per_epoch  # jump a full epoch
    again = loader.next_batch()
    np.testing.assert_array_equal(first[0], again[0])
    assert loader.epoch >= 1


def test_prefetch_loader(corpus):
    loader = TabLoader(corpus, seq_len=16, batch_per_shard=2)
    pf = PrefetchLoader(loader, depth=2)
    it = iter(pf)
    batches = [next(it) for _ in range(3)]
    pf.close()
    ref = TabLoader(corpus, seq_len=16, batch_per_shard=2)
    for got in batches:
        exp = ref.next_batch()
        np.testing.assert_array_equal(got[0], exp[0])


def test_generate_corpus_deterministic():
    a = generate_corpus(1000, 64, seed=5)
    b = generate_corpus(1000, 64, seed=5)
    assert a.equals(b)
    assert int(np.asarray(a["token"]).max()) < 64
