import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real single-device backend
# (the 512-device override belongs exclusively to repro.launch.dryrun and
# the subprocess-based multi-device tests).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tpch_small():
    from repro.data import tpch
    return tpch.generate_tables(sf=0.005, seed=11)
