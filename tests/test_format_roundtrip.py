import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core import (CPU_DEFAULT, ACCELERATOR_OPTIMIZED, TPU_CASCADE,
                        CompressionSpec, EncodingPolicy, FileConfig,
                        StringColumn, TabFileReader, Table, write_table)
from repro.core.config import intermediate_configs


def _table(n=20_000, seed=0):
    rng = np.random.default_rng(seed)
    return Table({
        "sorted": np.cumsum(rng.integers(0, 7, n)).astype(np.int64),
        "lowcard": rng.integers(0, 9, n).astype(np.int32),
        "f32": rng.normal(size=n).astype(np.float32),
        "f64": rng.normal(size=n).astype(np.float64),
        "flags": rng.random(n) < 0.1,
        "runs": np.repeat(np.arange(-(-n // 250), dtype=np.int32),
                          250)[:n],
        "strs": StringColumn.from_pylist([f"s{i % 40}" for i in range(n)]),
    })


@pytest.mark.parametrize("name,cfg", list(intermediate_configs().items()))
def test_roundtrip_all_configs(tmp_path, name, cfg):
    tbl = _table()
    path = str(tmp_path / f"{name}.tab")
    meta = write_table(tbl, path, cfg, threads=2)
    back = TabFileReader(path).read_table()
    assert back.equals(tbl)
    d = meta.describe()
    assert d["num_rows"] == tbl.num_rows
    assert d["logical_nbytes"] == tbl.nbytes


def test_page_count_follows_config(tmp_path):
    """Insight 1 knob: target_pages_per_chunk controls page counts."""
    tbl = _table(10_000)
    for pages in (1, 10, 100):
        path = str(tmp_path / f"p{pages}.tab")
        meta = write_table(tbl, path, FileConfig(
            rows_per_rg=10_000, target_pages_per_chunk=pages,
            encodings=EncodingPolicy.V1_ONLY,
            compression=CompressionSpec(codec="none")))
        counts = [len(c.pages) for rg in meta.row_groups
                  for c in rg.columns]
        assert max(counts) == pages


def test_rg_size_follows_config(tmp_path):
    """Insight 2 knob: rows_per_rg controls row-group geometry."""
    tbl = _table(30_000)
    meta = write_table(tbl, str(tmp_path / "rg.tab"),
                       FileConfig(rows_per_rg=7_000))
    assert [rg.n_rows for rg in meta.row_groups] == [7000, 7000, 7000,
                                                     7000, 2000]


def test_flex_never_larger_than_plain(tmp_path):
    """Insight 3: smallest-wins can only shrink stored bytes vs PLAIN."""
    tbl = _table(50_000)
    none = CompressionSpec(codec="none")
    plain = write_table(tbl, str(tmp_path / "plain.tab"), FileConfig(
        rows_per_rg=50_000, encodings=EncodingPolicy.PLAIN_ONLY,
        compression=none))
    flex = write_table(tbl, str(tmp_path / "flex.tab"), FileConfig(
        rows_per_rg=50_000, encodings=EncodingPolicy.FLEX,
        compression=none))
    assert flex.stored_bytes <= plain.stored_bytes


def test_multi_rowgroup_selected_columns(tmp_path):
    tbl = _table(25_000)
    path = str(tmp_path / "m.tab")
    write_table(tbl, path, FileConfig(rows_per_rg=4_000))
    rd = TabFileReader(path)
    back = rd.read_table(columns=["sorted", "strs"])
    assert back.names == ["sorted", "strs"]
    assert back.equals(tbl.select(["sorted", "strs"]))


def test_zone_map_pruning(tmp_path):
    tbl = Table({"x": np.arange(100_000, dtype=np.int64)})
    path = str(tmp_path / "z.tab")
    write_table(tbl, path, FileConfig(rows_per_rg=10_000))
    rd = TabFileReader(path)
    kept = rd.plan_row_groups(
        lambda name, stats: stats["max"] >= 95_000)
    assert kept == [9]


def test_stats_recorded(tmp_path):
    tbl = _table(5_000)
    meta = write_table(tbl, str(tmp_path / "s.tab"), CPU_DEFAULT)
    chunk = meta.row_groups[0].column("sorted")
    col = np.asarray(tbl["sorted"])
    assert chunk.stats == {"min": int(col.min()), "max": int(col.max())}


_COL_STRATEGY = st.sampled_from(["int32", "int64", "float32", "bool"])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3000), _COL_STRATEGY, st.integers(0, 2 ** 31),
       st.integers(1, 7))
def test_roundtrip_property(n, kind, seed, pages):
    rng = np.random.default_rng(seed)
    if kind == "int32":
        col = rng.integers(-100, 100, n).astype(np.int32)
    elif kind == "int64":
        col = np.cumsum(rng.integers(0, 10, n)).astype(np.int64)
    elif kind == "float32":
        col = rng.normal(size=n).astype(np.float32)
    else:
        col = rng.random(n) < 0.5
    import tempfile, os
    tbl = Table({"c": col})
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.tab")
        write_table(tbl, path, FileConfig(
            rows_per_rg=max(1, n // 2), target_pages_per_chunk=pages,
            encodings=EncodingPolicy.FLEX,
            compression=CompressionSpec(codec="gzip", min_gain=0.1)))
        back = TabFileReader(path).read_table()
    assert back.equals(tbl)
