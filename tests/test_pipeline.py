"""Pipelined scan executor (core/overlap.py): in-order consume, error
propagation, degeneration to the inline executor, the 3-stage modeled
wall, and the arena-reuse / dict-cache / decompress-memo decode paths."""

import time

import numpy as np
import pytest

from repro.core import (CompressionSpec, EncodingPolicy, FileConfig,
                        StringColumn, Table, write_table)
from repro.core.compression import chunk_decompress_memo
from repro.core.decode_plan import ArenaPool, clear_planner_cache
from repro.core.overlap import RunReport, run_overlapped
from repro.core.query import Q6_COLUMNS, q6, q6_reference
from repro.core.scan import ScanMetrics, Scanner, open_scanner
from repro.data import tpch
from repro.kernels import dict_decode


@pytest.fixture(scope="module")
def tpch_files(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_pipe")
    from repro.core.config import ACCELERATOR_OPTIMIZED
    metas = tpch.write_tpch(str(d), sf=0.004,
                            config=ACCELERATOR_OPTIMIZED.replace(
                                rows_per_rg=4_000,
                                target_pages_per_chunk=8),
                            seed=33)
    line, orders = tpch.generate_tables(sf=0.004, seed=33)
    return metas, line, orders


def _mixed_table(n=5_000, seed=0):
    """dict + delta + rle + bss + host-path columns, as in test_decode_plan."""
    rng = np.random.default_rng(seed)
    return Table({
        "sorted32": np.cumsum(rng.integers(0, 5, n)).astype(np.int32),
        "lowcard": rng.integers(0, 11, n).astype(np.int32),
        "f32dict": rng.integers(0, 9, n).astype(np.float32) / 8.0,
        "f32noise": rng.normal(size=n).astype(np.float32),
        "flags": rng.random(n) < 0.2,
        "runs": np.repeat(np.arange(-(-n // 500), dtype=np.int32), 500)[:n],
        "strs": StringColumn.from_pylist([f"s{i % 23}" for i in range(n)]),
    })


# -- executor behaviour ------------------------------------------------------

def test_pipelined_q6_matches_blocking(tpch_files):
    metas, line, _ = tpch_files
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    sc_b = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                        decode_backend="host")
    sc_p = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                        decode_backend="host")
    got_b, rep_b = q6(sc_b, overlapped=False)
    got_p, rep_p = q6(sc_p, overlapped=True, decode_workers=2)
    assert abs(got_b - ref) / max(1.0, abs(ref)) < 1e-5
    assert abs(got_p - got_b) < 1e-6 * max(1.0, abs(got_b))
    assert rep_p.decode_workers == 2
    assert rep_p.metrics.n_row_groups == rep_b.metrics.n_row_groups
    # no wall comparison between the two measured runs: each uses its own
    # noisy per-RG times, and decode-thread contention on a 2-core CI host
    # can invert it — the schedule itself is pinned on synthetic timings in
    # test_modeled_wall_three_stage_schedule
    assert rep_p.modeled_wall > 0.0


def test_in_order_consume_under_out_of_order_decode(tpch_files):
    """Later row groups decode *first* (inverted delays); the consume stage
    must still see strictly ascending plan order."""
    metas, line, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=["l_quantity"],
                      decode_backend="host")
    plan = sc.plan()
    assert len(plan) >= 3
    real_decode = sc.decode_rg

    def inverted(i, raws):
        time.sleep(0.01 * (plan[-1] - i))   # RG 0 finishes last
        return real_decode(i, raws)

    sc.decode_rg = inverted
    seen = []

    def consume(acc, i, cols):
        seen.append(i)
        part = np.asarray(cols["l_quantity"].array, dtype=np.float64).sum()
        return part if acc is None else acc + part

    total, rep = run_overlapped(sc, consume, depth=len(plan),
                                decode_workers=4)
    assert seen == plan
    assert total == pytest.approx(
        np.asarray(line["l_quantity"], dtype=np.float64).sum())
    assert rep.metrics.n_row_groups == len(plan)
    # per-RG accounting must be in plan order too (the modeled wall zips it)
    assert len(rep.metrics.decode_per_rg) == len(plan)


def test_decode_worker_error_propagates(tpch_files):
    metas, _, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=["l_quantity"],
                      decode_backend="host")
    real_decode = sc.decode_rg

    def bad(i, raws):
        if i >= 1:
            raise RuntimeError("decode boom")
        return real_decode(i, raws)

    sc.decode_rg = bad
    with pytest.raises(RuntimeError, match="decode boom"):
        run_overlapped(sc, lambda acc, i, cols: acc, decode_workers=2)


def test_fetch_error_propagates(tpch_files):
    metas, _, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=["l_quantity"],
                      decode_backend="host")

    def bad_fetch(i):
        raise OSError("fetch boom")

    sc.fetch_rg = bad_fetch
    with pytest.raises(OSError, match="fetch boom"):
        run_overlapped(sc, lambda acc, i, cols: acc, decode_workers=2)


def test_width_zero_depth_one_degenerates_to_inline(tpch_files):
    """decode_workers=0, depth=1 is the PR-1 executor: same results, inline
    decode accounting, and the two-stage modeled schedule."""
    metas, line, _ = tpch_files
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      decode_backend="host")
    got, rep = q6(sc, overlapped=True, depth=1, decode_workers=0)
    assert abs(got - ref) / max(1.0, abs(ref)) < 1e-5
    assert rep.decode_workers == 0
    # hand-compute the two-stage schedule (with the depth=1 fetch gate:
    # RG k's fetch waits for RG k-1's consume) the report must reproduce
    io_done = compute_done = 0.0
    hist = []
    for k, (io, d, c) in enumerate(zip(rep.metrics.io_per_rg,
                                       rep.metrics.decode_per_rg,
                                       rep.consume_per_rg)):
        gate = hist[k - 1] if k >= 1 else 0.0
        io_done = max(io_done, gate) + io
        compute_done = max(io_done, compute_done) + d + c
        hist.append(compute_done)
    assert rep.modeled_wall == pytest.approx(compute_done)


def test_stage_walls_recorded(tpch_files):
    metas, _, _ = tpch_files
    sc = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                      decode_backend="host")
    _, rep = q6(sc, overlapped=True, decode_workers=2)
    for stage in ("fetch", "decode", "consume"):
        assert stage in rep.stage_walls
        assert rep.stage_walls[stage] >= 0.0
    assert rep.metrics.decode_wall_seconds == rep.stage_walls["decode"]
    assert rep.metrics.consume_seconds == pytest.approx(
        sum(rep.consume_per_rg))
    assert "workers=2" in rep.stage_summary


# -- modeled wall (satellite: decode ∥ consume) ------------------------------

def _synthetic_report(workers: int, depth: int = 8) -> RunReport:
    m = ScanMetrics()
    m.io_per_rg = [1.0, 1.0, 1.0]
    m.io_seconds = 3.0
    m.decode_per_rg = [2.0, 2.0, 2.0]
    m.decode_seconds = 6.0
    return RunReport("overlapped", 0.0, m, [1.0, 1.0, 1.0],
                     decode_workers=workers, depth=depth)


def test_modeled_wall_three_stage_schedule():
    """io=[1,1,1], decode=[2,2,2], consume=[1,1,1], depth unconstrained:

    W=0 (inline):   compute_done = 4, 7, 10          → 10
    W=1:            decode_done = 3, 5, 7; consume → 4, 6, 8
    W=2:            decode_done = 3, 4, 5; consume → 4, 5, 6
    """
    assert _synthetic_report(0).modeled_wall == pytest.approx(10.0)
    assert _synthetic_report(1).modeled_wall == pytest.approx(8.0)
    assert _synthetic_report(2).modeled_wall == pytest.approx(6.0)
    # blocking sums every stage
    blk = _synthetic_report(0)
    blk.mode = "blocking"
    assert blk.modeled_wall == pytest.approx(12.0)


def test_modeled_wall_monotone_in_workers():
    walls = [_synthetic_report(w).modeled_wall for w in (0, 1, 2, 4)]
    assert walls == sorted(walls, reverse=True)
    # beyond n_rgs workers there is nothing left to parallelize
    assert _synthetic_report(4).modeled_wall == \
        _synthetic_report(3).modeled_wall


def test_modeled_wall_honors_depth_backpressure():
    """The in-flight semaphore gates RG k's fetch on RG k-depth's consume:
    with depth=2 and W=2, RG2's fetch waits for RG0 (consumed at 4), so
    decode_done = 3, 4, 7 and consume → 4, 5, 8 — the depth-free schedule
    (6.0) is infeasible for the real executor and must not be reported."""
    assert _synthetic_report(2, depth=2).modeled_wall == pytest.approx(8.0)
    # wider depth releases the gate back to the pure pipeline schedule
    assert _synthetic_report(2, depth=3).modeled_wall == pytest.approx(6.0)
    # depth=1 serializes fetch behind every consume for W=0 too
    assert _synthetic_report(0, depth=1).modeled_wall == pytest.approx(12.0)


# -- arena reuse + dict cache + decompress memo ------------------------------

@pytest.mark.parametrize("backend", ["host", "pallas"])
def test_second_pass_bit_identical_with_caches_hot(tmp_path, backend):
    """Pass 2 exercises arena reuse, dictionary-cache hits, and the gzip
    chunk decompress memo; results must stay bit-identical to the
    per-chunk reference path (the PR-1 decode)."""
    tbl = _mixed_table()
    path = str(tmp_path / f"mixed_{backend}.tab")
    write_table(tbl, path, FileConfig(
        rows_per_rg=2_000, target_pages_per_chunk=6,
        encodings=EncodingPolicy.FLEX,
        compression=CompressionSpec(codec="gzip", min_gain=0.0)))
    clear_planner_cache()
    dict_decode.dict_cache_clear()
    chunk_decompress_memo().clear()
    ref = Scanner(path, decode_backend=backend, use_plan=False)
    pln = Scanner(path, decode_backend=backend, use_plan=True)
    for pass_no in range(2):
        for i in ref.plan():
            raws, _ = ref.fetch_rg(i)
            cols_r, _ = ref.decode_rg(i, raws)
            cols_p, _ = pln.decode_rg(i, raws)
            for name in tbl.columns:
                a, b = cols_p[name], cols_r[name]
                if isinstance(a.array, StringColumn):
                    np.testing.assert_array_equal(a.array.offsets,
                                                  b.array.offsets)
                    np.testing.assert_array_equal(a.array.payload,
                                                  b.array.payload)
                else:
                    ra, rb = np.asarray(a.array), np.asarray(b.array)
                    assert ra.dtype == rb.dtype, (pass_no, name)
                    np.testing.assert_array_equal(ra, rb,
                                                  err_msg=f"{pass_no}:{name}")
    stats = dict_decode.dict_cache_stats()
    assert stats["hits"] > 0            # pass 2 reused decoded dictionaries
    memo = chunk_decompress_memo()
    assert memo.hits > 0                # pass 2 skipped gzip inflation
    if backend == "pallas":
        assert pln.planner._arena_pool.reuses > 0   # arenas recycled


def test_arena_pool_reuses_buffers():
    pool = ArenaPool(max_bytes=1 << 20)
    view1, buf1 = pool.take((4, 100), np.uint32)
    assert view1.shape == (4, 100) and view1.dtype == np.uint32
    view1[:] = 7                        # dirty it; reuse must not care
    pool.give(buf1)
    view2, buf2 = pool.take((4, 100), np.uint32)
    assert buf2 is buf1                 # same pooled capacity bucket
    assert pool.reuses == 1 and pool.allocs == 1
    # a different dtype/shape in the same byte bucket also reuses
    pool.give(buf2)
    view3, buf3 = pool.take((100, 4), np.float32)
    assert buf3 is buf1
    assert view3.shape == (100, 4) and view3.dtype == np.float32


def test_arena_pool_cap_drops_excess():
    pool = ArenaPool(max_bytes=1024)
    _, small = pool.take((16,), np.uint8)       # 16B bucket
    _, big = pool.take((4096,), np.uint8)       # 4KiB > cap
    pool.give(small)
    pool.give(big)                               # dropped, over cap
    _, again = pool.take((4096,), np.uint8)
    assert again is not big
    assert pool.allocs == 3


def test_dict_cache_keyed_and_capped():
    dict_decode.dict_cache_clear()
    a = np.arange(10, dtype=np.int32)
    entry = dict_decode.dict_cache_put(("t", "col", 0, "device"), a)
    assert dict_decode.dict_cache_get(("t", "col", 0, "device")) is entry
    assert dict_decode.dict_cache_get(("t", "col", 1, "device")) is None
    np.testing.assert_array_equal(np.asarray(entry.device), a)
    stats = dict_decode.dict_cache_stats()
    assert stats["entries"] == 1 and stats["hits"] == 1
    assert stats["misses"] == 1
    dict_decode.dict_cache_clear()
    assert dict_decode.dict_cache_stats()["entries"] == 0


def test_gzip_memo_scan_results_unchanged(tmp_path):
    """End-to-end: two q6 runs over a gzip file — the second hits the memo
    and returns the same revenue."""
    line, _ = tpch.generate_tables(sf=0.002, seed=7)
    path = str(tmp_path / "gz.tab")
    write_table(line.select(Q6_COLUMNS), path, FileConfig(
        rows_per_rg=4_000, target_pages_per_chunk=10,
        encodings=EncodingPolicy.FLEX,
        compression=CompressionSpec(codec="gzip", min_gain=0.0)))
    clear_planner_cache()
    chunk_decompress_memo().clear()
    got1, _ = q6(open_scanner(path, columns=Q6_COLUMNS,
                              decode_backend="host"), prune=False)
    hits_before = chunk_decompress_memo().hits
    got2, _ = q6(open_scanner(path, columns=Q6_COLUMNS,
                              decode_backend="host"), prune=False)
    assert chunk_decompress_memo().hits > hits_before
    assert got1 == pytest.approx(got2)
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    assert abs(got1 - ref) / max(1.0, abs(ref)) < 1e-5
