import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_config("granite-3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(model, params, max_batch=4, max_seq=128)


def test_generate_batched(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, 24).astype(np.int32), max_new_tokens=8)
        for i in range(6)]
    done = eng.generate(reqs)
    assert set(done) == set(range(6))
    for c in done.values():
        assert c.tokens.shape == (8,)
        assert np.all(c.tokens >= 0) and np.all(c.tokens < cfg.vocab_size)
    rep = eng.throughput_report(done)
    assert rep["n_requests"] == 6
    assert rep["decode_tokens_per_s"] > 0


def test_greedy_deterministic(engine):
    cfg, eng = engine
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    a = eng.generate([Request(0, prompt, 6)])[0].tokens
    b = eng.generate([Request(0, prompt, 6)])[0].tokens
    np.testing.assert_array_equal(a, b)


def test_length_buckets(engine):
    cfg, eng = engine
    rng = np.random.default_rng(2)
    reqs = [Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=4),
            Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 20)
                    .astype(np.int32), max_new_tokens=4)]
    done = eng.generate(reqs)
    assert set(done) == {0, 1}


def test_eos_stop(engine):
    cfg, eng = engine
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    free = eng.generate([Request(0, prompt, 8)])[0].tokens
    eos = int(free[2])
    stopped = eng.generate([Request(0, prompt, 8, eos_id=eos)])[0].tokens
    assert stopped.shape[0] <= 8
    assert eos in stopped.tolist()


def test_encoder_only_rejected():
    cfg = smoke_config("hubert-xlarge")
    model = Model(cfg)
    with pytest.raises(ValueError):
        ServeEngine(model, {}, max_batch=1)
