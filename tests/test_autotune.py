import numpy as np

from repro.core.autotune import autotune
from repro.core.config import EncodingPolicy
from repro.core import TabFileReader, write_table
from repro.data import tpch


def test_autotune_recommends_sane_config():
    line, _ = tpch.generate_tables(sf=0.005, seed=9,
                                   include_strings=False)
    rep = autotune(line, sample_rows=20_000)
    cfg = rep.config
    # Insight 2: million-row-class RGs for ~4-byte columns on a 7 GB/s lane
    assert cfg.rows_per_rg >= 200_000
    # Insight 1: page count at grid width
    assert cfg.target_pages_per_chunk >= 64
    # Insight 3: TPC-H sample has sorted keys + low-card columns → FLEX
    assert cfg.encodings == EncodingPolicy.FLEX
    # Insight 4: threshold preserved
    assert cfg.compression.min_gain == 0.10
    assert rep.est_compressed_bytes_per_row > 0
    assert len(rep.per_column) == len(line.names)


def test_autotuned_file_roundtrips(tmp_path):
    line, _ = tpch.generate_tables(sf=0.002, seed=10,
                                   include_strings=False)
    rep = autotune(line, sample_rows=5_000)
    path = str(tmp_path / "tuned.tab")
    write_table(line, path, rep.config)
    assert TabFileReader(path).read_table().equals(line)
