"""The roofline extractor must be exact on small known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_dot_flops_exact():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    hlo = _compile_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    rep = analyze_hlo(hlo)
    assert rep.dot_flops == 7 * 2 * 64 ** 3
    assert rep.exact_loop_multipliers


def test_nested_scan_multipliers():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    hlo = _compile_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    rep = analyze_hlo(hlo)
    assert rep.dot_flops == 5 * 3 * 2 * 32 ** 3


def test_plain_dot_flops():
    def f(a, b):
        return a @ b

    hlo = _compile_text(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                        jax.ShapeDtypeStruct((256, 64), jnp.float32))
    rep = analyze_hlo(hlo)
    assert rep.dot_flops == 2 * 128 * 256 * 64


def test_memory_bytes_positive_and_sane():
    def f(a):
        return jnp.sum(a * 2.0)

    hlo = _compile_text(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    rep = analyze_hlo(hlo)
    assert rep.memory_bytes >= 1024 * 1024 * 4      # at least reads input
    assert rep.memory_bytes < 1024 * 1024 * 4 * 10  # and not wildly off


def test_collective_bytes_psum():
    import numpy as np
    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("d",))

    def f(x):
        return jax.lax.psum(x, "d")

    fn = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    hlo = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((256,), jnp.float32)).compile().as_text()
    rep = analyze_hlo(hlo)
    # single-device psum may be optimized away; accept 0 or the buffer size
    assert rep.bytes_by_kind["all-reduce"] in (0, 1024)
