"""Multi-device tests run in subprocesses with placeholder CPU devices —
keeping the main test process on the real single-device backend."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = REPO_SRC
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_manual_dp_compression_numerics():
    """bf16 and int8+EF compressed all-reduce track the exact DP step."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import Model
        from repro.train.optimizer import OptConfig
        from repro.train.step import (build_manual_dp_step, build_train_step,
                                      init_manual_dp_state, init_train_state)

        cfg = smoke_config("granite-3-8b")
        model = Model(cfg)
        opt = OptConfig(peak_lr=1e-3, warmup_steps=0, total_steps=50)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (16, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (16, 32)), jnp.int32)}
        exact_step = jax.jit(build_train_step(model, opt))
        s0 = init_train_state(model, jax.random.PRNGKey(0), opt)
        s_exact, m_exact = exact_step(s0, batch)

        for method, tol in (("none", 1e-4), ("bf16", 5e-2),
                            ("int8_ef", 1e-1)):
            step = build_manual_dp_step(model, opt, mesh, method)
            s1 = init_manual_dp_state(model, jax.random.PRNGKey(0), opt,
                                      method)
            s1, m1 = step(s1, batch)
            # compare updated param trees
            diffs = jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))),
                s_exact["params"], s1["params"])
            worst = max(jax.tree.leaves(diffs))
            assert worst < tol, (method, worst)
            print(method, "worst param diff", worst)
        # int8 with error feedback converges over steps: loss decreases
        step = build_manual_dp_step(model, opt, mesh, "int8_ef")
        s = init_manual_dp_state(model, jax.random.PRNGKey(0), opt,
                                 "int8_ef")
        losses = []
        for i in range(12):
            s, m = step(s, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("int8_ef losses", losses[0], "->", losses[-1])
    """, n_devices=8)


def test_pipeline_parallel_matches_sequential():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import (pipeline_forward,
                                             sequential_reference)
        mesh = jax.make_mesh((4,), ("stage",))
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (4, 16, 16)) * 0.3,
                  "b": jax.random.normal(jax.random.PRNGKey(1), (4, 16))}

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        x = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
        ref = sequential_reference(stage_fn, params, x)
        out = pipeline_forward(stage_fn, params, x, mesh=mesh, n_micro=4)
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-5, err
        print("pipeline matches sequential, err", err)
    """, n_devices=4)


def test_sharded_train_step_small_mesh():
    """pjit path: FSDP+TP sharded step runs on a 4x2 placeholder mesh."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import Model
        from repro.train.optimizer import OptConfig
        from repro.train.step import make_sharded_step, init_train_state
        cfg = smoke_config("mixtral-8x22b")
        model = Model(cfg)
        opt = OptConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step, state_abs, state_sh, jit_for = make_sharded_step(
            model, opt, mesh, grad_accum=2, zero=True, donate=False)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (8, 32)), jnp.int32)}
        with jax.set_mesh(mesh):
            state = init_train_state(model, jax.random.PRNGKey(0), opt)
            state = jax.device_put(state, state_sh)
            jitted = jit_for(batch)
            state, metrics = jitted(state, batch)
            loss1 = float(metrics["loss"])
            state, metrics = jitted(state, batch)
            loss2 = float(metrics["loss"])
        assert np.isfinite(loss1) and np.isfinite(loss2)
        assert loss2 < loss1    # same batch twice: must improve
        print("sharded step losses", loss1, "->", loss2)
    """, n_devices=8)


def test_elastic_reshard_across_meshes():
    """Save on a 4-way mesh, restore onto a 2-way mesh (elastic rescale)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import CheckpointManager
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        state = {"w": jax.device_put(
            w, NamedSharding(mesh_a, P("data", "model")))}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(1, state, extra={"step": 1})
            sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
            restored, _ = mgr.restore(shardings=sh_b)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(w))
        assert restored["w"].sharding.mesh.shape["model"] == 4
        print("elastic reshard OK")
    """, n_devices=8)


def test_moe_shard_map_equivalence():
    """shard_map MoE (psum combine) ≡ GSPMD dispatch numerically."""
    run_with_devices("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import Model

        cfg0 = smoke_config("mixtral-8x22b")
        cfg0 = dataclasses.replace(cfg0, moe=dataclasses.replace(
            cfg0.moe, n_experts=4, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg0.vocab_size, (4, 32)), jnp.int32),
            "labels": jnp.asarray(
            rng.integers(0, cfg0.vocab_size, (4, 32)), jnp.int32)}
        model0 = Model(cfg0)
        params = model0.init(jax.random.PRNGKey(0))
        with jax.set_mesh(mesh):
            l0, _ = jax.jit(model0.train_loss)(params, batch)
            cfg1 = dataclasses.replace(cfg0, moe_shmap=True)
            l1, _ = jax.jit(Model(cfg1).train_loss)(params, batch)
        d = abs(float(l0) - float(l1))
        assert d < 2e-4, (float(l0), float(l1))
        print("moe shmap equivalence:", float(l0), float(l1))
    """, n_devices=4)
