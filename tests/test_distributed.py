"""Distributed scans (DESIGN.md §8): contiguous sharding, deterministic
tree reduce, the object-store storage model, background prefetch, decode
affinity, and multi-device bit-identity of Q6/Q12."""

import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.core.faults import FaultPlan
from repro.core.query import q6, q6_rg_stats_predicate, q12
from repro.core.scheduler import _apply_affinity, decode_affinity_mode
from repro.core.storage import (DEFAULT_OBJECT_COALESCE_GAP,
                                DEFAULT_OBJECT_CONNECTIONS,
                                DEFAULT_OBJECT_LATENCY, ObjectStoreStorage,
                                PrefetchingStorage, backend_io_defaults,
                                open_storage)
from repro.data import tpch
from repro.dataset import (plan_dataset_scan, run_distributed_scan,
                           write_dataset)
from repro.launch.mesh import scan_devices
from repro.parallel.collectives import tree_reduce
from repro.parallel.sharding import contiguous_shards

TUNED = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_500,
                                      target_pages_per_chunk=4)
HOST_OPTS = {"backend": "sim", "decode_backend": "host"}
REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def bits(x: float) -> bytes:
    return struct.pack("<d", float(x))


@pytest.fixture(scope="module")
def tables():
    return tpch.generate_tables(sf=0.002, seed=42, include_strings=False)


@pytest.fixture(scope="module")
def range_ds(tables, tmp_path_factory):
    line, _ = tables
    root = str(tmp_path_factory.mktemp("ds_dist"))
    return write_dataset(line, root, TUNED, partition_by="l_shipdate",
                         how="range", fragments=8)


@pytest.fixture(scope="module")
def q12_ds(tables, tmp_path_factory):
    line, orders = tables
    base = tmp_path_factory.mktemp("ds_q12")
    lds = write_dataset(line, str(base / "l"), TUNED,
                        partition_by="l_shipdate", how="range", fragments=6)
    ods = write_dataset(orders, str(base / "o"), TUNED, fragments=3)
    return lds, ods


# -- contiguous sharding ----------------------------------------------------

def test_contiguous_shards_partition_properties():
    for m in (1, 2, 5, 8, 17):
        for n in (1, 2, 3, 4, 9):
            weights = [(i * 37) % 11 + 1 for i in range(m)]
            shards = contiguous_shards(weights, n)
            assert len(shards) == n
            # contiguous, ordered, covering [0, m)
            pos = 0
            for lo, hi in shards:
                assert lo == pos and hi >= lo
                pos = hi
            assert pos == m
            # non-empty while items remain
            nonempty = sum(1 for lo, hi in shards if hi > lo)
            assert nonempty == min(n, m)


def test_contiguous_shards_weighted_balance():
    # one huge fragment up front: it gets a shard of its own
    shards = contiguous_shards([100, 1, 1, 1], 2)
    assert shards == [(0, 1), (1, 4)]
    shards = contiguous_shards([1, 1, 1, 100], 2)
    assert shards == [(0, 3), (3, 4)]
    # deterministic
    w = [5, 3, 8, 1, 9, 2, 7, 4]
    assert contiguous_shards(w, 3) == contiguous_shards(list(w), 3)


def test_scan_devices_cycles_on_small_hosts():
    devs = scan_devices(4)
    assert len(devs) == 4          # cycles when fewer real devices exist
    assert scan_devices(1) == [devs[0]]


# -- tree reduce ------------------------------------------------------------

def test_tree_reduce_pairing_depends_only_on_length():
    pairings = []

    def record(a, b):
        pairings.append((a, b))
        return f"({a}+{b})"

    tree_reduce(list("abcde"), record)
    first = list(pairings)
    pairings.clear()
    tree_reduce(list("abcde"), record)
    assert pairings == first       # same shape every time
    # 5 leaves: (a+b)(c+d) then ((a+b)+(c+d)) then (...+e)
    assert first[0] == ("a", "b") and first[1] == ("c", "d")


def test_tree_reduce_values_and_nones():
    assert tree_reduce([1, 2, 3, 4, 5], lambda a, b: a + b) == 15
    assert tree_reduce([], min) is None
    assert tree_reduce([None, None], min) is None
    assert tree_reduce([None, 7, None], max) == 7


# -- object-store storage model ---------------------------------------------

def test_object_store_model(tmp_path):
    p = tmp_path / "blob.bin"
    payload = bytes(range(256)) * 64
    p.write_bytes(payload)
    st = ObjectStoreStorage(str(p), connections=2,
                            connection_bandwidth=1e9, latency=5e-3,
                            sleep=False)
    assert st.kind == "object" and st.connections == 2
    assert st.request_seconds(1_000_000) == pytest.approx(5e-3 + 1e-3)
    # LPT over 2 connections: three requests, largest two on separate
    # lanes, the third behind the smaller — batch drains with the slowest
    sizes = [4_000_000, 2_000_000, 1_000_000]
    per = [st.request_seconds(s) for s in sizes]
    assert st.batch_seconds(sizes) == pytest.approx(max(per[0],
                                                        per[1] + per[2]))
    data = st.fetch(0, 512)
    assert data == payload[:512]
    assert st.stats.requests == 1 and len(st.stats.latencies) == 1
    assert st.stats.latencies[0] == pytest.approx(st.request_seconds(512))
    st.close()


def test_object_store_sleeps_modeled_time(tmp_path):
    import time
    p = tmp_path / "blob.bin"
    p.write_bytes(b"x" * 4096)
    st = ObjectStoreStorage(str(p), latency=20e-3)
    t0 = time.perf_counter()
    st.fetch(0, 1024)
    assert time.perf_counter() - t0 >= 20e-3   # remote latency is wall
    st.close()


def test_open_storage_object_defaults(tmp_path):
    p = tmp_path / "blob.bin"
    p.write_bytes(b"y" * 128)
    st = open_storage(str(p), backend="object")
    assert st.kind == "object"
    assert st.n_lanes == DEFAULT_OBJECT_CONNECTIONS
    assert st.latency == DEFAULT_OBJECT_LATENCY
    st.close()
    bw, lat, gap = backend_io_defaults("object")
    assert gap == DEFAULT_OBJECT_COALESCE_GAP > backend_io_defaults("sim")[2]
    assert lat == DEFAULT_OBJECT_LATENCY


# -- prefetch ---------------------------------------------------------------

def test_prefetch_hit_and_miss_accounting(tmp_path):
    p = tmp_path / "blob.bin"
    payload = os.urandom(1 << 16)
    p.write_bytes(payload)
    inner = open_storage(str(p), backend="sim", n_lanes=2)
    st = PrefetchingStorage(inner)
    assert st.prefetch([(0, 1024), (2048, 512)]) == 2
    assert st.prefetch([(0, 1024)]) == 0       # dedup against in-buffer
    data = st.fetch(0, 1024)                   # hit
    assert data == payload[:1024]
    miss = st.fetch(8192, 256)                 # never prefetched
    assert miss == payload[8192:8192 + 256]
    assert st.prefetch_stats.hits == 1
    assert st.prefetch_stats.misses == 1
    # consumption-time accounting: exactly one request per demand fetch,
    # nothing for the still-buffered (2048, 512) range
    assert inner.stats.requests == 2
    # single-use entries: the same range misses the second time
    st.fetch(0, 1024)
    assert st.prefetch_stats.misses == 2
    # hidden + stall partition the modeled request time of each hit
    ps = st.prefetch_stats
    assert (ps.hidden_seconds + ps.stall_seconds
            == pytest.approx(inner.request_seconds(1024)))
    st.close()


def test_prefetch_batch_hits_keep_request_counts(tmp_path):
    p = tmp_path / "blob.bin"
    p.write_bytes(os.urandom(1 << 15))
    inner = open_storage(str(p), backend="sim")
    st = PrefetchingStorage(inner)
    reqs = [(0, 512), (4096, 1024)]
    st.prefetch(reqs)
    datas, _ = st.fetch_batch(reqs)
    assert [len(d) for d in datas] == [512, 1024]
    assert inner.stats.requests == 2 and inner.stats.batches == 1
    assert st.prefetch_stats.hits == 2 and st.prefetch_stats.misses == 0
    st.close()


# -- decode affinity --------------------------------------------------------

def test_decode_affinity_modes(monkeypatch):
    monkeypatch.delenv("REPRO_DECODE_AFFINITY", raising=False)
    assert decode_affinity_mode() == "off"
    monkeypatch.setenv("REPRO_DECODE_AFFINITY", "auto")
    assert decode_affinity_mode().startswith("auto:")
    _apply_affinity(0)             # linux: pins; elsewhere: unsupported
    assert decode_affinity_mode() in ("auto:pinned", "auto:unsupported")
    monkeypatch.setenv("REPRO_DECODE_AFFINITY", "not-a-cpu-list")
    _apply_affinity(0)
    assert decode_affinity_mode() == "not-a-cpu-list:unsupported"


def test_affinity_logged_in_scan_metrics(range_ds, monkeypatch):
    monkeypatch.setenv("REPRO_DECODE_AFFINITY", "auto")
    plan = plan_dataset_scan(range_ds,
                             predicate_stats=q6_rg_stats_predicate)
    _, rep = run_distributed_scan(
        plan, lambda acc, i, cols: 1, lambda a, b: a + b,
        devices=1, decode_workers=1, open_opts=HOST_OPTS)
    assert rep.reports
    mode = rep.reports[0].metrics.decode_affinity
    assert mode.startswith("auto:")


# -- multi-device bit-identity ----------------------------------------------

@pytest.mark.parametrize("prune", [True, False])
@pytest.mark.parametrize("fused", [False, True])
def test_q6_device_sweep_bit_identical(range_ds, prune, fused):
    results = {}
    for d in (1, 2, 4):
        r, rep = q6(range_ds, prune=prune, fused=fused, devices=d,
                    decode_workers=2, open_opts=HOST_OPTS)
        results[d] = (r, rep)
        assert rep.devices == d
        assert sum(rep.device_fragments) == rep.files_scanned
        assert rep.fragments_quarantined == 0
    assert bits(results[1][0]) == bits(results[2][0]) == bits(results[4][0])


def test_q6_distributed_matches_windowed(range_ds):
    rd, _ = q6(range_ds, devices=1, decode_workers=2, open_opts=HOST_OPTS)
    rw, _ = q6(range_ds, decode_workers=2, open_opts=HOST_OPTS)
    # 2 surviving FY94 fragments: tree reduce == left fold at this width;
    # the executors agree bitwise on the same plan
    assert bits(rd) == bits(rw)


@pytest.mark.parametrize("fused", [False, True])
def test_q12_device_sweep_bit_identical(q12_ds, fused):
    lds, ods = q12_ds
    out = {}
    for d in (1, 2):
        res, brep, prep = q12(lds, ods, fused=fused, devices=d,
                              decode_workers=2, open_opts=HOST_OPTS)
        out[d] = res
        assert brep.devices == d and prep.devices == d
    assert out[1] == out[2]


def test_distributed_partials_are_plan_ordered(range_ds):
    plan = plan_dataset_scan(range_ds)

    def consume(acc, i, cols):
        n = int(cols["l_shipdate"].array.shape[0])
        return n if acc is None else acc + n

    parts1, rep1 = run_distributed_scan(plan, consume, None, devices=1,
                                        decode_workers=2,
                                        open_opts=HOST_OPTS)
    parts4, rep4 = run_distributed_scan(plan, consume, None, devices=4,
                                        decode_workers=2,
                                        open_opts=HOST_OPTS)
    assert parts1 == parts4        # slot list ignores which device ran it
    assert len(parts1) == len(plan.fragments)
    assert rep4.stolen_fragments >= 0
    assert sum(rep4.device_fragments) == len(plan.fragments)


def test_more_devices_than_fragments(tables, tmp_path):
    line, _ = tables
    ds = write_dataset(line.slice(0, 3_000), str(tmp_path), TUNED,
                       fragments=2)
    plan = plan_dataset_scan(ds)
    parts, rep = run_distributed_scan(
        plan, lambda acc, i, cols: 1, lambda a, b: a + b,
        devices=4, decode_workers=1, open_opts=HOST_OPTS)
    assert parts == 2 and rep.devices == 4
    assert sum(rep.device_fragments) == 2


# -- object backend through the distributed executor ------------------------

def test_distributed_object_backend_prefetch(range_ds):
    opts = dict(HOST_OPTS, backend="object", prefetch=True)
    r_obj, rep = q6(range_ds, prune=False, devices=2, decode_workers=2,
                    open_opts=opts)
    r_sim, _ = q6(range_ds, prune=False, devices=2, decode_workers=2,
                  open_opts=HOST_OPTS)
    assert bits(r_obj) == bits(r_sim)     # backend never changes results
    assert rep.bytes_by_backend.get("object", 0) == rep.stored_bytes
    assert rep.prefetch_hits + rep.prefetch_misses == rep.n_io_requests
    assert rep.prefetch_hits > 0          # lookahead actually landed
    assert rep.prefetch_hidden_seconds > 0
    assert rep.io_p95_us >= rep.io_p50_us > 0


# -- chaos: one device's fragments fault, the run heals ---------------------

def test_one_shard_faults_heal_bit_identical(range_ds):
    plan = plan_dataset_scan(range_ds)
    n = len(plan.fragments)
    lo, hi = contiguous_shards(
        [max(1, f.stored_bytes) for f in plan.fragments], 2)[0]
    shard0 = set(range(lo, hi))
    assert shard0 and len(shard0) < n

    def consume(acc, i, cols):
        s = float(np.asarray(cols["l_discount"].array,
                             dtype=np.float64).sum())
        return s if acc is None else acc + s

    clean, crep = run_distributed_scan(plan, consume, lambda a, b: a + b,
                                       devices=2, decode_workers=2,
                                       open_opts=HOST_OPTS)

    def chaos_opts(pos, frag):
        if pos in shard0:
            return {"fault_plan": FaultPlan(seed=pos + 1, io_error=0.5,
                                            bit_flip=0.3)}
        return None

    healed, hrep = run_distributed_scan(plan, consume, lambda a, b: a + b,
                                        devices=2, decode_workers=2,
                                        open_opts=HOST_OPTS,
                                        open_opts_for=chaos_opts)
    assert bits(clean) == bits(healed)
    assert hrep.retries > 0
    assert hrep.fragments_quarantined == 0
    assert crep.retries == 0


# -- real 4-device emulation (subprocess, XLA host platform) ----------------

@pytest.mark.slow
def test_four_emulated_devices_bit_identical(range_ds):
    code = f"""
import struct
import jax
assert len(jax.devices()) == 4, jax.devices()
from repro.core.query import q6
from repro.dataset import Dataset
ds = Dataset.load({range_ds.root!r})
opts = {{"backend": "sim", "decode_backend": "host"}}
r1, _ = q6(ds, devices=1, decode_workers=2, open_opts=opts)
r4, rep = q6(ds, devices=4, decode_workers=2, open_opts=opts)
assert struct.pack("<d", r1) == struct.pack("<d", r4), (r1, r4)
assert rep.devices == 4
assert len(set(rep.device_names)) == 4      # four distinct real devices
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep * bool(
        env.get("PYTHONPATH")) + env.get("PYTHONPATH", "")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
