"""Late materialization (core/fused.py): fused == unfused, bit for bit.

The property tests drive randomized predicates, encodings/codecs (via the
paper's file configs), page/row-group sizes, and padding edges through the
fused aggregate and selection paths, always diffing against the reference
execution mode (``FusedSpec.with_mode("reference")``) — the unfused twin
that materializes everything and evaluates the same canonical per-page
reduce.  Exact equality is asserted on the raw float bits / selection
vectors / gathered arrays, not on tolerances.
"""

import shutil
import struct
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (hypothesis not installed)
    from _hypothesis_fallback import given, settings, st

from repro.core import ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TPU_CASCADE, Table
from repro.core.fused import (FUSED_KEY, Compare, FusedRGResult, FusedSpec,
                              Interval, SumProduct)
from repro.core.query import Q6_COLUMNS, q6, q6_fused_spec, q6_reference
from repro.core.scan import Scanner
from repro.core.writer import write_table
from repro.data import tpch
from repro.kernels.common import kernel_launch_count

CONFIGS = {
    "cpu": CPU_DEFAULT,
    "opt": ACCELERATOR_OPTIMIZED,
    "cascade": TPU_CASCADE,
}


def _write(directory, name, n_rows, cfg, seed, rows_per_rg, pages):
    rng = np.random.default_rng(seed)
    tbl = Table({
        # sorted-ish int32 → DELTA; the stage-A predicate column
        "ship": np.cumsum(rng.integers(0, 3, n_rows)).astype(np.int32),
        # low-cardinality float32 → RLE_DICTIONARY
        "disc": rng.choice(np.linspace(0.0, 0.1, 11).astype(np.float32),
                           n_rows),
        "qty": rng.integers(1, 51, n_rows).astype(np.float32),
        # high-entropy float32 → PLAIN (or BSS under some configs)
        "price": (rng.random(n_rows) * 1e5).astype(np.float32),
        # int64 id → DELTA; emit column for selection mode
        "key": np.arange(n_rows, dtype=np.int64) * 3 + 7,
    })
    path = f"{directory}/{name}.tab"
    write_table(tbl, path, cfg.replace(rows_per_rg=rows_per_rg,
                                       target_pages_per_chunk=pages))
    return path, tbl


def _scan_fused(path, columns, spec, backend):
    sc = Scanner(path, columns, decode_backend=backend, fused_spec=spec)
    out = []
    for _, cols in sc.scan():
        res = cols[FUSED_KEY]
        assert isinstance(res, FusedRGResult)
        out.append(res)
    return out


def _assert_bitwise(fused_rgs, ref_rgs):
    assert len(fused_rgs) == len(ref_rgs)
    for f, r in zip(fused_rgs, ref_rgs):
        if f.partials is not None:
            assert f.partials.tobytes() == r.partials.tobytes()
            assert struct.pack("<d", f.partial) == \
                struct.pack("<d", r.partial)
        if f.selection is not None:
            np.testing.assert_array_equal(f.selection, r.selection)
            assert f.gathered.keys() == r.gathered.keys()
            for k in f.gathered:
                assert f.gathered[k].dtype == r.gathered[k].dtype
                assert f.gathered[k].tobytes() == r.gathered[k].tobytes()


def _oracle_sum(tbl, spec):
    mask = np.ones(tbl["ship"].shape[0], dtype=bool)
    for iv in spec.predicates:
        v = np.asarray(tbl[iv.column])
        cast = v.dtype.type
        if iv.lo is not None:
            mask &= (v >= cast(iv.lo)) if iv.lo_incl else (v > cast(iv.lo))
        if iv.hi is not None:
            mask &= (v <= cast(iv.hi)) if iv.hi_incl else (v < cast(iv.hi))
        if iv.in_set is not None:
            mask &= np.isin(v, np.asarray(iv.in_set, dtype=v.dtype))
    for cmp in spec.compares:
        mask &= np.asarray(tbl[cmp.left]) < np.asarray(tbl[cmp.right])
    return mask


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(sorted(CONFIGS)),
       st.integers(500, 4000),     # rows (padding edges: rarely pow2)
       st.integers(1, 4),          # pages per chunk
       st.integers(0, 2))          # predicate shape
def test_fused_agg_matches_reference(seed, cfg_name, n_rows, pages, pshape):
    rng = np.random.default_rng(seed)
    lo = float(rng.uniform(0.0, 0.08))
    if pshape == 0:       # typical window
        preds = (Interval("disc", lo=round(lo, 2), hi=round(lo + 0.02, 2),
                          hi_incl=bool(rng.integers(0, 2))),
                 Interval("qty", hi=float(rng.integers(5, 45))),
                 Interval("ship", lo=int(n_rows * 0.1),
                          hi=int(n_rows * 1.2)))
    elif pshape == 1:     # all-pruned extreme: nothing can match
        preds = (Interval("disc", lo=9.0),)
    else:                 # nothing-pruned extreme: everything matches
        preds = (Interval("qty", lo=0.0, hi=1e9, hi_incl=True),)
    spec = FusedSpec(predicates=preds, agg=SumProduct("price", "disc"))
    rpg = int(rng.choice([700, 1000, 1500]))
    tmp = tempfile.mkdtemp(prefix="fusedprop")
    try:
        path, tbl = _write(tmp, f"agg{seed}", n_rows,
                           CONFIGS[cfg_name], seed, rpg, pages)
        cols = ["ship", "disc", "qty", "price"]
        ref = _scan_fused(path, cols, spec.with_mode("reference"), "pallas")
        for backend in ("pallas", "host"):
            got = _scan_fused(path, cols, spec, backend)
            _assert_bitwise(got, ref)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    mask = _oracle_sum(tbl, spec)
    oracle = float(np.sum((tbl["price"][mask].astype(np.float64)
                           * tbl["disc"][mask].astype(np.float64))))
    total = sum(r.partial for r in ref)
    assert total == pytest.approx(oracle, rel=1e-4, abs=1e-6)
    if pshape == 1:
        assert total == 0.0


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(sorted(CONFIGS)),
       st.integers(500, 3000),
       st.integers(0, 2))
def test_fused_selection_matches_reference(seed, cfg_name, n_rows, pshape):
    rng = np.random.default_rng(seed + 77)
    if pshape == 0:
        preds = (Interval("qty", hi=float(rng.integers(5, 45))),
                 Interval("disc", in_set=(np.float32(0.02),
                                          np.float32(0.05))))
    elif pshape == 1:     # all-pruned
        preds = (Interval("ship", hi=-1),)
    else:                 # nothing-pruned
        preds = (Interval("ship", lo=-1),)
    spec = FusedSpec(predicates=preds,
                     compares=(Compare("disc", "qty"),),
                     emit=("key", "qty"))
    tmp = tempfile.mkdtemp(prefix="fusedprop")
    try:
        path, tbl = _write(tmp, f"sel{seed}", n_rows,
                           CONFIGS[cfg_name], seed, 900, 3)
        cols = ["ship", "disc", "qty", "key"]
        ref = _scan_fused(path, cols, spec.with_mode("reference"), "pallas")
        for backend in ("pallas", "host"):
            got = _scan_fused(path, cols, spec, backend)
            _assert_bitwise(got, ref)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    mask = _oracle_sum(tbl, spec)
    sel = np.concatenate([r.gathered["key"] for r in ref]) \
        if ref else np.zeros(0, np.int64)
    np.testing.assert_array_equal(sel, tbl["key"][mask])
    if pshape == 1:
        assert sel.shape[0] == 0
    if pshape == 2:
        assert all(r.n_selected == r.n_rows for r in ref)


# ---------------------------------------------------------------------------
# deterministic units: launch economy, zone skipping, Q6/Q12 wiring
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def q6_file(tmp_path_factory):
    d = tmp_path_factory.mktemp("fusedq6")
    metas = tpch.write_tpch(str(d), sf=0.004,
                            config=ACCELERATOR_OPTIMIZED.replace(
                                rows_per_rg=8_000,
                                target_pages_per_chunk=10),
                            seed=21)
    line, _ = tpch.generate_tables(sf=0.004, seed=21)
    return str(d / "lineitem.tab"), metas["lineitem"], line


def test_q6_fused_plan_shape(q6_file):
    """The Q6 spec must actually fuse on the paper's optimized config:
    shipdate (DELTA) decodes in stage A, disc/qty/price go late into one
    kernel — 2 launches per row group instead of 3+."""
    path, meta, _ = q6_file
    sc = Scanner(path, Q6_COLUMNS, decode_backend="pallas",
                 fused_spec=q6_fused_spec())
    fp = sc.planner.fused_plan_rg(0)
    assert fp.ok, fp.why
    assert set(fp.late) == {"l_discount", "l_quantity", "l_extendedprice"}
    assert [op.kind for op in fp.operands] == ["dict", "dict", "plain"]


def test_q6_fused_launch_economy(q6_file):
    path, meta, _ = q6_file
    def launches(fused):
        sc = Scanner(path, Q6_COLUMNS, decode_backend="pallas",
                     fused_spec=q6_fused_spec() if fused else None)
        n0 = kernel_launch_count()
        for _ in sc.scan():
            pass
        return kernel_launch_count() - n0
    n_rg = len(meta.row_groups)
    lf, lu = launches(True), launches(False)
    assert lf < lu                       # strictly fewer, the CI gate
    assert lf <= 2 * n_rg                # ≤ stage-A group + fused kernel


def test_q6_fused_bitwise_and_oracle(q6_file):
    path, _, line = q6_file
    got_f, _ = q6(Scanner(path, Q6_COLUMNS, decode_backend="pallas"),
                  fused=True)
    got_r, _ = q6(Scanner(path, Q6_COLUMNS, decode_backend="pallas"),
                  fused="reference")
    assert struct.pack("<d", got_f) == struct.pack("<d", got_r)
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    assert got_f == pytest.approx(ref, rel=1e-5)


def test_zone_maps_skip_pages(tmp_path):
    """A predicate on a sorted column must skip whole pages via the
    writer's per-page vmin/vmax stamps — before any arena byte exists."""
    n = 4000
    tbl = Table({
        "ship": np.arange(n, dtype=np.int32),
        "disc": np.full(n, 0.05, dtype=np.float32),
        "price": np.linspace(1, 2, n).astype(np.float32),
    })
    path = str(tmp_path / "zone.tab")
    write_table(tbl, path, ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=n, target_pages_per_chunk=8))
    # predicate on the *late* dict column can't zone-skip (constant), but
    # the sorted late-fusable ship interval can: select one narrow band
    spec = FusedSpec(predicates=(Interval("ship", lo=100, hi=200),),
                     agg=SumProduct("price", "disc"))
    sc = Scanner(path, ["ship", "disc", "price"], decode_backend="pallas",
                 fused_spec=spec)
    fp = sc.planner.fused_plan_rg(0)
    (_, cols), = list(sc.scan())
    res = cols[FUSED_KEY]
    if "ship" in fp.late:
        assert res.pages_skipped > 0            # zone maps did the work
    else:
        # ship stayed in stage A: selection-skip covers the same pages
        assert res.pages_skipped >= fp.n_pages - 2
    ref = Scanner(path, ["ship", "disc", "price"], decode_backend="pallas",
                  fused_spec=spec.with_mode("reference"))
    (_, rcols), = list(ref.scan())
    assert res.partials.tobytes() == rcols[FUSED_KEY].partials.tobytes()


def test_fused_requires_plan(tmp_path):
    tbl = Table({"x": np.arange(64, dtype=np.int32)})
    path = str(tmp_path / "t.tab")
    write_table(tbl, path, CPU_DEFAULT)
    with pytest.raises(ValueError, match="use_plan"):
        Scanner(path, ["x"], use_plan=False, fused_spec=q6_fused_spec())


def test_fused_spec_validation():
    with pytest.raises(ValueError):
        FusedSpec()                              # selection needs predicates
    with pytest.raises(ValueError):
        FusedSpec(predicates=(Interval("a", lo=0),),
                  agg=SumProduct("a", "b"), emit=("c",))
    s = q6_fused_spec()
    assert s.with_mode("reference").mode == "reference"
    assert s.columns()[0] == "l_shipdate"
