"""Cross-column DecodePlan: bit-identity vs the per-chunk reference path,
kernel-launch economy, coalesced I/O, and the pread storage layer."""

import os
import threading

import numpy as np
import pytest

from repro.core import (CompressionSpec, EncodingPolicy, FileConfig,
                        StringColumn, Table, write_table)
from repro.core.decode_plan import clear_planner_cache, planner_for
from repro.core.scan import Scanner, open_scanner
from repro.core.storage import (RealStorage, coalesce_ranges,
                                fetch_coalesced)
from repro.kernels.common import kernel_launch_count


def _table(n=6_000, seed=0):
    """Columns chosen so FLEX picks every encoding the planner groups:
    DELTA (sorted), RLE_DICTIONARY (low-card int/float/string),
    RLE (runs/bool), BYTE_STREAM_SPLIT (f32 noise), plus host-path
    types (f64, strings)."""
    rng = np.random.default_rng(seed)
    return Table({
        "sorted64": np.cumsum(rng.integers(0, 9, n)).astype(np.int64),
        "sorted32": np.cumsum(rng.integers(0, 5, n)).astype(np.int32),
        "lowcard": rng.integers(0, 11, n).astype(np.int32),
        "lowcard64": rng.integers(0, 7, n).astype(np.int64),
        "f32dict": rng.integers(0, 9, n).astype(np.float32) / 8.0,
        "f32noise": rng.normal(size=n).astype(np.float32),
        "f64": rng.normal(size=n).astype(np.float64),
        "flags": rng.random(n) < 0.2,
        "runs": np.repeat(np.arange(-(-n // 500), dtype=np.int32), 500)[:n],
        "strs": StringColumn.from_pylist([f"s{i % 23}" for i in range(n)]),
    })


def _cfg(codec: str, pages: int, rows_per_rg: int = 2_500) -> FileConfig:
    return FileConfig(rows_per_rg=rows_per_rg,
                      target_pages_per_chunk=pages,
                      encodings=EncodingPolicy.FLEX,
                      compression=CompressionSpec(codec=codec,
                                                  min_gain=0.05))


def _assert_results_identical(a, b, name):
    if isinstance(a.array, StringColumn) or isinstance(b.array, StringColumn):
        assert type(a.array) is type(b.array), name
        np.testing.assert_array_equal(a.array.offsets, b.array.offsets,
                                      err_msg=name)
        np.testing.assert_array_equal(a.array.payload, b.array.payload,
                                      err_msg=name)
    else:
        ra, rb = np.asarray(a.array), np.asarray(b.array)
        assert ra.dtype == rb.dtype, name
        np.testing.assert_array_equal(ra, rb, err_msg=name)
    assert a.on_device == b.on_device, name
    assert a.n_values == b.n_values, name
    assert a.logical_bytes == b.logical_bytes, name
    assert a.stored_bytes == b.stored_bytes, name


@pytest.mark.parametrize("codec", ["none", "gzip", "cascade"])
@pytest.mark.parametrize("pages", [1, 7])
@pytest.mark.parametrize("backend", ["host", "pallas"])
def test_plan_bit_identical(tmp_path, codec, pages, backend):
    """Plan-path DecodeResults equal the per-chunk reference path across
    encodings × codecs × (single/multi page) for both backends."""
    tbl = _table()
    path = str(tmp_path / f"t_{codec}_{pages}.tab")
    write_table(tbl, path, _cfg(codec, pages))
    ref = Scanner(path, decode_backend=backend, use_plan=False)
    pln = Scanner(path, decode_backend=backend, use_plan=True)
    for i in ref.plan():
        raws_r, _ = ref.fetch_rg(i)
        raws_p, _ = pln.fetch_rg(i)
        cols_r, _ = ref.decode_rg(i, raws_r)
        cols_p, _ = pln.decode_rg(i, raws_p)
        for name in tbl.columns:
            _assert_results_identical(cols_p[name], cols_r[name],
                                      f"rg{i}:{name}:{codec}:{pages}")


@pytest.mark.parametrize("backend", ["host", "pallas"])
def test_plan_bit_identical_ragged_pages(tmp_path, backend):
    """Columns see ragged page counts when rows_per_rg doesn't divide the
    page size evenly; the plan's class padding must not leak."""
    tbl = _table(n=5_117)  # prime-ish → ragged last pages everywhere
    path = str(tmp_path / "ragged.tab")
    write_table(tbl, path, _cfg("none", 13, rows_per_rg=1_777))
    ref = Scanner(path, decode_backend=backend, use_plan=False)
    pln = Scanner(path, decode_backend=backend, use_plan=True)
    for i in ref.plan():
        raws, _ = ref.fetch_rg(i)
        cols_r, _ = ref.decode_rg(i, raws)
        cols_p, _ = pln.decode_rg(i, raws)
        for name in tbl.columns:
            _assert_results_identical(cols_p[name], cols_r[name],
                                      f"rg{i}:{name}")


def test_plan_launch_count_drops(tmp_path):
    """The tentpole claim: a multi-column row group decodes in O(encoding
    groups) Pallas launches instead of O(columns × stride groups)."""
    n = 4_000
    rng = np.random.default_rng(3)
    # four dictionary columns with identical code bitwidth → ONE group
    tbl = Table({f"d{k}": rng.integers(0, 9, n).astype(np.int32)
                 for k in range(4)})
    path = str(tmp_path / "launch.tab")
    write_table(tbl, path, FileConfig(
        rows_per_rg=n, target_pages_per_chunk=8,
        encodings=EncodingPolicy.V1_ONLY,
        compression=CompressionSpec(codec="none")))

    ref = Scanner(path, decode_backend="pallas", use_plan=False)
    raws, _ = ref.fetch_rg(0)
    l0 = kernel_launch_count()
    ref.decode_rg(0, raws)
    ref_launches = kernel_launch_count() - l0
    assert ref_launches == 4          # one per column chunk

    pln = Scanner(path, decode_backend="pallas", use_plan=True)
    plan = pln.planner.plan_rg(0)
    assert plan.n_groups == 1         # same (encoding, codec, width) class
    l0 = kernel_launch_count()
    cols, _ = pln.decode_rg(0, raws)
    plan_launches = kernel_launch_count() - l0
    assert plan_launches == plan.n_groups == 1
    assert plan_launches < ref_launches
    # and the batched result is still right
    for k in range(4):
        np.testing.assert_array_equal(np.asarray(cols[f"d{k}"].array),
                                      np.asarray(tbl[f"d{k}"]))


def test_plan_cache_hits(tmp_path):
    """Plans are cached per (footer, columns, backend): a second scanner
    over the same file re-uses the planner and builds nothing."""
    tbl = _table(n=2_000)
    path = str(tmp_path / "cache.tab")
    write_table(tbl, path, _cfg("none", 4))
    clear_planner_cache()
    s1 = Scanner(path, columns=["lowcard", "sorted32"],
                 decode_backend="host")
    for i in s1.plan():
        raws, _ = s1.fetch_rg(i)
        s1.decode_rg(i, raws)
    built = s1.planner.plans_built
    assert built > 0
    s2 = Scanner(path, columns=["lowcard", "sorted32"],
                 decode_backend="host")
    assert s2.planner is s1.planner
    for i in s2.plan():
        raws, _ = s2.fetch_rg(i)
        s2.decode_rg(i, raws)
    assert s2.planner.plans_built == built   # all cache hits
    # different column selection → different plan cache entry
    s3 = Scanner(path, columns=["lowcard"], decode_backend="host")
    assert s3.planner is not s1.planner


def test_plan_cache_invalidated_on_rewrite(tmp_path):
    """Rewriting a file in place must not reuse the old footer's plan —
    stale page offsets would decode garbage silently."""
    import time as _time
    path = str(tmp_path / "rw.tab")
    write_table(_table(n=2_000, seed=1), path, _cfg("none", 4))
    s1 = Scanner(path, columns=["lowcard"], decode_backend="host")
    raws, _ = s1.fetch_rg(0)
    s1.decode_rg(0, raws)
    _time.sleep(0.01)  # ensure a distinct mtime_ns
    tbl2 = _table(n=2_000, seed=9)
    write_table(tbl2, path, _cfg("none", 7))
    s2 = Scanner(path, columns=["lowcard"], decode_backend="host")
    assert s2.planner is not s1.planner
    raws, _ = s2.fetch_rg(0)
    cols, _ = s2.decode_rg(0, raws)
    np.testing.assert_array_equal(
        np.asarray(cols["lowcard"].array),
        np.asarray(tbl2["lowcard"])[:cols["lowcard"].n_values])


def test_dict_group_split_cap(tmp_path, monkeypatch):
    """Multi-column dict groups split per column (shared-dict kernel) when
    the per-page dictionary arena would exceed the cap."""
    from repro.core import decode_plan as dp
    n = 2_000
    rng = np.random.default_rng(5)
    tbl = Table({f"d{k}": rng.integers(0, 9, n).astype(np.int32)
                 for k in range(3)})
    path = str(tmp_path / "split.tab")
    write_table(tbl, path, FileConfig(
        rows_per_rg=n, target_pages_per_chunk=4,
        encodings=EncodingPolicy.V1_ONLY,
        compression=CompressionSpec(codec="none")))
    monkeypatch.setattr(dp, "_DICT_ARENA_CAP_BYTES", 1)
    clear_planner_cache()
    sc = Scanner(path, decode_backend="pallas")
    plan = sc.planner.plan_rg(0)
    assert plan.n_groups == 3          # split per column under the cap
    raws, _ = sc.fetch_rg(0)
    l0 = kernel_launch_count()
    cols, _ = sc.decode_rg(0, raws)
    assert kernel_launch_count() - l0 == 3
    for k in range(3):
        np.testing.assert_array_equal(np.asarray(cols[f"d{k}"].array),
                                      np.asarray(tbl[f"d{k}"]))
    clear_planner_cache()


# -- coalesced I/O -----------------------------------------------------------

def test_coalesce_ranges_merges_and_maps():
    ranges = [(0, 100), (100, 50), (200, 30), (10_000, 5)]
    merged, index = coalesce_ranges(ranges, gap=64)
    assert merged == [(0, 230), (10_000, 5)]
    assert index == [(0, 0), (0, 100), (0, 200), (1, 0)]
    # zero gap: only strictly adjacent ranges merge
    merged2, _ = coalesce_ranges(ranges, gap=0)
    assert merged2 == [(0, 150), (200, 30), (10_000, 5)]
    # unsorted input maps back correctly
    merged3, index3 = coalesce_ranges([(200, 30), (0, 100)], gap=1_000)
    assert merged3 == [(0, 230)]
    assert index3 == [(0, 200), (0, 0)]


def test_fetch_coalesced_bytes_equal(tmp_path):
    path = str(tmp_path / "blob.bin")
    rng = np.random.default_rng(0)
    blob = rng.integers(0, 256, 100_000, dtype=np.uint16
                        ).astype(np.uint8).tobytes()
    with open(path, "wb") as f:
        f.write(blob)
    st = RealStorage(path)
    ranges = [(0, 1_000), (1_200, 500), (50_000, 1), (1_700, 300)]
    views, _ = fetch_coalesced(st, ranges, gap=4_096)
    for (off, size), view in zip(ranges, views):
        assert bytes(view) == blob[off:off + size]
    # the three near-adjacent ranges merged into one request
    assert st.stats.requests == 2
    assert st.stats.batches == 1
    assert st.stats.last_batch_requests == 2


def test_scanner_fetch_rg_coalesces(tmp_path):
    """A row group's column chunks are adjacent on disk → one request."""
    tbl = _table(n=3_000)
    path = str(tmp_path / "co.tab")
    write_table(tbl, path, _cfg("none", 4, rows_per_rg=3_000))
    sc = open_scanner(path, backend="sim", n_lanes=1,
                      decode_backend="host")
    raws, _ = sc.fetch_rg(0)
    assert sc.storage.stats.requests == 1
    assert sc.storage.stats.last_batch_requests == 1
    # gap=0 still merges strictly adjacent chunks but the column subset
    # below leaves holes → more requests
    sc2 = open_scanner(path, columns=["sorted64", "f64"], backend="sim",
                       n_lanes=1, decode_backend="host", coalesce_gap=0)
    sc2.fetch_rg(0)
    assert sc2.storage.stats.requests == 2
    # and decode still works on the coalesced views
    cols, _ = sc.decode_rg(0, raws)
    np.testing.assert_array_equal(np.asarray(cols["lowcard"].array),
                                  np.asarray(tbl["lowcard"]))


def test_real_storage_pread_concurrent(tmp_path):
    """os.pread fetches don't serialize on (or corrupt) a shared file
    position across the I/O and decode threads."""
    path = str(tmp_path / "c.bin")
    blob = bytes(range(256)) * 4_000
    with open(path, "wb") as f:
        f.write(blob)
    st = RealStorage(path)
    errs = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        for _ in range(200):
            off = int(rng.integers(0, len(blob) - 512))
            data = st.fetch(off, 512)
            if data != blob[off:off + 512]:
                errs.append(off)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert st.stats.requests == 800
    st.close()
