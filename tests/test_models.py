"""Per-arch smoke tests (reduced configs): one forward/train step on CPU
asserting output shapes + no NaNs; decode consistency for cache-bearing
archs; MoE/SSM unit behaviours."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, concrete_inputs, get_arch, smoke_config
from repro.models.config import MoEConfig
from repro.models.model import Model


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke_train(name):
    cfg = smoke_config(name)
    arch = dataclasses.replace(get_arch(name), config=cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_inputs(arch, "train_4k", batch=2, seq_len=64)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    assert np.isfinite(float(metrics["aux_loss"]))


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke_grad_step(name):
    cfg = smoke_config(name)
    arch = dataclasses.replace(get_arch(name), config=cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_inputs(arch, "train_4k", batch=2, seq_len=32)

    def loss_fn(p):
        return model.train_loss(p, batch)[0]

    g = jax.jit(jax.grad(loss_fn))(params)
    flat = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in flat), name
    gnorm = float(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in flat))
    assert gnorm > 0, f"{name}: zero gradient"


@pytest.mark.parametrize("name", [a for a in ARCHS
                                  if not get_arch(a).config.encoder_only])
def test_arch_prefill_decode_shapes(name):
    cfg = smoke_config(name)
    arch = dataclasses.replace(get_arch(name), config=cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_caches(b=2, s_max=80)
    pre = concrete_inputs(arch, "prefill_32k", batch=2, seq_len=48)
    logits, caches = jax.jit(model.prefill)(params, pre, caches)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, _ = jax.jit(model.decode_step)(
        params, tok, jnp.asarray(48, jnp.int32), caches)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2))), name


@pytest.mark.parametrize("name", ["granite-3-8b", "gemma2-2b",
                                  "mamba2-2.7b", "zamba2-7b",
                                  "deepseek-coder-33b"])
def test_decode_consistency(name):
    """prefill(t0..tn)+decode(t_{n+1}) == prefill(t0..t_{n+1})."""
    cfg = smoke_config(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    s = 29
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 1)),
                       jnp.int32)
    c1 = model.init_caches(b=2, s_max=s + 8)
    full, _ = jax.jit(model.prefill)(params, {"tokens": toks}, c1)
    c2 = model.init_caches(b=2, s_max=s + 8)
    _, c2 = jax.jit(model.prefill)(params, {"tokens": toks[:, :s]}, c2)
    dec, _ = jax.jit(model.decode_step)(
        params, toks[:, s:], jnp.asarray(s, jnp.int32), c2)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-2, f"{name}: rel={rel}"


@pytest.mark.parametrize("name", ["mixtral-8x22b", "deepseek-v3-671b"])
def test_decode_consistency_moe_nodrop(name):
    """MoE consistency holds under no-drop capacity (serve semantics)."""
    cfg = smoke_config(name)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    s = 21
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 1)),
                       jnp.int32)
    c1 = model.init_caches(b=2, s_max=s + 8)
    full, _ = jax.jit(model.prefill)(params, {"tokens": toks}, c1)
    c2 = model.init_caches(b=2, s_max=s + 8)
    _, c2 = jax.jit(model.prefill)(params, {"tokens": toks[:, :s]}, c2)
    dec, _ = jax.jit(model.decode_step)(
        params, toks[:, s:], jnp.asarray(s, jnp.int32), c2)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-2, f"{name}: rel={rel}"


def test_param_counts_match_published():
    expected = {
        "minitron-8b": (8.8e9, 0.1), "granite-3-8b": (8.2e9, 0.1),
        "gemma2-2b": (2.6e9, 0.15), "deepseek-coder-33b": (33.1e9, 0.1),
        "mamba2-2.7b": (2.7e9, 0.1), "deepseek-v3-671b": (671e9, 0.05),
        "mixtral-8x22b": (141e9, 0.05), "zamba2-7b": (7e9, 0.15),
    }
    for name, (target, tol) in expected.items():
        n = get_arch(name).config.param_count()
        assert abs(n - target) / target < tol, (name, n)
    # MoE active params (DeepSeek-V3 reports 37B, Mixtral 39B)
    assert abs(get_arch("deepseek-v3-671b").config.active_param_count()
               - 37e9) / 37e9 < 0.05
    assert abs(get_arch("mixtral-8x22b").config.active_param_count()
               - 39e9) / 39e9 < 0.05


def test_moe_aux_loss_balances():
    from repro.models.moe import init_moe_params, moe_forward
    cfg = smoke_config("mixtral-8x22b")
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 0.0
    # perfectly uniform routing would give aux = weight; ours is close-ish
    assert float(aux) < 10 * cfg.moe.aux_loss_weight


def test_ssm_long_context_state_is_constant_size():
    from repro.models.ssm import init_ssm_state
    cfg = smoke_config("mamba2-2.7b")
    s1 = init_ssm_state(1, cfg, jnp.float32)
    total = sum(np.prod(x.shape) for x in jax.tree.leaves(s1))
    assert total < 1e6    # O(1) in sequence length


def test_window_cache_bounded():
    cfg = smoke_config("mixtral-8x22b")   # window 16 in smoke
    model = Model(cfg)
    caches = model.init_caches(b=1, s_max=1000)
    k = caches["segments"][0]["pos0"]["k"]
    assert k.shape[2] == cfg.window       # (steps, B, W, KV, dh)


def test_gemma2_softcap_applied():
    cfg = smoke_config("gemma2-2b")
    assert cfg.attn_softcap == 50.0 and cfg.final_softcap == 30.0
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # logits must be bounded by the final softcap
    caches = model.init_caches(b=1, s_max=16)
    toks = jnp.asarray(np.arange(8)[None], jnp.int32)
    logits, _ = jax.jit(model.prefill)(params, {"tokens": toks}, caches)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-3


def test_decode_consistency_int8_cache():
    """int8 KV cache decode stays within quantization tolerance."""
    cfg = dataclasses.replace(smoke_config("granite-3-8b"),
                              kv_cache_dtype="int8")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    s = 29
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 1)),
                       jnp.int32)
    c1 = model.init_caches(b=2, s_max=s + 8)
    assert c1["segments"][0]["pos0"]["k"].dtype == jnp.int8
    full, _ = jax.jit(model.prefill)(params, {"tokens": toks}, c1)
    c2 = model.init_caches(b=2, s_max=s + 8)
    _, c2 = jax.jit(model.prefill)(params, {"tokens": toks[:, :s]}, c2)
    dec, _ = jax.jit(model.decode_step)(
        params, toks[:, s:], jnp.asarray(s, jnp.int32), c2)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 5e-2, rel
