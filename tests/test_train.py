"""Optimizer, checkpoint manager, FT runner, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (OptConfig, apply_adamw, global_norm,
                                   init_opt_state, lr_at)


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_at(jnp.asarray(s), cfg)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=0.01)
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)
    assert lrs[1] < lrs[2]             # warmup rising


@pytest.mark.parametrize("moments_dtype", ["float32", "bfloat16"])
def test_adamw_converges_quadratic(moments_dtype):
    cfg = OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=300,
                    weight_decay=0.0, clip_norm=0.0,
                    moments_dtype=moments_dtype)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_adamw(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping():
    cfg = OptConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = apply_adamw(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_no_decay_on_vectors():
    cfg = OptConfig(peak_lr=0.0, weight_decay=1.0, warmup_steps=0,
                    total_steps=10)
    params = {"scale": jnp.ones(8), "w": jnp.ones((4, 4))}
    state = init_opt_state(params, cfg)
    g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = apply_adamw(params, g, state, cfg)
    np.testing.assert_array_equal(np.asarray(new["scale"]), np.ones(8))


# -- checkpointing -------------------------------------------------------------

def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"params": {"a": jax.random.normal(k, (8, 8)),
                       "nested": [jnp.arange(4.0), None]},
            "opt": {"step": jnp.asarray(seed)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = _tree(3)
    mgr.save(3, state, extra={"step": 3, "loader": {"r": 7}})
    restored, extra = mgr.restore()
    assert extra["step"] == 3 and extra["loader"]["r"] == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(state["params"]["a"]))
    assert restored["params"]["nested"][1] is None
    assert int(restored["opt"]["step"]) == 3


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), extra={"step": s})
    assert mgr.list_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_torn_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree(1), extra={"step": 1})
    # fake a torn write: directory without COMMITTED marker
    os.makedirs(str(tmp_path / "step_000000002" / "arrays"))
    with open(str(tmp_path / "step_000000002" / "manifest.json"),
              "w") as f:
        f.write("{}")
    assert mgr.latest_step() == 1
    restored, extra = mgr.restore()
    assert extra["step"] == 1


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _tree(5), extra={"step": 5})
    mgr.wait()
    _, extra = mgr.restore()
    assert extra["step"] == 5


def test_elastic_restore_new_sharding(tmp_path):
    """Cross-mesh restore: place restored leaves with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, extra={"step": 1})
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = mgr.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


# -- FT runner -------------------------------------------------------------------

def test_runner_preemption_resume(tmp_path):
    from repro.configs import smoke_config
    from repro.core.config import ACCELERATOR_OPTIMIZED
    from repro.data.loader import TabLoader
    from repro.data.tokens import write_corpus
    from repro.models.model import Model
    from repro.train.runner import (RunnerConfig, SimulatedPreemption,
                                    TrainRunner)
    corpus = str(tmp_path / "c.tab")
    cfg = smoke_config("gemma2-2b")
    write_corpus(corpus, 120_000, cfg.vocab_size,
                 ACCELERATOR_OPTIMIZED.replace(rows_per_rg=60_000,
                                               target_pages_per_chunk=8))
    model = Model(cfg)
    opt = OptConfig(peak_lr=5e-4, warmup_steps=2, total_steps=20)

    def mk(fail=None):
        return TrainRunner(
            model, opt, TabLoader(corpus, seq_len=32, batch_per_shard=2),
            str(tmp_path / "ckpt"),
            RunnerConfig(total_steps=14, save_every=7, log_every=7,
                         fail_at_step=fail))

    with pytest.raises(SimulatedPreemption):
        mk(fail=9).run()
    out = mk().run()
    assert out["final_step"] == 14
    losses = [h["loss"] for h in out["history"]]
    assert all(np.isfinite(l) for l in losses)


# -- sharding rules ------------------------------------------------------------------

def test_param_pspecs_rules():
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import param_pspecs
    params = {
        "embed": jnp.zeros((1600, 64)),
        "segments": [{"pos0": {"attn": {"wq": jnp.zeros((64, 128)),
                                        "wo": jnp.zeros((128, 64))},
                               "norm1": jnp.zeros((64,))}}],
    }
    specs = param_pspecs(params, zero=False, mesh_axes=("data", "model"),
                         mesh_sizes={"data": 4, "model": 16})
    assert specs["embed"] == P("model", None)
    seg = specs["segments"][0]["pos0"]
    assert seg["attn"]["wq"] == P(None, "model")
    assert seg["attn"]["wo"] == P("model", None)
    assert seg["norm1"] == P(None)


def test_fit_spec_relocates_model_axis():
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import fit_spec
    # 49155 vocab is not divisible by 16 → TP moves to d_model dim
    s = fit_spec((49155, 4096), ("model", None), ("data", "model"),
                 {"data": 16, "model": 16})
    assert s == P(None, "model")
    # divisible stays put
    s = fit_spec((256000, 4096), ("model", None), ("data", "model"),
                 {"data": 16, "model": 16})
    assert s == P("model", None)


def test_constrain_noop_without_mesh():
    from repro.parallel.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
