import numpy as np

from repro.core import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TabFileReader,
                        write_table)
from repro.core.rewriter import rewrite_file
from repro.data import tpch


def test_rewrite_preserves_data_changes_geometry(tmp_path):
    line, _ = tpch.generate_tables(sf=0.002, seed=3)
    src = str(tmp_path / "src.tab")
    dst = str(tmp_path / "dst.tab")
    write_table(line, src, CPU_DEFAULT.replace(rows_per_rg=3_000))
    rep = rewrite_file(src, dst, ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=5_000, target_pages_per_chunk=20), threads=2)
    back = TabFileReader(dst).read_table()
    assert back.equals(line)
    meta = TabFileReader(dst).meta
    assert meta.row_groups[0].n_rows == 5_000
    assert max(len(c.pages) for c in meta.row_groups[0].columns) == 20
    assert rep.rows == line.num_rows
    assert rep.seconds > 0
    # the paper's §5 claim: rewriting usually shrinks (FLEX encodings)
    assert rep.dst_describe["compression_ratio"] > 0


def test_rewrite_rebuckets_small_rgs(tmp_path):
    line, _ = tpch.generate_tables(sf=0.002, seed=4)
    src = str(tmp_path / "s2.tab")
    dst = str(tmp_path / "d2.tab")
    write_table(line, src, CPU_DEFAULT.replace(rows_per_rg=1_000))
    n_src_rgs = len(TabFileReader(src).meta.row_groups)
    rewrite_file(src, dst, ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=1_000_000))
    meta = TabFileReader(dst).meta
    assert len(meta.row_groups) == 1 < n_src_rgs
    assert TabFileReader(dst).read_table().equals(line)


def test_rewrite_column_projection(tmp_path):
    line, _ = tpch.generate_tables(sf=0.001, seed=5)
    src = str(tmp_path / "s3.tab")
    dst = str(tmp_path / "d3.tab")
    write_table(line, src, CPU_DEFAULT)
    rewrite_file(src, dst, ACCELERATOR_OPTIMIZED,
                 columns=["l_orderkey", "l_quantity"])
    back = TabFileReader(dst).read_table()
    assert back.names == ["l_orderkey", "l_quantity"]
    assert back.equals(line.select(["l_orderkey", "l_quantity"]))
