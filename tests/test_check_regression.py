"""CI perf-gate logic (tools/check_regression.py): CSV parsing, the
wall-time threshold, the exact counter gate, and coverage loss."""

import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import check_regression as cr  # noqa: E402


def _write_csv(path, rows):
    with open(path, "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in rows:
            f.write(r + "\n")


def test_parse_csv_extracts_counters(tmp_path):
    p = str(tmp_path / "b.csv")
    _write_csv(p, [
        "q6_overlapped,1234.5,lower_bound_us=268;x_over_bound=4.6",
        "plan_launches,90.0,launches_per_rg=5;pallas-interpret;measured",
        "io_coalesced,50.0,requests=3;speedup=4.00x;sim",
    ])
    rows = cr.parse_csv(p)
    assert rows["q6_overlapped"][0] == pytest.approx(1234.5)
    assert rows["q6_overlapped"][1] == {}          # non-counter keys ignored
    assert rows["plan_launches"][1] == {"launches_per_rg": 5.0}
    assert rows["io_coalesced"][1] == {"requests": 3.0}


def test_clean_run_passes():
    base = {"a": (1000.0, {"launches": 4.0})}
    cur = {"a": (1200.0, {"launches": 4.0})}       # +20% < 25%
    regs, table = cr.compare(base, cur, 0.25, 500.0)
    assert regs == []
    assert table[0][-1] == "ok"


def test_wall_regression_trips():
    base = {"a": (1000.0, {})}
    cur = {"a": (1300.0, {})}                      # +30%
    regs, _ = cr.compare(base, cur, 0.25, 500.0)
    assert len(regs) == 1 and "wall" in regs[0]


def test_wall_noise_floor_skips_tiny_rows():
    base = {"cache_hit": (10.0, {})}
    cur = {"cache_hit": (30.0, {})}                # 3x but microseconds
    regs, _ = cr.compare(base, cur, 0.25, 500.0)
    assert regs == []


def test_any_counter_increase_trips():
    base = {"a": (1000.0, {"requests": 8.0})}
    cur = {"a": (900.0, {"requests": 9.0})}        # faster but chattier
    regs, _ = cr.compare(base, cur, 0.25, 500.0)
    assert len(regs) == 1 and "requests" in regs[0]
    # decreases are fine
    regs2, _ = cr.compare(base, {"a": (900.0, {"requests": 7.0})},
                          0.25, 500.0)
    assert regs2 == []


def test_missing_counter_token_trips():
    """Dropping a gated counter from the derived column must not silently
    disable its gate."""
    base = {"a": (1000.0, {"launches": 4.0})}
    cur = {"a": (1000.0, {})}
    regs, _ = cr.compare(base, cur, 0.25, 500.0)
    assert len(regs) == 1 and "missing" in regs[0]


def test_missing_row_is_coverage_loss():
    base = {"a": (1000.0, {}), "b": (1000.0, {})}
    cur = {"a": (1000.0, {})}
    regs, _ = cr.compare(base, cur, 0.25, 500.0)
    assert len(regs) == 1 and "missing" in regs[0]


def test_new_rows_do_not_trip():
    base = {"a": (1000.0, {})}
    cur = {"a": (1000.0, {}), "brand_new": (5.0, {})}
    regs, table = cr.compare(base, cur, 0.25, 500.0)
    assert regs == []
    assert any("new (no baseline)" in row[-1] for row in table)


def test_cli_end_to_end_pass_and_fail(tmp_path):
    basedir = tmp_path / "baselines"
    curdir = tmp_path / "current"
    basedir.mkdir()
    curdir.mkdir()
    _write_csv(str(basedir / "fig5_smoke.csv"),
               ["q6,1000.0,launches=4;sim"])
    _write_csv(str(curdir / "fig5_smoke.csv"),
               ["q6,1050.0,launches=4;sim"])
    tool = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "check_regression.py")
    report = str(tmp_path / "report.md")
    ok = subprocess.run(
        [sys.executable, tool, "--baseline", str(basedir), "--current",
         str(curdir), "--report", report, "fig5_smoke.csv"],
        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert os.path.exists(report)
    # inject: doubled wall + one extra launch
    _write_csv(str(curdir / "fig5_smoke.csv"),
               ["q6,2000.0,launches=5;sim"])
    bad = subprocess.run(
        [sys.executable, tool, "--baseline", str(basedir), "--current",
         str(curdir), "--report", report, "fig5_smoke.csv"],
        capture_output=True, text=True)
    assert bad.returncode == 1
    assert "wall" in bad.stdout and "launches" in bad.stdout
    with open(report) as f:
        text = f.read()
    assert "REGRESSIONS" in text


def test_speed_scale_normalizes_slower_machine():
    base = {"cpu_reference": (1000.0, {}), "a": (10000.0, {})}
    # machine 2x slower; row +90% raw — normalized it's 5% faster
    cur = {"cpu_reference": (2000.0, {}), "a": (19000.0, {})}
    scale = cr.speed_scale(base, cur)
    assert scale == pytest.approx(0.5)
    regs, _ = cr.compare(base, cur, 0.25, 500.0, scale)
    assert regs == []
    # a real regression still trips through the normalization
    cur2 = {"cpu_reference": (2000.0, {}), "a": (30000.0, {})}
    regs2, _ = cr.compare(base, cur2, 0.25, 500.0,
                          cr.speed_scale(base, cur2))
    assert len(regs2) == 1 and "wall" in regs2[0]


def test_speed_scale_clamped_and_optional():
    assert cr.speed_scale({"a": (1.0, {})}, {"a": (1.0, {})}) == 1.0
    base = {"cpu_reference": (10000.0, {})}
    assert cr.speed_scale(base, {"cpu_reference": (100.0, {})}) == 4.0
    assert cr.speed_scale(base, {"cpu_reference": (1e9, {})}) == 0.25


def test_merge_min_takes_faster_run_per_row():
    a = {"x": (1000.0, {"launches": 4.0}), "only_a": (5.0, {})}
    b = {"x": (800.0, {"launches": 4.0}), "only_b": (7.0, {})}
    merged = cr.merge_min(a, b)
    assert merged["x"][0] == 800.0
    assert merged["only_a"][0] == 5.0 and merged["only_b"][0] == 7.0


def test_selftest_demonstrates_gate():
    assert cr.selftest() == 0
