"""Per-kernel sweeps: Pallas (interpret=True) vs ref.py oracle vs host
numpy decoders, across shapes/dtypes/widths."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from repro.core import bitpack
from repro.core.compression import cascade_compress, cascade_manifest
from repro.kernels import ref
from repro.kernels.bitunpack import bitunpack_pages
from repro.kernels.bss_decode import bss_decode_pages
from repro.kernels.cascade_decode import cascade_decode_pages
from repro.kernels.delta_decode import delta_decode_pages
from repro.kernels.dict_decode import dict_decode_pages
from repro.kernels.filter_agg import TILE, filter_agg_q6
from repro.kernels.rle_decode import rle_decode_pages


@pytest.mark.parametrize("width", [1, 4, 7, 11, 16, 23, 32])
@pytest.mark.parametrize("n_pages", [1, 5])
def test_bitunpack_sweep(width, n_pages):
    rng = np.random.default_rng(width * 7 + n_pages)
    vals = rng.integers(0, 2 ** min(width, 31), size=(n_pages, 352),
                        dtype=np.uint64)
    words = np.stack([bitpack.pack(v, width) for v in vals])
    out = bitunpack_pages(jnp.asarray(words), width=width)
    out_ref = ref.bitunpack_pages_ref(jnp.asarray(words), width=width)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    npt.assert_array_equal(np.asarray(out)[:, :352], vals)


@pytest.mark.parametrize("n_dict,dtype", [
    (5, np.int32), (300, np.int32), (7, np.float32), (64, np.uint32)])
def test_dict_decode_sweep(n_dict, dtype):
    rng = np.random.default_rng(n_dict)
    width = bitpack.bit_width(max(1, n_dict - 1))
    codes = rng.integers(0, n_dict, size=(3, 224), dtype=np.uint64)
    words = np.stack([bitpack.pack(c, width) for c in codes])
    if dtype == np.float32:
        dictionary = rng.normal(size=n_dict).astype(dtype)
    else:
        dictionary = rng.integers(-500, 500, n_dict).astype(dtype)
    out = dict_decode_pages(jnp.asarray(words), jnp.asarray(dictionary),
                            width=width)
    out_ref = ref.dict_decode_pages_ref(jnp.asarray(words),
                                        jnp.asarray(dictionary),
                                        width=width)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    npt.assert_array_equal(np.asarray(out)[:, :224], dictionary[codes])


@pytest.mark.parametrize("n_values", [1025, 4096, 10_000])
def test_delta_decode_sweep(n_values):
    from repro.core.encodings import (build_delta_manifest,
                                      encode_delta_page)
    from repro.core.schema import Field, PhysicalType
    rng = np.random.default_rng(n_values)
    pages = [np.cumsum(rng.integers(-3, 50, n_values)).astype(np.int32)
             for _ in range(3)]
    encoded = [encode_delta_page(p, Field("c", PhysicalType.INT32))
               for p in pages]
    mans = [build_delta_manifest(e.payload, e.n_values, e.extra)
            for e in encoded]
    n_blocks = max(m["n_blocks"] for m in mans)
    n_mb = n_blocks * 4

    def pad2(arrs, w, dt):
        out = np.zeros((len(arrs), w), dt)
        for i, a in enumerate(arrs):
            out[i, :len(a)] = a
        return out

    payload = pad2([np.frombuffer(e.payload, np.uint32) for e in encoded],
                   max(len(e.payload) // 4 for e in encoded), np.uint32)
    mb_off = pad2([m["mb_off"] for m in mans], n_mb, np.int32)
    mb_w = pad2([m["mb_width"] for m in mans], n_mb, np.int32)
    mind = pad2([m["min_delta"].astype(np.int32)[:m["n_blocks"]]
                 for m in mans], n_blocks, np.int32)
    first = np.array([[m["first_value"]] for m in mans], np.int32)
    args = [jnp.asarray(x) for x in
            (payload, mb_off, mb_w, mind, first)]
    out = delta_decode_pages(*args, n_blocks=n_blocks)
    out_ref = ref.delta_decode_pages_ref(*args, n_blocks=n_blocks)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    for i, p in enumerate(pages):
        npt.assert_array_equal(np.asarray(out)[i, :n_values], p)


@pytest.mark.parametrize("max_run", [1, 50, 3000])
def test_rle_decode_sweep(max_run):
    rng = np.random.default_rng(max_run)
    n_runs = 40
    vals = rng.integers(-99, 99, size=(2, n_runs)).astype(np.int32)
    counts = rng.integers(1, max_run + 1,
                          size=(2, n_runs)).astype(np.int32)
    totals = counts.sum(axis=1)
    n_out = -(-int(totals.max()) // 1024) * 1024
    out = rle_decode_pages(jnp.asarray(vals), jnp.asarray(counts),
                           n_out=n_out)
    out_ref = ref.rle_decode_pages_ref(jnp.asarray(vals),
                                       jnp.asarray(counts), n_out=n_out)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    for i in range(2):
        expect = np.repeat(vals[i], counts[i])
        npt.assert_array_equal(np.asarray(out)[i, :totals[i]], expect)


@pytest.mark.parametrize("n", [64, 1000, 4093])
def test_bss_decode_sweep(n):
    rng = np.random.default_rng(n)
    pages = rng.normal(size=(2, n)).astype(np.float32)
    stride = (n + (-n) % 4) // 4

    def pack_page(p):
        planes = p.view(np.uint8).reshape(n, 4)
        body = b"".join(planes[:, s].tobytes()
                        + b"\x00" * ((-n) % 4) for s in range(4))
        return np.frombuffer(body, np.uint32)

    payload = np.stack([pack_page(p) for p in pages])
    out = bss_decode_pages(jnp.asarray(payload), stride_words=stride,
                           n_out=stride * 4)
    out_ref = ref.bss_decode_pages_ref(jnp.asarray(payload),
                                       stride_words=stride,
                                       n_out=stride * 4)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    npt.assert_array_equal(np.asarray(out)[:, :n], pages)


def test_cascade_decode_kernel():
    rng = np.random.default_rng(9)
    raw = np.repeat(rng.integers(0, 30, 50, dtype=np.uint32),
                    rng.integers(1, 200, 50)).tobytes()
    man = cascade_manifest(cascade_compress(raw))
    n_out = -(-man["n_words"] // 1024) * 1024
    out = cascade_decode_pages(
        jnp.asarray(man["value_words"][None]),
        jnp.asarray(man["count_words"][None]),
        value_width=man["value_width"], count_width=man["count_width"],
        n_runs=man["n_runs"], n_out=n_out)
    out_ref = ref.cascade_decode_pages_ref(
        jnp.asarray(man["value_words"][None]),
        jnp.asarray(man["count_words"][None]),
        value_width=man["value_width"], count_width=man["count_width"],
        n_runs=man["n_runs"], n_out=n_out)
    npt.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    expect = np.frombuffer(raw, np.uint32)
    npt.assert_array_equal(np.asarray(out)[0, :man["n_words"]], expect)


def test_filter_agg_q6_kernel():
    rng = np.random.default_rng(10)
    n = TILE * 3
    key = rng.integers(0, 2000, n).astype(np.int32)
    qty = rng.integers(1, 51, n).astype(np.float32)
    disc = (rng.integers(0, 11, n) / 100).astype(np.float32)
    price = rng.normal(1000, 100, n).astype(np.float32)
    kw = dict(lo=731, hi=1096, dlo=0.05, dhi=0.07, qmax=24.0)
    out = filter_agg_q6(jnp.asarray(key), jnp.asarray(qty),
                        jnp.asarray(disc), jnp.asarray(price), **kw)
    out_ref = ref.filter_agg_q6_ref(jnp.asarray(key), jnp.asarray(qty),
                                    jnp.asarray(disc), jnp.asarray(price),
                                    **kw)
    npt.assert_allclose(float(out), float(out_ref), rtol=1e-5)


@pytest.mark.parametrize("b,s,h,kvh,dh,causal,cap", [
    (2, 256, 4, 2, 64, True, 0.0),
    (1, 384, 8, 8, 128, True, 50.0),
    (2, 128, 4, 1, 32, False, 0.0),
])
def test_flash_attention_kernel(b, s, h, kvh, dh, causal, cap):
    import jax
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, cap=cap,
                          q_block=128, kv_block=128)
    # oracle: materialized-softmax attention
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, s, kvh, g, dh)
    sc = jnp.einsum("bqkgd,bskd->bqkgs", qf,
                    k.astype(jnp.float32)) * dh ** -0.5
    if cap:
        sc = cap * jnp.tanh(sc / cap)
    if causal:
        m = np.tril(np.ones((s, s), bool))
        sc = jnp.where(jnp.asarray(m)[None, :, None, None, :], sc, -1e30)
    w = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bqkgs,bskd->bqkgd", w,
                     v.astype(jnp.float32)).reshape(b, s, h, dh)
    npt.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_matches_model_blockwise():
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import blockwise_attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 256, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 64)), jnp.float32)
    a = flash_attention(q, k, v, causal=True)
    b_ = blockwise_attention(q, k, v, causal=True)
    npt.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)
