"""End-to-end behaviour: the paper's pipeline from files to query results,
and the trainer whose input pipeline is the configured scan."""

import numpy as np
import pytest

from repro.core import ACCELERATOR_OPTIMIZED, CPU_DEFAULT, TabFileReader
from repro.core.config import intermediate_configs
from repro.core.query import Q6_COLUMNS, q6, q6_reference
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.data import tpch


def test_paper_pipeline_end_to_end(tmp_path):
    """Write CPU-default files → rewrite accelerator-aware → scan → Q6.

    The configuration ladder must hold the paper's direction: the optimized
    file yields >= effective bandwidth of the baseline under the modeled
    4-lane storage (Fig. 1/3), with identical query answers.
    """
    metas = tpch.write_tpch(str(tmp_path), sf=0.01, config=CPU_DEFAULT,
                            seed=2)
    line, _ = tpch.generate_tables(sf=0.01, seed=2)
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})

    # warm the jitted consumer so compile time never lands in a measurement
    warm = open_scanner(metas["lineitem_path"], columns=Q6_COLUMNS,
                        backend="sim", n_lanes=4, decode_backend="host")
    q6(warm, prune=False)

    paths = {}
    for name, cfg in intermediate_configs().items():
        if name == "baseline":
            paths[name] = metas["lineitem_path"]
        else:
            paths[name] = str(tmp_path / f"line_{name}.tab")
            rewrite_file(metas["lineitem_path"], paths[name], cfg, threads=2)
    # Decode at this tiny scale is a handful of ms, so single measurements
    # are scheduler noise.  Interleave the configurations across rounds so
    # a noisy period penalizes every rung equally, and keep each rung's
    # best round (later rounds also hit the cached decode plan — the
    # serving-loop pattern).
    # decode_workers=0 pins the inline-decode executor: this ladder
    # compares *file layouts*, and the pipelined executor's parallel-decode
    # credit depends on row-group count, which would cross-contaminate the
    # comparison at this scale.  The cross-scan caches are cleared per run
    # for the same reason — a hot decompress memo erases the baseline
    # config's gzip handicap, which is exactly the codec cost this ladder
    # exists to show.  Cache/pipeline behavior is covered by
    # tests/test_pipeline.py.
    from repro.core.compression import chunk_decompress_memo
    from repro.core.scheduler import clear_delivered_windows
    from repro.dataset.result_cache import clear_all_result_caches
    from repro.kernels.dict_decode import dict_cache_clear
    results = {name: 0.0 for name in paths}
    for _ in range(4):
        for name, path in paths.items():
            chunk_decompress_memo().clear()
            dict_cache_clear()
            clear_delivered_windows()       # delivered-result window and
            clear_all_result_caches()       # fragment result cache: a hit
            # in either would skip the very fetch+decode being laddered
            sc = open_scanner(path, columns=Q6_COLUMNS, backend="sim",
                              n_lanes=4, decode_backend="host")
            rev, report = q6(sc, prune=False, decode_workers=0)
            assert abs(rev - ref) / max(1.0, abs(ref)) < 1e-5, name
            # cold arm really refetched (no cache served this round)
            assert sc.storage.stats.requests > 0, name
            results[name] = max(results[name],
                                report.effective_bandwidth())
    # Wall time on this CPU-only container is decode-dominated, and with
    # cross-column batched decode the host cost of the baseline and
    # optimized layouts converges at this tiny scale — so the ladder is
    # asserted with a noise band here; the deterministic separations
    # (kernel-launch and I/O-request economy) are asserted exactly in
    # test_decode_plan.py and measured at scale by the benchmarks.
    assert results["optimized"] >= 0.8 * results["baseline"]
    # at test scale (sf=0.01) the whole table fits one default RG, so the
    # rg_size rung only has to stay in the same band as +pages (the full
    # separation appears at benchmark scale — see benchmarks/fig2b)
    assert results["+rg_size"] >= results["+pages"] * 0.6


def test_trainer_reads_through_scan(tmp_path):
    """The training loader is the scan engine: loss decreases on a corpus
    written with the paper-optimized config."""
    import jax
    from repro.configs import smoke_config
    from repro.data.loader import TabLoader
    from repro.data.tokens import write_corpus
    from repro.models.model import Model
    from repro.train.optimizer import OptConfig
    from repro.train.step import build_train_step, init_train_state

    cfg = smoke_config("minitron-8b")
    corpus = str(tmp_path / "corpus.tab")
    write_corpus(corpus, 150_000, cfg.vocab_size,
                 ACCELERATOR_OPTIMIZED.replace(rows_per_rg=75_000,
                                               target_pages_per_chunk=16))
    model = Model(cfg)
    opt = OptConfig(peak_lr=1e-3, warmup_steps=3, total_steps=30)
    step = jax.jit(build_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    loader = TabLoader(corpus, seq_len=48, batch_per_shard=4)
    losses = []
    for _ in range(25):
        x, y = loader.next_batch()
        state, metrics = step(state, {"tokens": x, "labels": y})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_file_describe_matches_paper_vocab(tmp_path):
    """FLEX files report encoding histograms — the evidence behind Fig. 3's
    compression-ratio annotations."""
    line, _ = tpch.generate_tables(sf=0.002, seed=6)
    from repro.core import write_table
    meta = write_table(line, str(tmp_path / "l.tab"),
                       ACCELERATOR_OPTIMIZED.replace(rows_per_rg=100_000))
    d = meta.describe()
    assert d["compression_ratio"] > 1.5
    assert "DELTA_BINARY_PACKED" in d["encodings"]     # sorted orderkeys
    assert "RLE_DICTIONARY" in d["encodings"]          # low-card columns
