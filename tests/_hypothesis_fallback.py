"""Deterministic fallback for the tiny slice of the hypothesis API the test
suite uses, so property tests still *run* (not skip) in environments where
hypothesis isn't installed (e.g. this container).

With hypothesis available the real library is used (see the guarded imports
in the test modules); this shim draws ``max_examples`` pseudo-random examples
from the declared strategies with a fixed seed per example index, so runs
are reproducible.  No shrinking, no example database — a failure prints the
drawn arguments via the plain assert message instead.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value, endpoint=True)))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = int(rng.integers(min_size, hi, endpoint=True))
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def binary(min_size: int = 0, max_size: int = None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 64

    def draw(rng):
        n = int(rng.integers(min_size, hi, endpoint=True))
        return rng.integers(0, 256, size=n, dtype=np.uint16
                            ).astype(np.uint8).tobytes()

    return _Strategy(draw)


_TEXT_POOL = ("abcdefghijklmnopqrstuvwxyz" "ABCDEFGHIJKLMNOPQRSTUVWXYZ"
              "0123456789 _-.,!?" "éßñ" "日本語" "🙂🚀")


def text(min_size: int = 0, max_size: int = None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 20
    pool = list(_TEXT_POOL)

    def draw(rng):
        n = int(rng.integers(min_size, hi, endpoint=True))
        idx = rng.integers(0, len(pool), size=n)
        return "".join(pool[i] for i in idx)

    return _Strategy(draw)


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 20)
            for ex in range(n):
                rng = np.random.default_rng(0xC0FFEE + ex)
                drawn = [s.draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # keep pytest from treating the strategy-drawn parameters as
        # fixtures: hide the wrapped signature entirely
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


class _St:
    integers = staticmethod(integers)
    lists = staticmethod(lists)
    binary = staticmethod(binary)
    text = staticmethod(text)
    sampled_from = staticmethod(sampled_from)


st = _St()
