"""ScanService (core/scheduler.py): fairness, cancellation, error
isolation, adaptive-resize convergence on synthetic timings, cooperative
scan sharing, and the per-chunk-dispatch bit-identity regression."""

import threading
import time

import numpy as np
import pytest

from repro.core import (CompressionSpec, EncodingPolicy, FileConfig,
                        StringColumn, Table, write_table)
from repro.core.overlap import run_blocking, run_overlapped
from repro.core.query import Q6_COLUMNS, q6, q6_reference
from repro.core.scan import Scanner, open_scanner
from repro.core.scheduler import (ScanCancelled, ScanService, scan_service,
                                  shutdown_scan_service)
from repro.data import tpch


class StubScanner:
    """Synthetic-timing scanner: sleeps stand in for fetch/decode work
    (sleeps release the GIL, so pool parallelism is real)."""

    def __init__(self, n_rgs: int, fetch_s: float = 0.0005,
                 decode_s: float = 0.005, fail_at=None):
        self.n_rgs = n_rgs
        self.fetch_s = fetch_s
        self.decode_s = decode_s
        self.fail_at = fail_at
        self.decoded = []

    def plan(self, predicate_stats=None, row_groups=None):
        return list(range(self.n_rgs))

    def fetch_rg(self, i):
        time.sleep(self.fetch_s)
        return {"col": bytes(4)}, self.fetch_s

    def decode_rg(self, i, raws):
        if self.fail_at is not None and i >= self.fail_at:
            raise RuntimeError(f"decode failed at rg {i}")
        time.sleep(self.decode_s)
        self.decoded.append(i)
        return {"col": i}, self.decode_s


@pytest.fixture
def svc():
    service = ScanService(workers=1, adaptive=False)
    yield service
    service.shutdown()


# -- basic delivery ----------------------------------------------------------

def test_in_order_delivery(svc):
    handle = svc.submit(StubScanner(6), depth=3)
    seen = [item[0] for item in handle]
    assert seen == list(range(6))
    assert svc.active_scans == 0       # scan unregistered on exhaustion


def test_depth_backpressure_bounds_fetch_ahead(svc):
    sc = StubScanner(8, decode_s=0.01)
    handle = svc.submit(sc, depth=2)
    first = next(handle)
    time.sleep(0.08)                   # plenty of time to overrun depth
    # ≤ depth RGs may be decoded beyond the one delivered-but-unacked
    assert len(sc.decoded) <= 1 + 2
    handle.cancel()


# -- fairness ----------------------------------------------------------------

def test_round_robin_fairness_across_scans(svc):
    """A long scan must not monopolize the pool: a short scan submitted
    alongside finishes well before the long one ends."""
    long_sc = StubScanner(20, decode_s=0.01)
    short_sc = StubScanner(3, decode_s=0.01)
    h_long = svc.submit(long_sc, depth=4)
    h_short = svc.submit(short_sc, depth=4)
    t0 = time.perf_counter()
    done = {}

    def drain(name, h):
        for _ in h:
            pass
        done[name] = time.perf_counter() - t0

    t1 = threading.Thread(target=drain, args=("long", h_long))
    t2 = threading.Thread(target=drain, args=("short", h_short))
    t1.start(), t2.start()
    t1.join(), t2.join()
    # fair share: the short scan (3 RGs) finishes in well under half the
    # long scan's wall, not after it
    assert done["short"] < done["long"] * 0.7


# -- error isolation / cancellation -----------------------------------------

def test_error_isolated_to_failing_scan(svc):
    bad = StubScanner(6, fail_at=2)
    good = StubScanner(6)
    h_bad = svc.submit(bad, depth=2)
    h_good = svc.submit(good, depth=2)
    result = {}

    def drain_bad():
        try:
            for _ in h_bad:
                pass
        except RuntimeError as e:
            result["err"] = e

    t = threading.Thread(target=drain_bad)
    t.start()
    seen = [item[0] for item in h_good]
    t.join()
    assert seen == list(range(6))       # untouched by the sibling failure
    assert "decode failed" in str(result["err"])
    assert svc.active_scans == 0
    # the pool survived: a fresh scan still completes
    assert [i for i, *_ in svc.submit(StubScanner(2))] == [0, 1]


def test_fetch_error_propagates_to_owner_only(svc):
    class BadFetch(StubScanner):
        def fetch_rg(self, i):
            raise OSError("fetch exploded")

    h_bad = svc.submit(BadFetch(3))
    h_good = svc.submit(StubScanner(3))
    with pytest.raises(OSError, match="fetch exploded"):
        for _ in h_bad:
            pass
    assert [i for i, *_ in h_good] == [0, 1, 2]


def test_shutdown_unblocks_active_consumer():
    """shutdown() must cancel in-flight scans — a consumer blocked on its
    next row group would otherwise spin on done_cv forever."""
    svc = ScanService(workers=1, adaptive=False)
    handle = svc.submit(StubScanner(50, decode_s=0.02), depth=2)
    next(handle)
    got = {}

    def drain():
        try:
            for _ in handle:
                pass
        except ScanCancelled as e:
            got["exc"] = e

    t = threading.Thread(target=drain)
    t.start()
    time.sleep(0.05)
    svc.shutdown()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert isinstance(got.get("exc"), ScanCancelled)


def test_abandoned_handle_releases_scan(svc):
    """Dropping a handle mid-scan (no cancel, no exhaustion) must not leak
    the scan registration in the shared service."""
    import gc

    handle = svc.submit(StubScanner(20, decode_s=0.01), depth=2)
    next(handle)
    del handle
    gc.collect()
    deadline = time.time() + 2.0
    while svc.active_scans and time.time() < deadline:
        time.sleep(0.01)
    assert svc.active_scans == 0
    # context-manager form closes on scope exit too
    with svc.submit(StubScanner(20, decode_s=0.01), depth=2) as h:
        next(h)
    assert svc.active_scans == 0


def test_cancellation_releases_scan(svc):
    handle = svc.submit(StubScanner(50, decode_s=0.01), depth=2)
    next(handle)
    handle.cancel()
    with pytest.raises((ScanCancelled, StopIteration)):
        while True:
            next(handle)
    assert svc.active_scans == 0
    # cancel is idempotent
    handle.cancel()


# -- adaptive sizing ---------------------------------------------------------

def test_adaptive_grows_on_decode_bound_stream():
    svc = ScanService(adaptive=True, max_workers=4, resize_every=4)
    try:
        handle = svc.submit(StubScanner(24, fetch_s=0.0005,
                                        decode_s=0.02), depth=8)
        for _ in handle:
            time.sleep(0.002)          # cheap consume → decode-bound
        assert svc.resize_events, "no resize window completed"
        # decode ≫ max(fetch, consume) → pool grew toward max_workers
        assert svc.resize_events[-1] >= 3
    finally:
        svc.shutdown()


def test_adaptive_shrinks_on_consume_bound_stream():
    svc = ScanService(workers=4, adaptive=True, max_workers=4,
                      resize_every=4)
    try:
        handle = svc.submit(StubScanner(16, fetch_s=0.0005,
                                        decode_s=0.001), depth=4)
        for _ in handle:
            time.sleep(0.01)           # consume dominates
        assert svc.resize_events[-1] == 1
    finally:
        svc.shutdown()


def test_workers_hint_floors_pool(svc):
    handle = svc.submit(StubScanner(4), workers_hint=3)
    assert handle.workers == 3
    assert svc.pool_size >= 3
    for _ in handle:
        pass
    # floor released with the scan; adaptive=False keeps base width
    assert svc.active_scans == 0


# -- cooperative scans -------------------------------------------------------

@pytest.fixture(scope="module")
def small_tpch(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_sched")
    from repro.core.config import ACCELERATOR_OPTIMIZED
    metas = tpch.write_tpch(str(d), sf=0.004,
                            config=ACCELERATOR_OPTIMIZED.replace(
                                rows_per_rg=4_000,
                                target_pages_per_chunk=8),
                            seed=77)
    line, orders = tpch.generate_tables(sf=0.004, seed=77)
    return metas, line, orders


def test_cooperative_scans_share_inflight_jobs(small_tpch):
    """Concurrent identical scans subscribe to each other's in-flight
    jobs: total fetched requests drop, results stay correct."""
    metas, line, _ = small_tpch
    ref = q6_reference({c: np.asarray(line[c]) for c in Q6_COLUMNS})
    svc = ScanService(workers=1, adaptive=False)
    try:
        results = {}

        def one(k):
            sc = open_scanner(metas["lineitem_path"],
                              columns=list(Q6_COLUMNS),
                              decode_backend="host")
            # slow the consume a touch so scans stay overlapped and the
            # subscription window is reliably open
            got, rep = q6(sc, prune=False, service=svc, depth=1)
            results[k] = (got, rep)

        threads = [threading.Thread(target=one, args=(k,))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for got, rep in results.values():
            assert abs(got - ref) / max(1.0, abs(ref)) < 1e-5
        assert svc.shared_rgs > 0, "no cooperative sharing happened"
        fetched = sum(rep.metrics.n_io_requests
                      for _, rep in results.values())
        solo = max(rep.metrics.n_row_groups
                   for _, rep in results.values())
        # 4 scans fetched fewer requests than 4 solo scans would have
        assert fetched < 4 * max(1, solo) * len(Q6_COLUMNS)
    finally:
        svc.shutdown()


def test_sharing_requires_identical_shape(small_tpch):
    """Different column selections must NOT share jobs."""
    metas, line, _ = small_tpch
    svc = ScanService(workers=1, adaptive=False)
    try:
        out = {}

        def one(name, cols, expect):
            sc = open_scanner(metas["lineitem_path"], columns=cols,
                              decode_backend="host")
            total = 0.0
            for _, dec, *_ in svc.submit(sc, depth=1):
                total += float(np.asarray(
                    dec[expect].array, dtype=np.float64).sum())
            out[name] = total

        t1 = threading.Thread(target=one, args=(
            "qty", ["l_quantity"], "l_quantity"))
        t2 = threading.Thread(target=one, args=(
            "disc", ["l_discount"], "l_discount"))
        t1.start(), t2.start()
        t1.join(), t2.join()
        assert out["qty"] == pytest.approx(
            np.asarray(line["l_quantity"], dtype=np.float64).sum())
        assert out["disc"] == pytest.approx(
            np.asarray(line["l_discount"], dtype=np.float64).sum())
    finally:
        svc.shutdown()


# -- per-chunk dispatch bit-identity (regression) ----------------------------

def _mixed_table(n=5_000, seed=5):
    rng = np.random.default_rng(seed)
    return Table({
        "sorted32": np.cumsum(rng.integers(0, 5, n)).astype(np.int32),
        "lowcard": rng.integers(0, 11, n).astype(np.int32),
        "f32dict": rng.integers(0, 9, n).astype(np.float32) / 8.0,
        "f32noise": rng.normal(size=n).astype(np.float32),
        "flags": rng.random(n) < 0.2,
        "runs": np.repeat(np.arange(-(-n // 500), dtype=np.int32), 500)[:n],
        "strs": StringColumn.from_pylist([f"s{i % 23}" for i in range(n)]),
    })


@pytest.mark.parametrize("backend", ["host", "pallas"])
@pytest.mark.parametrize("codec", ["gzip", "cascade"])
def test_per_chunk_dispatch_bit_identical(tmp_path, backend, codec):
    """The scheduled per-chunk decode (phase-1/phase-2 items through the
    shared pool) must equal the monolithic per-RG decode AND the
    per-chunk reference decoder, bit for bit."""
    tbl = _mixed_table()
    path = str(tmp_path / f"m_{backend}_{codec}.tab")
    write_table(tbl, path, FileConfig(
        rows_per_rg=2_000, target_pages_per_chunk=6,
        encodings=EncodingPolicy.FLEX,
        compression=CompressionSpec(codec=codec, min_gain=0.0)))
    svc = ScanService(workers=2, adaptive=False)
    try:
        sched_cols = {}

        def consume(acc, i, cols):
            sched_cols[i] = cols
            return acc

        run_overlapped(Scanner(path, decode_backend=backend), consume,
                       decode_workers=2, service=svc)
        ref = Scanner(path, decode_backend=backend, use_plan=False)
        mono = Scanner(path, decode_backend=backend)
        for i in ref.plan():
            raws, _ = ref.fetch_rg(i)
            cols_r, _ = ref.decode_rg(i, raws)
            cols_m, _ = mono.decode_rg(i, raws)
            for name in tbl.columns:
                for other in (cols_m[name], cols_r[name]):
                    a, b = sched_cols[i][name], other
                    if isinstance(a.array, StringColumn):
                        np.testing.assert_array_equal(a.array.offsets,
                                                      b.array.offsets)
                        np.testing.assert_array_equal(a.array.payload,
                                                      b.array.payload)
                    else:
                        ra, rb = np.asarray(a.array), np.asarray(b.array)
                        assert ra.dtype == rb.dtype, (i, name)
                        np.testing.assert_array_equal(
                            ra, rb, err_msg=f"rg{i}:{name}")
    finally:
        svc.shutdown()


def test_per_chunk_item_times_reach_report(small_tpch):
    """decode_chunks_per_rg is populated by the service path and feeds the
    per-chunk modeled schedule."""
    metas, _, _ = small_tpch
    sc = open_scanner(metas["lineitem_path"], columns=list(Q6_COLUMNS),
                      decode_backend="host")
    svc = ScanService(workers=2, adaptive=False)
    try:
        _, rep = q6(sc, prune=False, service=svc, decode_workers=2)
        chunks = rep.metrics.decode_chunks_per_rg
        assert len(chunks) == rep.metrics.n_row_groups
        assert all(len(c) >= 1 for c in chunks)
        # item times sum to ~the per-RG decode accounting
        for parts, d in zip(chunks, rep.metrics.decode_per_rg):
            assert sum(parts) == pytest.approx(d, rel=1e-6)
        # the phase-2 barrier index is recorded for every RG and lands
        # inside the item list (after open + phase 1 + transition);
        # fused jobs (REPRO_FUSED=1) deliberately clear it — their phase-3
        # item must never be modeled as parallel with phase 2, so the
        # modeled schedule serializes the whole decode (p2_start == 0)
        splits = rep.metrics.decode_p2_start_per_rg
        assert len(splits) == len(chunks)
        for parts, s in zip(chunks, splits):
            if sc.fused_spec is not None:
                assert s == 0
            else:
                assert 2 <= s <= len(parts) - 1
        assert rep.modeled_wall > 0.0
    finally:
        svc.shutdown()


def test_modeled_wall_chunk_schedule_tighter_than_rg():
    """Per-chunk schedule: 2 servers split an RG's two 1s chunks →
    decode_done = 1s, vs 2s when the RG is indivisible."""
    from repro.core.overlap import RunReport
    from repro.core.scan import ScanMetrics

    def report(chunked):
        m = ScanMetrics()
        m.io_per_rg = [0.0, 0.0]
        m.decode_per_rg = [2.0, 2.0]
        if chunked:
            # [open, transition, chunk, chunk, finalize] with the phase-2
            # barrier at index 2 — open/transition/finalize model the
            # executor's serialized DAG edges and stay serial
            m.decode_chunks_per_rg = [[0.0, 0.0, 1.0, 1.0, 0.0],
                                      [0.0, 0.0, 1.0, 1.0, 0.0]]
            m.decode_p2_start_per_rg = [2, 2]
        return RunReport("overlapped", 0.0, m, [0.5, 0.5],
                         decode_workers=2, depth=8)

    # indivisible RGs: two servers pipeline whole RGs
    #   rg0 decode 0→2, consume 2→2.5; rg1 decode 0→2, consume 2.5→3
    assert report(False).modeled_wall == pytest.approx(3.0)
    # chunked: rg0's two chunks decode in parallel 0→1, consume 1→1.5;
    #   rg1 decodes 1→2, consume 2→2.5
    assert report(True).modeled_wall == pytest.approx(2.5)
    # phase-1 work gates phase 2: [open, inflate=10, transition,
    # decode=1, decode=1, fin] must model ≥ 10 + 1 even with spare
    # servers (the barrier), not min(10, 1+1)
    m = ScanMetrics()
    m.io_per_rg = [0.0]
    m.decode_per_rg = [12.0]
    m.decode_chunks_per_rg = [[0.0, 10.0, 0.0, 1.0, 1.0, 0.0]]
    m.decode_p2_start_per_rg = [3]
    barrier = RunReport("overlapped", 0.0, m, [0.0],
                        decode_workers=4, depth=8)
    assert barrier.modeled_wall == pytest.approx(11.0)
    # no recorded barrier → fully serial; never beat the executor's DAG
    m = ScanMetrics()
    m.io_per_rg = [0.0]
    m.decode_per_rg = [2.0]
    m.decode_chunks_per_rg = [[1.0, 1.0]]
    serial = RunReport("overlapped", 0.0, m, [0.0],
                       decode_workers=4, depth=8)
    assert serial.modeled_wall == pytest.approx(2.0)


def test_global_singleton_lifecycle():
    svc1 = scan_service()
    assert scan_service() is svc1
    handle = svc1.submit(StubScanner(2))
    assert [i for i, *_ in handle] == [0, 1]
    shutdown_scan_service()
    svc2 = scan_service()
    assert svc2 is not svc1
    assert [i for i, *_ in svc2.submit(StubScanner(1))] == [0]
    shutdown_scan_service()


# -- fetch pool (fetch_threads) ----------------------------------------------

def test_fetch_pool_default_is_single_thread():
    svc = ScanService(workers=1, adaptive=False)
    try:
        assert svc.fetch_threads == 1
        handle = svc.submit(StubScanner(3))
        assert [i for i, *_ in handle] == [0, 1, 2]
        assert len(svc._fetch_pool) == 1
    finally:
        svc.shutdown()


def test_fetch_pool_overlaps_blocking_reads():
    """With fetch_threads=N, N concurrent scans' blocking reads overlap:
    the fetch stage stops serializing across scans."""
    svc = ScanService(workers=2, adaptive=False, fetch_threads=4)
    try:
        scanners = [StubScanner(4, fetch_s=0.02, decode_s=0.0005)
                    for _ in range(4)]
        handles = [svc.submit(sc, depth=2) for sc in scanners]
        seen = {}

        def drain(k):
            seen[k] = [i for i, *_ in handles[k]]

        t0 = time.perf_counter()
        threads = [threading.Thread(target=drain, args=(k,))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        assert len(svc._fetch_pool) == 4
        for k in range(4):
            assert seen[k] == [0, 1, 2, 3]        # order preserved
        # serialized: 16 fetches x 20ms = 320ms; pooled: ~80ms + decode.
        # generous bound so CI scheduling noise cannot flake it
        assert wall < 0.28, f"fetch pool did not overlap reads ({wall:.3f}s)"
    finally:
        svc.shutdown()


def test_fetch_pool_bit_identical_to_default_path(small_tpch):
    """The pooled fetch path must deliver byte-identical results to the
    default single-thread path (the paper's one-channel NVMe model)."""
    metas, line, _ = small_tpch
    def run(fetch_threads):
        svc = ScanService(workers=2, adaptive=False,
                          fetch_threads=fetch_threads)
        try:
            sc = open_scanner(metas["lineitem_path"],
                              columns=list(Q6_COLUMNS),
                              decode_backend="host")
            got, _ = q6(sc, prune=False, service=svc, depth=4)
            return got
        finally:
            svc.shutdown()

    assert run(1) == run(3)


# -- priority classes (fragment-priority hook) -------------------------------

def test_service_order_respects_priority_classes():
    svc = ScanService(workers=1, adaptive=False)
    try:
        a = svc.submit(StubScanner(1), priority=2)
        b = svc.submit(StubScanner(1), priority=0)
        c = svc.submit(StubScanner(1), priority=0)
        with svc._lock:
            order = svc._service_order_locked(0)
            prios = [s.priority for s, _ in order]
        assert prios == sorted(prios)      # strict class ordering
        # cursor offsets are per-class positions, so advancing past a
        # skipped scan of another class cannot skew this class's rotation
        assert [off for _, off in order] == [0, 1, 0]
        with svc._lock:                    # rotation stays inside a class
            rotated = svc._service_order_locked(1)
        assert rotated[0][0].priority == 0 and rotated[1][0].priority == 0
        assert (rotated[0][0] is not order[0][0]
                or rotated[1][0] is not order[1][0])
        for h in (a, b, c):
            h.cancel()
    finally:
        svc.shutdown()


def test_lower_priority_scan_finishes_first():
    """One worker, two equal scans: the priority-0 scan completes before
    the priority-1 scan submitted ahead of it."""
    svc = ScanService(workers=1, adaptive=False)
    try:
        slow = svc.submit(StubScanner(6, decode_s=0.004), priority=1)
        fast = svc.submit(StubScanner(6, decode_s=0.004), priority=0)
        finish = {}

        def drain(name, h):
            for _ in h:
                pass
            finish[name] = time.perf_counter()

        threads = [threading.Thread(target=drain, args=("slow", slow)),
                   threading.Thread(target=drain, args=("fast", fast))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert finish["fast"] < finish["slow"]
    finally:
        svc.shutdown()
