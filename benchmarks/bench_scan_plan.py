"""DecodePlan: cross-column batched decode + coalesced I/O economics.

Measures the three quantities the planner changes (DESIGN.md §2.4):

  * Pallas launches per multi-column row group — O(encoding groups) with
    the plan vs O(columns × stride groups) per-chunk (counted, not modeled);
  * storage requests per row group — coalesced vs one-per-chunk, and the
    modeled N-lane batch time for each (sim, Insight 2);
  * host decode wall time for a wide (15-column) scan, per-chunk vs planned
    (measured) — the per-page numpy overhead the plan's group batching
    removes, plus plan build vs cache-hit cost.
"""

from __future__ import annotations

import os
import time

from benchmarks.common import (BENCH_SF, emit, emit_cpu_reference,
                               ensure_tpch, timeit)
from repro.core.compression import chunk_decompress_memo
from repro.core.config import ACCELERATOR_OPTIMIZED, CompressionSpec
from repro.core.query import Q6_COLUMNS, q6_fused_spec
from repro.core.scan import Scanner, open_scanner
from repro.core.storage import SimulatedStorage, coalesce_ranges
from repro.kernels.common import kernel_launch_count

WIDE_COLUMNS = [
    "l_orderkey", "l_partkey", "l_suppkey", "l_linenumber", "l_quantity",
    "l_extendedprice", "l_discount", "l_tax", "l_returnflag",
    "l_linestatus", "l_shipdate", "l_commitdate", "l_receiptdate",
    "l_shipinstruct", "l_shipmode",
]


def _decode_time(path, use_plan: bool) -> float:
    sc = open_scanner(path, columns=WIDE_COLUMNS, decode_backend="host",
                      use_plan=use_plan)
    plan = sc.plan()
    raws = {i: sc.fetch_rg(i)[0] for i in plan}

    def body():
        for i in plan:
            sc.decode_rg(i, raws[i])

    # min: the CI gate compares this row across runs, so scheduler noise
    # on shared runners must not read as a regression
    return timeit(body, repeats=5, warmup=1, reduce="min")


def run() -> None:
    emit_cpu_reference()   # lets the CI gate normalize by machine speed
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_000_000)
    base = ensure_tpch(cfg, "scan_plan")
    path = base["lineitem_path"]

    # -- measured host decode: per-chunk vs planned -------------------------
    t_chunk = _decode_time(path, use_plan=False)
    t_plan = _decode_time(path, use_plan=True)
    emit("scan_plan_decode_per_chunk", t_chunk * 1e6,
         f"15 columns;host;measured;sf={BENCH_SF}")
    emit("scan_plan_decode_planned", t_plan * 1e6,
         f"speedup={t_chunk / max(t_plan, 1e-12):.2f}x;host;measured")

    # -- plan build vs cache hit -------------------------------------------
    sc = open_scanner(path, columns=WIDE_COLUMNS, decode_backend="host")
    t0 = time.perf_counter()
    n_groups = sc.prepare_plans()
    build = time.perf_counter() - t0
    t0 = time.perf_counter()
    sc.prepare_plans()
    hit = time.perf_counter() - t0
    emit("scan_plan_build", build * 1e6, f"groups={n_groups};measured")
    emit("scan_plan_cache_hit", hit * 1e6, "measured")

    # -- kernel-launch economy (pallas, small slice) ------------------------
    small = ensure_tpch(cfg.replace(rows_per_rg=50_000,
                                    target_pages_per_chunk=20),
                        "scan_plan_small", sf=0.004)
    for use_plan in (False, True):
        sc = Scanner(small["lineitem_path"], columns=WIDE_COLUMNS,
                     decode_backend="pallas", use_plan=use_plan)
        raws, _ = sc.fetch_rg(0)
        sc.decode_rg(0, raws)          # warm jit (+ arena pool)
        l0 = kernel_launch_count()
        sc.decode_rg(0, raws)
        launches = kernel_launch_count() - l0
        dt = timeit(lambda: sc.decode_rg(0, raws),
                    repeats=max(3, int(os.environ.get("BENCH_ROUNDS", "3"))),
                    warmup=0, reduce="min")
        arena = (f"arena_reuses={sc.planner._arena_pool.reuses};"
                 if use_plan else "")
        emit(f"scan_plan_launches_{'planned' if use_plan else 'per_chunk'}",
             dt * 1e6,
             f"launches_per_rg={launches};{arena}"
             "pallas-interpret;measured")

    # -- fused late materialization (DESIGN.md §7): the Q6 predicate set
    # decodes its aggregate operands *in-kernel*, so one row group costs
    # the stage-A group launch plus exactly one fused launch — gated
    # against the per-chunk and planned rows above
    sc = Scanner(small["lineitem_path"], columns=list(Q6_COLUMNS),
                 decode_backend="pallas", fused_spec=q6_fused_spec())
    raws, _ = sc.fetch_rg(0)
    sc.decode_rg(0, raws)              # warm jit (+ arena pool)
    l0 = kernel_launch_count()
    sc.decode_rg(0, raws)
    launches = kernel_launch_count() - l0
    dt = timeit(lambda: sc.decode_rg(0, raws),
                repeats=max(3, int(os.environ.get("BENCH_ROUNDS", "3"))),
                warmup=0, reduce="min")
    emit("scan_plan_launches_fused", dt * 1e6,
         f"launches_per_rg={launches};q6 predicate+agg;"
         "pallas-interpret;measured")

    # -- chunk decompress memo: gzip revisit cost (ROADMAP lever) -----------
    gz = ensure_tpch(cfg.replace(compression=CompressionSpec(codec="gzip",
                                                             min_gain=0.0)),
                     "scan_plan_gzip")
    sc = open_scanner(gz["lineitem_path"], columns=WIDE_COLUMNS,
                      decode_backend="host")
    plan = sc.plan()
    raws = {i: sc.fetch_rg(i)[0] for i in plan}
    sc.decode_rg(plan[0], raws[plan[0]])   # warm jits off the timings
    cold, hot = float("inf"), float("inf")
    rounds = max(3, int(os.environ.get("BENCH_ROUNDS", "3")))
    for _ in range(rounds):                # best-of: shared-host noise
        chunk_decompress_memo().clear()
        t0 = time.perf_counter()
        for i in plan:
            sc.decode_rg(i, raws[i])
        cold = min(cold, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for i in plan:
            sc.decode_rg(i, raws[i])
        hot = min(hot, time.perf_counter() - t0)
    memo = chunk_decompress_memo()
    emit("scan_plan_gzip_decode_cold", cold * 1e6,
         "gzip min_gain=0;host;measured")
    emit("scan_plan_gzip_decode_memo_hot", hot * 1e6,
         f"speedup={cold / max(hot, 1e-12):.2f}x;"
         f"memo_hit_chunks={memo.hits};host;measured")

    # -- request coalescing under the N-lane model (Insight 2) --------------
    meta = Scanner(path, columns=WIDE_COLUMNS, use_plan=False,
                   decode_backend="host").meta
    sim = SimulatedStorage(path, n_lanes=1)
    chunk_ranges = [rg.column(c).byte_range
                    for rg in meta.row_groups for c in WIDE_COLUMNS]
    merged, _ = coalesce_ranges(chunk_ranges, gap=64 * 1024)
    t_split = sim.batch_seconds([s for _, s in chunk_ranges])
    t_merged = sim.batch_seconds([s for _, s in merged])
    emit("scan_plan_io_per_chunk", t_split * 1e6,
         f"requests={len(chunk_ranges)};sim")
    emit("scan_plan_io_coalesced", t_merged * 1e6,
         f"requests={len(merged)};"
         f"speedup={t_split / max(t_merged, 1e-12):.2f}x;sim")


if __name__ == "__main__":
    run()
