"""Fig. 3 (right deltas) / Insight 4: blind vs selective compression.

At 4 simulated lanes the pipeline becomes decode-bound, so skipping
pointless decompression work moves the overlapped wall time; at 1 lane the
effect vanishes (I/O-bound) — both paper observations are reproduced.
Also benchmarks the TPU-native cascade codec variant.
"""

from __future__ import annotations

from benchmarks.common import emit, ensure_tpch
from repro.core.compression import chunk_decompress_memo
from repro.core.config import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT,
                               CompressionSpec, TPU_CASCADE)
from repro.core.scheduler import clear_delivered_windows
from repro.dataset.result_cache import clear_all_result_caches
from repro.kernels.dict_decode import dict_cache_clear
from repro.core.query import Q6_COLUMNS
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner

VARIANTS = {
    "blind_gzip": ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=1_000_000,
        compression=CompressionSpec(codec="gzip", min_gain=0.0)),
    "selective_gzip": ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=1_000_000,
        compression=CompressionSpec(codec="gzip", min_gain=0.10)),
    "selective_cascade": TPU_CASCADE.replace(rows_per_rg=1_000_000),
    "no_compression": ACCELERATOR_OPTIMIZED.replace(
        rows_per_rg=1_000_000, compression=CompressionSpec(codec="none")),
}


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT.replace(rows_per_rg=1_000_000),
                       "fig3c_base")
    for name, cfg in VARIANTS.items():
        path = base["lineitem_path"] + f".{name}"
        rewrite_file(base["lineitem_path"], path, cfg)
        meta = TabFileReader(path).meta
        # cold-scan per round: a hot decompress memo would skip the blind
        # gzip inflation this Insight-4 comparison exists to measure
        for lanes in (1, 4):
            best = None
            for _ in range(3):
                chunk_decompress_memo().clear()
                dict_cache_clear()
                clear_delivered_windows()
                clear_all_result_caches()
                sc = open_scanner(path, columns=None,
                                  backend="sim", n_lanes=lanes,
                                  decode_backend="host")
                _, m = sc.scan_with_metrics()
                assert sc.storage.stats.requests > 0, \
                    "cold arm was served from a cache"
                if best is None or m.overlapped_seconds \
                        < best.overlapped_seconds:
                    best = m
            emit(f"fig3c_{name}_ssd{lanes}",
                 best.overlapped_seconds * 1e6,
                 f"effective_GBps={best.effective_bandwidth()/1e9:.3f};"
                 f"decode_s={best.decode_seconds:.4f};"
                 f"stored_MB={meta.stored_bytes/1e6:.1f}")
