"""Fig. 5: query-level validation — Q6 and Q12 runtimes across file
configurations, blocking vs pipelined reader, against a CPU-baseline
engine and the theoretical storage lower bound.

Each configuration runs BENCH_ROUNDS times (default 3) and keeps the best
modeled wall: decode at benchmark SF is tens of ms, where scheduler noise
on a shared container swamps single measurements, and later rounds hit
the decode-plan / dictionary / decompress caches — the serving-loop
pattern the executor is built for (DESIGN.md §2.4/§2.5).

Note these are therefore *hot-cache* numbers for every configuration: a
gzip-everything baseline file stops paying inflation on revisit, so the
paper's cold-scan configuration ladder (optimized ≥ baseline) is not what
this table shows.  The cold-scan ladder is asserted in
tests/test_system.py (caches cleared per run) and measured by the
fig2/fig3 suites; the cold-vs-hot gzip delta itself is the
scan_plan_gzip_* pair in bench_scan_plan.py."""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit, emit_cpu_reference, ensure_tpch
from repro.core.config import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT,
                               EncodingPolicy, FileConfig)
from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                              Q6_COLUMNS, q6, q6_reference, q12)
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.core.storage import SimulatedStorage
from repro.kernels.common import kernel_launch_count

CONFIGS = {
    "baseline": CPU_DEFAULT,
    "pages": CPU_DEFAULT.replace(target_pages_per_chunk=100),
    "rg_size": FileConfig(rows_per_rg=1_000_000,
                          target_pages_per_chunk=100,
                          encodings=EncodingPolicy.V1_ONLY),
    "optimized": ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_000_000),
}


def _cpu_baseline_q6(path: str) -> float:
    """A CPU-engine stand-in: blocking full read + numpy compute."""
    t0 = time.perf_counter()
    rd = TabFileReader(path)
    tbl = rd.read_table(columns=list(Q6_COLUMNS))
    q6_reference({c: np.asarray(tbl[c]) for c in Q6_COLUMNS})
    return time.perf_counter() - t0


def run() -> None:
    emit_cpu_reference()   # lets the CI gate normalize by machine speed
    base = ensure_tpch(CPU_DEFAULT, "fig5_base")
    obase = base["orders_path"]
    # warm the jitted query consumers so compile time never lands in the
    # first measured configuration
    warm = open_scanner(base["lineitem_path"], columns=list(Q6_COLUMNS),
                        decode_backend="host")
    q6(warm, overlapped=False, prune=False)
    warm_l = open_scanner(base["lineitem_path"],
                          columns=Q12_LINEITEM_COLUMNS,
                          decode_backend="host")
    warm_o = open_scanner(base["orders_path"],
                          columns=Q12_ORDERS_COLUMNS,
                          decode_backend="host")
    q12(warm_l, warm_o, overlapped=False)
    rounds = max(1, int(os.environ.get("BENCH_ROUNDS", "3")))
    bounds = {}
    paths = {}
    for name, cfg in CONFIGS.items():
        lpath = base["lineitem_path"] + f".q_{name}"
        rewrite_file(base["lineitem_path"], lpath, cfg)
        opath = obase + f".q_{name}"
        rewrite_file(obase, opath, cfg)
        paths[name] = (lpath, opath)
        meta = TabFileReader(lpath).meta
        # theoretical lower bound: stored bytes / 1-lane bandwidth
        sim = SimulatedStorage(lpath, n_lanes=1)
        q6_cols_bytes = sum(rg.column(c).stored_bytes
                            for rg in meta.row_groups for c in Q6_COLUMNS)
        bounds[name] = q6_cols_bytes / sim.lane_bandwidth

    # Rounds are interleaved *across* configurations (like
    # tests/test_system.py) so a noisy period on a shared host penalizes
    # every configuration equally instead of wiping out one config's
    # entire sample.  The overlapped rows try both executor shapes — W=0
    # (inline decode, the private PR-1 double buffer) and W=2 (the shared
    # ScanService pool floored at 2, per-chunk dispatch) — and keep the
    # best; ``workers=`` in derived records which one won.  On a 2-core
    # container the pool pays for decode-heavy/consume-busy streams and
    # loses to GIL contention elsewhere; on wider hosts it wins outright
    # (DESIGN.md §2.5/§2.6).
    best = {}   # row name → (wall_seconds, derived)
    for _ in range(rounds):
        for name in CONFIGS:
            lpath, opath = paths[name]
            bound = bounds[name]
            for mode, workers in (("blocking", 0), ("overlapped", 0),
                                  ("overlapped", 2)):
                sc = open_scanner(lpath, columns=list(Q6_COLUMNS),
                                  backend="sim", n_lanes=1,
                                  decode_backend="host")
                rev, rep = q6(sc, overlapped=(mode == "overlapped"),
                              prune=False, decode_workers=workers)
                # per-stage wall spans + the deterministic launch/request
                # economy (the CI gate trips on any io_requests increase)
                row = (f"fig5_q6_{name}_{mode}", rep.modeled_wall,
                       f"lower_bound_us={bound*1e6:.0f};"
                       f"x_over_bound={rep.modeled_wall/bound:.2f};"
                       f"io_requests={rep.metrics.n_io_requests};"
                       f"{rep.stage_summary}")
                if row[0] not in best or row[1] < best[row[0]][0]:
                    best[row[0]] = (row[1], row[2])

            if name == "optimized":
                # fused late-materialization pair (DESIGN.md §7): pallas
                # decode so the launch economy is visible — the CI gate
                # pins fused launches strictly below unfused, and the
                # fused row records its wall speedup over the unfused twin
                for fused in (False, True):
                    sc = open_scanner(lpath, columns=list(Q6_COLUMNS),
                                      backend="sim", n_lanes=1,
                                      decode_backend="pallas")
                    l0 = kernel_launch_count()
                    _, rep = q6(sc, overlapped=False, prune=False,
                                fused=fused)
                    launches = kernel_launch_count() - l0
                    key = ("fig5_q6_optimized_pallas_fused" if fused
                           else "fig5_q6_optimized_pallas_unfused")
                    derived = (f"launches={launches};"
                               f"io_requests={rep.metrics.n_io_requests};"
                               f"{rep.stage_summary}")
                    if key not in best or rep.modeled_wall < best[key][0]:
                        best[key] = (rep.modeled_wall, derived)

            for workers in (0, 2):
                lsc = open_scanner(lpath, columns=Q12_LINEITEM_COLUMNS,
                                   backend="sim", n_lanes=1,
                                   decode_backend="host")
                osc = open_scanner(opath, columns=Q12_ORDERS_COLUMNS,
                                   backend="sim", n_lanes=1,
                                   decode_backend="host")
                _, brep, prep = q12(lsc, osc, overlapped=True,
                                    decode_workers=workers)
                wall = brep.modeled_wall + prep.modeled_wall
                key = f"fig5_q12_{name}_overlapped"
                derived = (
                    f"build_us={brep.modeled_wall*1e6:.0f};"
                    f"probe_us={prep.modeled_wall*1e6:.0f};"
                    f"io_requests="
                    f"{brep.metrics.n_io_requests + prep.metrics.n_io_requests};"
                    f"{prep.stage_summary}")
                if key not in best or wall < best[key][0]:
                    best[key] = (wall, derived)

    for name in CONFIGS:
        for key in (f"fig5_q6_{name}_blocking",
                    f"fig5_q6_{name}_overlapped",
                    f"fig5_q12_{name}_overlapped"):
            wall, derived = best[key]
            emit(key, wall * 1e6, derived)

    uf_wall, uf_derived = best["fig5_q6_optimized_pallas_unfused"]
    f_wall, f_derived = best["fig5_q6_optimized_pallas_fused"]
    emit("fig5_q6_optimized_pallas_unfused", uf_wall * 1e6, uf_derived)
    emit("fig5_q6_optimized_pallas_fused", f_wall * 1e6,
         f"speedup_vs_unfused={uf_wall / max(f_wall, 1e-12):.2f}x;"
         f"{f_derived}")

    cpu_s = min(_cpu_baseline_q6(base["lineitem_path"] + ".q_optimized")
                for _ in range(rounds))   # same noise treatment as fig5 rows
    emit("fig5_q6_cpu_engine_baseline", cpu_s * 1e6,
         "blocking full-read numpy engine on optimized file (measured)")
