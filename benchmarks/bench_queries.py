"""Fig. 5: query-level validation — Q6 and Q12 runtimes across file
configurations, blocking vs overlapped reader, against a CPU-baseline
engine and the theoretical storage lower bound."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, ensure_tpch
from repro.core.config import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT,
                               EncodingPolicy, FileConfig)
from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                              Q6_COLUMNS, q6, q6_reference, q12)
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.core.storage import SimulatedStorage

CONFIGS = {
    "baseline": CPU_DEFAULT,
    "pages": CPU_DEFAULT.replace(target_pages_per_chunk=100),
    "rg_size": FileConfig(rows_per_rg=1_000_000,
                          target_pages_per_chunk=100,
                          encodings=EncodingPolicy.V1_ONLY),
    "optimized": ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_000_000),
}


def _cpu_baseline_q6(path: str) -> float:
    """A CPU-engine stand-in: blocking full read + numpy compute."""
    t0 = time.perf_counter()
    rd = TabFileReader(path)
    tbl = rd.read_table(columns=list(Q6_COLUMNS))
    q6_reference({c: np.asarray(tbl[c]) for c in Q6_COLUMNS})
    return time.perf_counter() - t0


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT, "fig5_base")
    obase = base["orders_path"]
    # warm the jitted query consumers so compile time never lands in the
    # first measured configuration
    warm = open_scanner(base["lineitem_path"], columns=list(Q6_COLUMNS),
                        decode_backend="host")
    q6(warm, overlapped=False, prune=False)
    warm_l = open_scanner(base["lineitem_path"],
                          columns=Q12_LINEITEM_COLUMNS,
                          decode_backend="host")
    warm_o = open_scanner(base["orders_path"],
                          columns=Q12_ORDERS_COLUMNS,
                          decode_backend="host")
    q12(warm_l, warm_o, overlapped=False)
    for name, cfg in CONFIGS.items():
        lpath = base["lineitem_path"] + f".q_{name}"
        rewrite_file(base["lineitem_path"], lpath, cfg)
        opath = obase + f".q_{name}"
        rewrite_file(obase, opath, cfg)
        meta = TabFileReader(lpath).meta
        # theoretical lower bound: stored bytes / 1-lane bandwidth
        sim = SimulatedStorage(lpath, n_lanes=1)
        q6_cols_bytes = sum(rg.column(c).stored_bytes
                            for rg in meta.row_groups for c in Q6_COLUMNS)
        bound = q6_cols_bytes / sim.lane_bandwidth

        for mode in ("blocking", "overlapped"):
            sc = open_scanner(lpath, columns=list(Q6_COLUMNS),
                              backend="sim", n_lanes=1,
                              decode_backend="host")
            rev, rep = q6(sc, overlapped=(mode == "overlapped"),
                          prune=False)
            emit(f"fig5_q6_{name}_{mode}", rep.modeled_wall * 1e6,
                 f"lower_bound_us={bound*1e6:.0f};"
                 f"x_over_bound={rep.modeled_wall/bound:.2f}")

        lsc = open_scanner(lpath, columns=Q12_LINEITEM_COLUMNS,
                           backend="sim", n_lanes=1, decode_backend="host")
        osc = open_scanner(opath, columns=Q12_ORDERS_COLUMNS,
                           backend="sim", n_lanes=1, decode_backend="host")
        _, brep, prep = q12(lsc, osc, overlapped=True)
        emit(f"fig5_q12_{name}_overlapped",
             (brep.modeled_wall + prep.modeled_wall) * 1e6,
             f"build_us={brep.modeled_wall*1e6:.0f};"
             f"probe_us={prep.modeled_wall*1e6:.0f}")

    cpu_s = _cpu_baseline_q6(base["lineitem_path"] + ".q_optimized")
    emit("fig5_q6_cpu_engine_baseline", cpu_s * 1e6,
         "blocking full-read numpy engine on optimized file (measured)")
