# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback

from benchmarks.common import flush_csv


def main() -> None:
    print("name,us_per_call,derived")
    suites = [
        ("bench_page_count", "fig2a"),      # Fig 2(a): page-count sweep
        ("bench_rg_size", "fig2b"),         # Fig 2(b): RG-size sweep
        ("bench_encoding", "fig3"),         # Fig 3: FLEX + SSD scaling
        ("bench_compression", "fig3c"),     # Fig 3: Insight-4 deltas
        ("bench_queries", "fig5"),          # Fig 5: Q6/Q12 query level
        ("bench_rewriter", "sec5"),         # §5: rewriter overhead
        ("bench_kernels", "kernels"),       # §3: per-encoding decode bw
        ("roofline", "roofline"),           # §Roofline from dry-run JSONs
    ]
    failures = []
    for mod_name, tag in suites:
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            mod.run()
            flush_csv(f"{tag}.csv")
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
