# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--smoke`` runs a CI-sized subset (tiny scale factor, 1 repeat) of the
# scan-path suites so per-PR regressions in decode/planning/I-O are caught
# without the full benchmark cost.
import argparse
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-SF subset for CI (scan-path suites only)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (e.g. bench_queries)")
    ap.add_argument("--trace", action="store_true",
                    help="record each suite with the flight recorder "
                         "(core/trace.py) and write trace_<tag>.json "
                         "next to the CSVs")
    args = ap.parse_args()
    if args.smoke:
        os.environ.setdefault("BENCH_SF", "0.01")
        # smoke rows are small enough that extra best-of rounds are cheap,
        # and the CI perf gate needs the min to be noise-proof
        os.environ.setdefault("BENCH_ROUNDS", "5")
        os.environ["BENCH_SMOKE"] = "1"   # bench_concurrent: N subset

    from benchmarks.common import flush_csv

    suites = [
        ("bench_page_count", "fig2a"),      # Fig 2(a): page-count sweep
        ("bench_rg_size", "fig2b"),         # Fig 2(b): RG-size sweep
        ("bench_encoding", "fig3"),         # Fig 3: FLEX + SSD scaling
        ("bench_compression", "fig3c"),     # Fig 3: Insight-4 deltas
        ("bench_queries", "fig5"),          # Fig 5: Q6/Q12 query level
        ("bench_scan_plan", "scan_plan"),   # DecodePlan launch/IO economy
        ("bench_concurrent", "concurrent"),  # ScanService N-scan sharing
        ("bench_dataset", "dataset"),       # dataset pruning + sharding
        ("bench_distributed", "distributed"),  # devices × storage backends
        ("bench_rewriter", "sec5"),         # §5: rewriter overhead
        ("bench_kernels", "kernels"),       # §3: per-encoding decode bw
        ("roofline", "roofline"),           # §Roofline from dry-run JSONs
    ]
    if args.smoke:
        suites = [s for s in suites
                  if s[0] in ("bench_queries", "bench_scan_plan",
                              "bench_concurrent", "bench_dataset",
                              "bench_distributed")]
    if args.only:
        keep = set(args.only.split(","))
        suites = [s for s in suites if s[0] in keep]

    tracer = None
    if args.trace:
        from repro.core import trace
        from benchmarks.common import RESULTS_DIR
        tracer = trace.enable()

    print("name,us_per_call,derived")
    failures = []
    suffix = "_smoke" if args.smoke else ""
    for mod_name, tag in suites:
        try:
            if tracer is not None:
                tracer.clear()
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            mod.run()
            flush_csv(f"{tag}{suffix}.csv")
            if tracer is not None:
                tracer.export(os.path.join(RESULTS_DIR,
                                           f"trace_{tag}{suffix}.json"))
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if tracer is not None:
        from repro.core import trace
        trace.disable()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
