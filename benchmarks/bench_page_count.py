"""Fig. 2(a): storage-bus bandwidth vs page count (one simulated SSD).

The decode stage parallelizes across pages — on the TPU target, grid step
= page (Insight 1).  Per-page decode costs are **measured** on this host;
the page-parallel decoder is **modeled** as an LPT schedule onto a
128-lane grid (labeled): one page per chunk serializes the whole chunk,
~100+ pages let the grid work, beyond that the (modeled) lane is the
bottleneck and the curve flattens — the paper's shape.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, ensure_tpch
from repro.core.config import CPU_DEFAULT, EncodingPolicy, FileConfig
from repro.core.encodings import Encoding, decode_page, decode_plain_page
from repro.core.query import Q6_COLUMNS
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.storage import SimulatedStorage

PAGE_COUNTS = (1, 4, 16, 64, 100, 256)
GRID_LANES = 128


def _page_decode_times(reader) -> list:
    """Measured serial decode time of every page."""
    times = []
    for rg in reader.meta.row_groups:
        for name in Q6_COLUMNS:
            chunk = rg.column(name)
            field = reader.meta.schema.field(name)
            raw = reader.read_chunk_bytes(chunk)
            dict_payload, pages = reader.chunk_pages(chunk, raw)
            dictionary = None
            if dict_payload is not None:
                dp = chunk.dict_page
                dictionary = decode_plain_page(dict_payload, dp.n_values,
                                               field, dp.extra)
            enc = Encoding(chunk.encoding)
            for pm, payload in pages:
                t0 = time.perf_counter()
                decode_page(enc, payload, pm.n_values, field, pm.extra,
                            dictionary)
                times.append(time.perf_counter() - t0)
    return times


def _lpt(times: list, lanes: int) -> float:
    load = np.zeros(lanes)
    for t in sorted(times, reverse=True):
        i = int(np.argmin(load))
        load[i] += t
    return float(load.max()) if times else 0.0


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT.replace(rows_per_rg=1_000_000),
                       "fig2a_base")
    for pages in PAGE_COUNTS:
        cfg = FileConfig(rows_per_rg=1_000_000,
                         target_pages_per_chunk=pages,
                         encodings=EncodingPolicy.V1_ONLY)
        path = base["lineitem_path"] + f".p{pages}"
        rewrite_file(base["lineitem_path"], path, cfg,
                     columns=list(Q6_COLUMNS))
        reader = TabFileReader(path)
        stored = sum(rg.column(c).stored_bytes
                     for rg in reader.meta.row_groups for c in Q6_COLUMNS)
        page_times = min((_page_decode_times(reader) for _ in range(3)),
                         key=sum)
        decode_s = _lpt(page_times, GRID_LANES)
        sim = SimulatedStorage(path, n_lanes=1)
        io_s = sum(sim.batch_seconds(
            [rg.column(c).byte_range[1] for c in Q6_COLUMNS])
            for rg in reader.meta.row_groups)
        pipeline_s = max(io_s, decode_s)
        bw = stored / pipeline_s
        emit(f"fig2a_pages_{pages}", pipeline_s * 1e6,
             f"storage_bus_GBps={bw/1e9:.3f};"
             f"grid_decode_s={decode_s:.5f};io_sim_s={io_s:.5f};"
             f"n_pages={len(page_times)}")
