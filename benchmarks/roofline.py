"""§Roofline: three-term analysis of every dry-run cell.

Terms (seconds, per device — HLO numbers from the compiled per-device
program; TPU v5e constants from launch.mesh):

  compute    = dot_FLOPs / 197e12
  memory     = HLO_bytes / 819e9
  collective = collective_bytes / 50e9

MODEL_FLOPS: 6·N·D for train (N_active for MoE), 2·N_active·D for
prefill/decode.  useful-compute time / dominant term = the roofline
fraction; MODEL_FLOPS / HLO_dot_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def model_flops(rec: dict) -> float:
    sh = SHAPES[rec["shape"]]
    n_act = rec["model_params_active"]
    if sh.kind == "train":
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * n_act * tokens
    if sh.kind == "prefill":
        tokens = sh.seq_len * sh.global_batch
        return 2.0 * n_act * tokens
    return 2.0 * n_act * sh.global_batch          # decode: 1 token/seq


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "hlo" not in rec:
        return None
    n_dev = rec["n_devices"]
    dot = rec["hlo"]["dot_flops_per_device"]
    mem = rec["hlo"]["memory_bytes_per_device"]
    coll = rec["collectives"]["total_bytes"]
    t_c = dot / PEAK_FLOPS_BF16
    t_m = mem / HBM_BW
    t_x = coll / ICI_LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops(rec)
    useful_t = (mf / n_dev) / PEAK_FLOPS_BF16
    frac = useful_t / max(dom[0], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec["mesh"], "variant": rec.get("variant", "baseline"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[1], "bound_s": dom[0],
        "model_flops": mf,
        "useful_ratio": mf / max(dot * n_dev, 1e-30),
        "roofline_fraction": min(frac, 1.0),
    }


def suggestion(row: dict, rec: dict) -> str:
    dom = row["dominant"]
    kind = SHAPES[row["shape"]].kind
    if dom == "compute" and row["useful_ratio"] < 0.5 and kind == "train":
        return ("remat recompute dominates dot-FLOPs: move remat "
                "full→dots (saves matmul outputs, recomputes elementwise)")
    if dom == "compute" and kind == "prefill":
        return ("quadratic attention flops: causal block-skipping in the "
                "kv scan halves compute")
    if dom == "memory" and kind == "decode":
        return ("cache-bandwidth bound: int8/bf16 KV cache or wider "
                "cache-length sharding spreads reads")
    if dom == "memory":
        return ("HBM traffic: larger fusion regions / bf16 accumulators / "
                "reduce activation copies between sharded ops")
    if dom == "collective" and rec.get("zero"):
        return ("FSDP all-gathers dominate: raise grad_accum (amortize "
                "per-microbatch gathers) or drop zero on the small leaves")
    if dom == "collective":
        return ("all_to_all/all-reduce bound: overlap dispatch with "
                "shared-expert compute; bf16 reductions")
    return "balanced: push MXU utilization via larger microbatches"


def load_cells(mesh: str = "single_pod") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        rec = json.load(open(f))
        row = analyze_cell(rec)
        if row is not None:
            row["suggestion"] = suggestion(row, rec)
            row["_rec"] = rec
            out.append(row)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": mesh, "skipped": rec["reason"],
                        "variant": rec.get("variant", "baseline")})
    return out


def markdown_table(mesh: str = "single_pod",
                   variant: str = "baseline") -> str:
    rows = [r for r in load_cells(mesh)
            if r.get("variant", "baseline") == variant]
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO flops | roofline frac | what would move it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | {r['skipped']} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['suggestion']} |")
    return "\n".join(lines)


def run() -> None:
    from benchmarks.common import emit
    for r in load_cells("single_pod"):
        if "skipped" in r:
            continue
        emit(f"roofline_{r['arch']}_{r['shape']}", r["bound_s"] * 1e6,
             f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_ratio']:.2f}")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("## Roofline (single-pod 16x16, baseline)\n\n")
        f.write(markdown_table())
        f.write("\n")


if __name__ == "__main__":
    run()
