"""Fig. 2(b): storage-bus bandwidth vs rows-per-row-group (one SSD).

Small RGs produce ~100 KB column chunks whose per-request latency starves
the accelerator DMA path (Insight 2); million-row RGs reach MiB-scale
transfers and saturate the lane.
"""

from __future__ import annotations

from benchmarks.common import BENCH_SF, emit, ensure_tpch
from repro.core.config import CPU_DEFAULT, EncodingPolicy, FileConfig
from repro.core.query import Q6_COLUMNS
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.storage import SimulatedStorage

RG_SIZES = (12_288, 61_440, 122_880, 500_000, 1_000_000, 4_000_000)


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT.replace(rows_per_rg=1_000_000),
                       "fig2b_base")
    n_rows = TabFileReader(base["lineitem_path"]).meta.num_rows
    for rg in RG_SIZES:
        if rg > n_rows * 4:
            continue
        cfg = FileConfig(rows_per_rg=rg, target_pages_per_chunk=100,
                         encodings=EncodingPolicy.V1_ONLY)
        path = base["lineitem_path"] + f".rg{rg}"
        rewrite_file(base["lineitem_path"], path, cfg,
                     columns=list(Q6_COLUMNS))
        reader = TabFileReader(path)
        sim = SimulatedStorage(path, n_lanes=1)
        stored = 0
        io_s = 0.0
        chunk_sizes = []
        for rgm in reader.meta.row_groups:
            sizes = [rgm.column(c).byte_range[1] for c in Q6_COLUMNS]
            chunk_sizes += sizes
            stored += sum(rgm.column(c).stored_bytes for c in Q6_COLUMNS)
            io_s += sim.batch_seconds(sizes)
        bw = stored / io_s
        emit(f"fig2b_rg_{rg}", io_s * 1e6,
             f"storage_bus_GBps={bw/1e9:.3f};"
             f"mean_chunk_KB={sum(chunk_sizes)/len(chunk_sizes)/1e3:.0f};"
             f"n_rgs={len(reader.meta.row_groups)}")
