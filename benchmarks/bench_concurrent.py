"""Concurrent-scan throughput: N overlapped Q6/Q12 scans through the
shared ScanService vs the same N scans run back-to-back.

The ROADMAP north star is a serving loop running *many small scans*
concurrently; PR 2's executor gave each scan a private pipeline, so the
pipeline head/tail (first RG with nothing overlapped, last consume with
nothing decoding behind it) went idle N times and concurrent callers
fought over cores.  The ScanService (core/scheduler.py) shares one fetch
thread + one decode pool across scans, so scan B's chunks decode inside
scan A's bubbles.

For N ∈ {1, 2, 4, 8} this suite measures the *measured* aggregate wall
(real thread overlap — the modeled per-scan schedule cannot see cross-scan
sharing) plus per-scan p50/p95, and the deterministic launch / I/O-request
economy (totals across the N scans; the CI gate fails on any increase).
Storage is the calibrated sim backend (host-instant reads), decode the
host backend — the same shape as the fig5 rows.

Concurrent identical scans additionally exercise **cooperative scans**:
a scan subscribes to an already-in-flight fetch+decode job for the same
(file, columns, backend) row group instead of redoing the work, so the
service arm's fetched-request count (``io_fetched``) can only ever be
*lower* than the sequential arm's gated ``io_requests``.

The multi-tenant front end (DESIGN.md §11) adds mixed-tenant rows at
serving fan-out (N ∈ {16, 64}: gold weight 4 / bronze weight 1 through
one windowed service — per-class p50/p95/p99 latencies and window/share
counters, with a cold sequential companion row carrying the gated
deterministic counts) and the ``conc_q6_window_repeat`` pin: a repeat
identical Q6 after the first completes must be served from the
delivered-result window with ``io_requests=0`` (gated exact).

Best-of-BENCH_ROUNDS like every suite; rounds interleave the sequential
and concurrent arms so a noisy scheduler window penalizes both equally.
Smoke mode (CI) runs N = 4 only (the gated rows).

Standalone:  python -m benchmarks.bench_concurrent --smoke
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import emit, emit_cpu_reference, ensure_tpch
from repro.core.config import CPU_DEFAULT, ACCELERATOR_OPTIMIZED
from repro.core.query import (Q12_LINEITEM_COLUMNS, Q12_ORDERS_COLUMNS,
                              Q6_COLUMNS, q6, q12)
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner
from repro.core.scheduler import ScanService
from repro.kernels.common import kernel_launch_count


def _q6_scanner(lpath: str):
    return open_scanner(lpath, columns=list(Q6_COLUMNS), backend="sim",
                        n_lanes=1, decode_backend="host")


def _q12_scanners(lpath: str, opath: str):
    return (open_scanner(lpath, columns=Q12_LINEITEM_COLUMNS, backend="sim",
                         n_lanes=1, decode_backend="host"),
            open_scanner(opath, columns=Q12_ORDERS_COLUMNS, backend="sim",
                         n_lanes=1, decode_backend="host"))


def _run_n(make_job, n: int, service: ScanService, concurrent: bool
           ) -> tuple[float, list[float], dict[str, int]]:
    """Run n scan jobs; returns (aggregate wall, per-scan walls, counters).

    ``make_job(k, service)`` returns a zero-arg callable executing one full
    scan k through ``service``.  Counters are totals across the n scans —
    deterministic, so concurrency must not change them ("zero increase in
    launches or I/O requests per scan").
    """
    jobs = [make_job(k, service) for k in range(n)]
    walls = [0.0] * n
    launches0 = kernel_launch_count()
    shared0 = service.shared_rgs

    def one(k: int) -> None:
        t0 = time.perf_counter()
        jobs[k]()
        walls[k] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if concurrent:
        threads = [threading.Thread(target=one, args=(k,)) for k in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for k in range(n):
            one(k)
    agg = time.perf_counter() - t0
    counters = {"launches": kernel_launch_count() - launches0,
                "io_requests": sum(getattr(j, "io_requests", 0)
                                   for j in jobs),
                "shared_rgs": service.shared_rgs - shared0}
    return agg, walls, counters


def _emit_pair(name: str, n: int, service: ScanService, make_job,
               rounds: int) -> None:
    """Best-of-rounds sequential vs concurrent rows for one workload."""
    best = {}   # arm -> (agg, walls, counters)
    for _ in range(rounds):
        for arm, concurrent in (("sequential", False), ("service", True)):
            agg, walls, counters = _run_n(make_job, n, service, concurrent)
            if arm not in best or agg < best[arm][0]:
                best[arm] = (agg, walls, counters)
    seq_agg = best["sequential"][0]
    for arm in ("sequential", "service"):
        agg, walls, counters = best[arm]
        # the sequential arm's request count is deterministic → gated
        # (``io_requests=``); the service arm's depends on how many RGs
        # cooperative subscription happened to share (thread timing), so it
        # is emitted under a non-gated name — it can only ever be LOWER
        # than the sequential count, never higher
        io_key = "io_requests" if arm == "sequential" else "io_fetched"
        derived = (f"p50_us={np.percentile(walls, 50) * 1e6:.0f};"
                   f"p95_us={np.percentile(walls, 95) * 1e6:.0f};"
                   f"launches={counters['launches']};"
                   f"{io_key}={counters['io_requests']};"
                   f"shared_rgs={counters['shared_rgs']};"
                   f"speedup_vs_seq={seq_agg / max(agg, 1e-12):.2f}x;"
                   f"n={n};measured")
        emit(f"conc_{name}_n{n}_{arm}", agg * 1e6, derived)


def _emit_mixed(name: str, n: int, lpath: str, rounds: int) -> None:
    """Mixed-tenant serving shape (DESIGN.md §11): n identical Q6 scans,
    alternately submitted by a weight-4 ``gold`` and a weight-1
    ``bronze`` tenant through one windowed multi-tenant service.

    Two rows per n: the cold **sequential** companion arm clears the
    delivered-result window before every scan, so its launch/io_request
    totals are deterministic (gated exact); the **service** arm runs all
    n concurrently with the window live and reports per-class latency
    percentiles plus window/sharing counters — informational (thread
    timing), the fetch count can only ever be lower than the gated
    sequential count."""
    best: dict[str, tuple] = {}
    for _ in range(rounds):
        # -- gated cold sequential arm ---------------------------------
        svc = ScanService(window_bytes=64 << 20)
        svc.register_tenant("gold", weight=4)
        svc.register_tenant("bronze", weight=1)
        launches0 = kernel_launch_count()
        io_total = 0
        t0 = time.perf_counter()
        for k in range(n):
            svc.clear_delivered_window()          # every scan runs cold
            _, rep = q6(_q6_scanner(lpath), prune=False, service=svc,
                        tenant="gold" if k % 2 == 0 else "bronze")
            io_total += rep.metrics.n_io_requests
        agg = time.perf_counter() - t0
        counters = {"launches": kernel_launch_count() - launches0,
                    "io_requests": io_total}
        svc.shutdown()
        if "seq" not in best or agg < best["seq"][0]:
            best["seq"] = (agg, counters)

        # -- concurrent mixed-tenant arm (window live) -----------------
        svc = ScanService(window_bytes=64 << 20)
        svc.register_tenant("gold", weight=4)
        svc.register_tenant("bronze", weight=1)
        walls: dict[str, list[float]] = {"gold": [], "bronze": []}
        io_fetched = [0]
        lock = threading.Lock()

        def one(k: int) -> None:
            tenant = "gold" if k % 2 == 0 else "bronze"
            t1 = time.perf_counter()
            _, rep = q6(_q6_scanner(lpath), prune=False, service=svc,
                        tenant=tenant)
            dt = time.perf_counter() - t1
            with lock:
                walls[tenant].append(dt)
                io_fetched[0] += rep.metrics.n_io_requests

        t0 = time.perf_counter()
        threads = [threading.Thread(target=one, args=(k,))
                   for k in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        agg = time.perf_counter() - t0
        stats = {"io_fetched": io_fetched[0],
                 "window_hits": svc.window_hits,
                 "shared_rgs": svc.shared_rgs}
        svc.shutdown()
        if "service" not in best or agg < best["service"][0]:
            best["service"] = (agg, walls, stats)

    seq_agg, seq_counters = best["seq"]
    emit(f"conc_mixed_{name}_n{n}_seq", seq_agg * 1e6,
         f"launches={seq_counters['launches']};"
         f"io_requests={seq_counters['io_requests']};"
         f"n={n};measured")
    agg, walls, stats = best["service"]
    pct = {f"{cls}_p{p}_us": np.percentile(ws, p) * 1e6
           for cls, ws in walls.items() for p in (50, 95, 99)}
    emit(f"conc_mixed_{name}_n{n}_service", agg * 1e6,
         ";".join(f"{k}={v:.0f}" for k, v in pct.items()) + ";"
         f"io_fetched={stats['io_fetched']};"
         f"window_hits={stats['window_hits']};"
         f"shared_rgs={stats['shared_rgs']};"
         f"speedup_vs_seq={seq_agg / max(agg, 1e-12):.2f}x;"
         f"n={n};measured")


def _emit_window_repeat(lpath: str, rounds: int) -> None:
    """Deterministic delivered-window pin (the ISSUE's acceptance row):
    an identical Q6 submitted *after* the first completes is served
    entirely from the delivered-result window — the repeat arm's
    ``io_requests`` is gated (exactly zero; any fetch is a regression),
    the first run's count rides along as informational ``io_first``."""
    best = None
    for _ in range(rounds):
        svc = ScanService(window_bytes=64 << 20)
        svc.register_tenant("gold", weight=4)
        _, r1 = q6(_q6_scanner(lpath), prune=False, service=svc,
                   tenant="gold")
        t0 = time.perf_counter()
        _, r2 = q6(_q6_scanner(lpath), prune=False, service=svc,
                   tenant="gold")
        wall = time.perf_counter() - t0
        hits = svc.window_hits
        svc.shutdown()
        if best is None or wall < best[0]:
            best = (wall, r1.metrics.n_io_requests,
                    r2.metrics.n_io_requests, hits)
    wall, io_first, io_repeat, hits = best
    emit("conc_q6_window_repeat", wall * 1e6,
         f"io_requests={io_repeat};io_first={io_first};"
         f"window_hits={hits};measured")


def run() -> None:
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    emit_cpu_reference()   # lets the CI gate normalize by machine speed
    base = ensure_tpch(CPU_DEFAULT, "fig5_base")
    # Moderate row groups: each scan is a short pipeline (~5 RGs at smoke
    # SF, ~25 at the default SF) — the serving-loop shape where per-scan
    # head/tail bubbles and repeated decode work are what the shared pool
    # and cooperative-scan subscription recover.
    cfg = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=12_000,
                                        target_pages_per_chunk=4)
    lpath = base["lineitem_path"] + ".conc"
    opath = base["orders_path"] + ".conc"
    rewrite_file(base["lineitem_path"], lpath, cfg)
    rewrite_file(base["orders_path"], opath, cfg)
    rounds = max(1, int(os.environ.get("BENCH_ROUNDS", "3")))

    # One dedicated service for the whole suite: the serving-loop shape
    # (persistent pool, adaptive sizing warm).  Both arms run through it so
    # the comparison isolates *concurrency*, not pool spin-up.
    service = ScanService()

    def q6_job(k: int, svc: ScanService):
        sc = _q6_scanner(lpath)

        def job():
            rev, rep = q6(sc, prune=False, service=svc)
            job.io_requests = rep.metrics.n_io_requests
            return rev

        job.io_requests = 0
        return job

    def q12_job(k: int, svc: ScanService):
        lsc, osc = _q12_scanners(lpath, opath)

        def job():
            _, brep, prep = q12(lsc, osc, service=svc)
            job.io_requests = (brep.metrics.n_io_requests
                               + prep.metrics.n_io_requests)

        job.io_requests = 0
        return job

    # warm the jitted consumers + plan/dict caches outside timing
    q6(_q6_scanner(lpath), prune=False, service=service)
    q12(*_q12_scanners(lpath, opath), service=service)

    q6_ns = (4,) if smoke else (1, 2, 4, 8)
    q12_ns = (4,) if smoke else (1, 2, 4, 8)
    for n in q6_ns:
        _emit_pair("q6", n, service, q6_job, rounds)
    for n in q12_ns:
        _emit_pair("q12", n, service, q12_job, rounds)
    service.shutdown()

    # -- multi-tenant front end rows (DESIGN.md §11) -------------------
    # Mixed-tenant fleets at serving fan-out, plus the deterministic
    # window-repeat pin; mixed rounds are capped — each round already
    # aggregates n scans, so best-of-2 is stable.
    for n in (16, 64):
        _emit_mixed("q6", n, lpath, min(rounds, 2))
    _emit_window_repeat(lpath, rounds)


if __name__ == "__main__":
    import argparse

    from benchmarks.common import flush_csv

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (tiny SF, N ∈ {1,4})")
    args = ap.parse_args()
    if args.smoke:
        os.environ.setdefault("BENCH_SF", "0.01")
        os.environ.setdefault("BENCH_ROUNDS", "5")
        os.environ["BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    run()
    flush_csv(f"concurrent{'_smoke' if args.smoke else ''}.csv")
