"""Decode throughput per encoding (§3: "V2 encodings are also efficient").

Measured: vectorized host decoders (the CPU-measured analogue of the
VPU-shaped kernels).  The Pallas interpret path is correctness-only and
not timed (Python interpreter per grid step is not representative).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.encodings import (Encoding, decode_page,
                                  encode_chunk_with)
from repro.core.schema import Field, PhysicalType

N = 2_000_000


def run() -> None:
    rng = np.random.default_rng(0)
    cases = {
        "plain_f32": (rng.normal(size=N).astype(np.float32),
                      Encoding.PLAIN, PhysicalType.FLOAT),
        "delta_sorted_i64": (np.cumsum(rng.integers(0, 9, N)).astype(
            np.int64), Encoding.DELTA_BINARY_PACKED, PhysicalType.INT64),
        "dict_lowcard_i32": (rng.integers(0, 11, N).astype(np.int32),
                             Encoding.RLE_DICTIONARY, PhysicalType.INT32),
        "rle_runs_i32": (np.repeat(np.arange(N // 1000, dtype=np.int32),
                                   1000), Encoding.RLE,
                         PhysicalType.INT32),
        "bss_f32": (rng.normal(size=N).astype(np.float32),
                    Encoding.BYTE_STREAM_SPLIT, PhysicalType.FLOAT),
    }
    for name, (vals, enc, pt) in cases.items():
        field = Field("c", pt)
        ce = encode_chunk_with(enc, vals, field, [(0, N)])
        page = ce.pages[0]
        dict_vals = None
        if ce.dict_page is not None:
            from repro.core.encodings import decode_plain_page
            dict_vals = decode_plain_page(ce.dict_page.payload,
                                          ce.dict_page.n_values, field,
                                          ce.dict_page.extra)

        def dec():
            decode_page(enc, page.payload, page.n_values, field,
                        page.extra, dict_vals)

        s = timeit(dec, repeats=3)
        logical = vals.nbytes
        emit(f"kernel_host_{name}", s * 1e6,
             f"decode_GBps={logical/s/1e9:.2f};"
             f"encoded_ratio={logical/len(page.payload):.2f}")
