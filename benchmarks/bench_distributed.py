"""Distributed scans: fragment sharding across devices × storage backends.

Two storage arms over the same 16-fragment range-partitioned lineitem
dataset, each swept over devices ∈ {1, 2, 4} through
``run_distributed_scan`` (contiguous byte-balanced shards, per-device
ScanService, deterministic tree reduce — DESIGN.md §8):

  nvme_dN      the calibrated NVMe sim backend (accounts modeled time,
               wall stays real) — rows are machine-speed ``measured``
  remote_dN    the object-store backend with prefetch OFF
               (ObjectStoreStorage *sleeps* its modeled per-request
               latency, so remote waits dominate wall) — device workers
               overlap each other's fetch sleeps, the pure
               device-scaling story; sleep-dominated rows are tagged
               ``sim`` so the perf gate never machine-scales them
  remote_pf_dN the same remote profile with fragment-window prefetch on —
               the prefetcher hides fetch latency behind decode *within*
               one device, the orthogonal lever

Asserts, every run: the devices=4 aggregate is bit-identical to
devices=1 on every arm; remote d4 beats d1 by ≥ 1.5× (fetch sleeps
overlap across device workers); prefetch hides ≥ 50% of the modeled
fetch latency it touches (hidden / (hidden + stall)) and beats the
prefetch-off wall at d1.

Counters gated by tools/check_regression.py: ``launches`` and
``io_requests`` (prefetch accounts I/O at consumption, so requests stay
deterministic).  Prefetch hit/miss, latency percentiles, stolen
fragments and per-backend bytes ride along informationally.

Standalone:  python -m benchmarks.bench_distributed --smoke
"""

from __future__ import annotations

import os
import struct
import time

from benchmarks.common import emit, emit_cpu_reference, ensure_tpch
from repro.core.config import ACCELERATOR_OPTIMIZED, CPU_DEFAULT
from repro.core.query import q6
from repro.core.reader import TabFileReader
from repro.dataset import Dataset, write_dataset

TUNED = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=4_000,
                                      target_pages_per_chunk=4)
NVME_OPTS = {"backend": "sim", "decode_backend": "host"}
REMOTE_OPTS = {"backend": "object", "decode_backend": "host"}
REMOTE_PF_OPTS = {"backend": "object", "decode_backend": "host",
                  "prefetch": True}
DEVICES = (1, 2, 4)
N_FILES = 16


def _dataset(line_table, root: str) -> Dataset:
    if os.path.exists(os.path.join(root, "manifest.json")):
        return Dataset.load(root)
    return write_dataset(line_table, root, TUNED,
                         partition_by="l_shipdate", how="range",
                         fragments=N_FILES)


def _run(ds: Dataset, devices: int, opts: dict) -> tuple[float, dict]:
    t0 = time.perf_counter()
    # prune=False keeps all 16 fragments in play so every device shard
    # has work (the FY1994 predicate would prune to ~4 fragments)
    acc, rep = q6(ds, prune=False, devices=devices, open_opts=opts)
    wall = time.perf_counter() - t0
    pf_total = rep.prefetch_hidden_seconds + rep.prefetch_stall_seconds
    return wall, {
        "result": acc,
        "launches": rep.n_kernel_launches,
        "io_requests": rep.n_io_requests,
        "scanned": rep.files_scanned,
        "stolen_fragments": rep.stolen_fragments,
        "prefetch_hits": rep.prefetch_hits,
        "prefetch_misses": rep.prefetch_misses,
        "hidden_pct": (100.0 * rep.prefetch_hidden_seconds / pf_total
                       if pf_total > 0 else 0.0),
        "io_p50_us": rep.io_p50_us,
        "io_p95_us": rep.io_p95_us,
        "bytes_by_backend": rep.bytes_by_backend,
    }


def _emit_arm(name: str, wall: float, info: dict, base_wall: float,
              tag: str) -> None:
    backend_cols = "".join(f"bytes_{k}={v};" for k, v in
                           sorted(info["bytes_by_backend"].items()))
    emit(name, wall * 1e6,
         f"launches={info['launches']};io_requests={info['io_requests']};"
         f"scanned={info['scanned']};"
         f"stolen_fragments={info['stolen_fragments']};"
         f"prefetch_hits={info['prefetch_hits']};"
         f"prefetch_misses={info['prefetch_misses']};"
         f"hidden_pct={info['hidden_pct']:.0f};"
         f"io_p50_us={info['io_p50_us']:.0f};"
         f"io_p95_us={info['io_p95_us']:.0f};"
         f"{backend_cols}"
         f"speedup_vs_d1={base_wall / max(wall, 1e-12):.2f}x;{tag}")


def run() -> None:
    emit_cpu_reference()
    base = ensure_tpch(CPU_DEFAULT, "fig5_base")
    line = TabFileReader(base["lineitem_path"]).read_table()
    data_root = os.path.dirname(base["lineitem_path"])
    rounds = max(1, int(os.environ.get("BENCH_ROUNDS", "3")))
    ds = _dataset(line, os.path.join(data_root, f"ds_dist_{N_FILES}"))

    # warm decode-plan/dict caches and the jitted consumer outside timing
    q6(ds, prune=False, devices=1, open_opts=NVME_OPTS)

    best: dict = {}
    arms = ([(f"nvme_d{d}", d, NVME_OPTS) for d in DEVICES]
            + [(f"remote_d{d}", d, REMOTE_OPTS) for d in DEVICES]
            + [(f"remote_pf_d{d}", d, REMOTE_PF_OPTS) for d in (1, 4)])
    for _ in range(rounds):
        for arm, d, opts in arms:
            wall, info = _run(ds, d, opts)
            if arm not in best or wall < best[arm][0]:
                best[arm] = (wall, info)

    # multi-device reduce is bit-identical to single-device on every arm
    ref = struct.pack("<d", best["nvme_d1"][1]["result"])
    for arm in best:
        assert struct.pack("<d", best[arm][1]["result"]) == ref, \
            (arm, best[arm][1]["result"])
    # device workers overlap each other's remote fetch sleeps: ≥ 1.5×
    d1, d4 = best["remote_d1"][0], best["remote_d4"][0]
    assert d1 / d4 >= 1.5, f"remote d4 speedup {d1 / d4:.2f}x < 1.5x"
    # prefetch hides ≥ half the modeled fetch latency it touches, and
    # beats the prefetch-off wall outright at d1
    hp = best["remote_pf_d1"][1]["hidden_pct"]
    assert hp >= 50.0, f"prefetch hid only {hp:.0f}% of fetch latency"
    assert best["remote_pf_d1"][0] < best["remote_d1"][0]

    for fam, devs, tag in (("nvme", DEVICES, "measured"),
                           ("remote", DEVICES, "sim"),
                           ("remote_pf", (1, 4), "sim")):
        base_wall = best[f"{fam}_d1"][0]
        for d in devs:
            arm = f"{fam}_d{d}"
            _emit_arm(f"dist_q6_{arm}", best[arm][0], best[arm][1],
                      base_wall, tag)


if __name__ == "__main__":
    import argparse

    from benchmarks.common import flush_csv

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (tiny SF)")
    args = ap.parse_args()
    if args.smoke:
        os.environ.setdefault("BENCH_SF", "0.01")
        os.environ.setdefault("BENCH_ROUNDS", "3")
        os.environ["BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    run()
    flush_csv(f"distributed{'_smoke' if args.smoke else ''}.csv")
