"""Dataset-layer throughput: partitioned multi-file Q6 through the
pruning planner + sharded ScanService executor (repro.dataset).

Three comparisons, each over range-partitioned (l_shipdate) lineitem
datasets of files ∈ {1, 4, 16}:

  seq        the status-quo client: a per-file loop running q6 over
             every fragment back to back (row-group zone maps on, no
             file-level pruning, no cross-file overlap)
  sharded    the dataset executor: manifest pruning (partition ranges +
             file zone maps under the FY1994 predicate), surviving
             fragments scanned concurrently through the shared
             ScanService with a bounded window
  unpruned   the sharded executor with pruning disabled — isolates the
             pruning contribution, and every round asserts its result is
             bit-identical to the pruned arm (plan-order reduction)

plus, at 16 files, **compacted vs raw**: the same rows ingested as
CPU-default fragments (1 page/chunk, blind gzip) scanned as-is vs after
``compact_dataset`` rewrote them to the tuned config behind the atomic
manifest swap.

Counters (gated by tools/check_regression.py): ``launches`` and
``io_requests`` are deterministic — file pruning must keep lowering
requests, and concurrency must never raise them.  Storage is the
calibrated sim backend, decode the host backend (fig5 shape).

Standalone:  python -m benchmarks.bench_dataset --smoke
"""

from __future__ import annotations

import os
import time

from benchmarks.common import emit, emit_cpu_reference, ensure_tpch
from repro.core.config import ACCELERATOR_OPTIMIZED, CPU_DEFAULT
from repro.core.query import Q6_COLUMNS, q6
from repro.core.reader import TabFileReader
from repro.core.scheduler import ScanService
from repro.dataset import (Dataset, compact_dataset, plan_dataset_scan,
                           write_dataset)

SIM_OPTS = {"backend": "sim", "decode_backend": "host"}
TUNED = ACCELERATOR_OPTIMIZED.replace(rows_per_rg=4_000,
                                      target_pages_per_chunk=4)
FILES = (1, 4, 16)
WINDOW = 4


def _dataset(line_table, root: str, n_files: int, config) -> Dataset:
    if os.path.exists(os.path.join(root, "manifest.json")):
        return Dataset.load(root)
    return write_dataset(line_table, root, config,
                         partition_by="l_shipdate", how="range",
                         fragments=n_files)


def _seq_loop(ds: Dataset, service: ScanService) -> tuple[float, dict]:
    """Per-file q6 loop over every fragment (no manifest pruning)."""
    total = None
    io_requests = 0
    t0 = time.perf_counter()
    for frag in ds.fragments:
        sc = ds.open_fragment(frag, columns=list(Q6_COLUMNS), **SIM_OPTS)
        acc, rep = q6(sc, prune=True, service=service)
        io_requests += rep.metrics.n_io_requests
        total = acc if total is None else total + acc
    wall = time.perf_counter() - t0
    return wall, {"result": total, "io_requests": io_requests,
                  "launches": 0, "files": len(ds.fragments),
                  "scanned": len(ds.fragments), "pruned": 0}


def _sharded(ds: Dataset, service: ScanService, prune: bool
             ) -> tuple[float, dict]:
    t0 = time.perf_counter()
    acc, rep = q6(ds, prune=prune, service=service, window=WINDOW,
                  open_opts=SIM_OPTS)
    wall = time.perf_counter() - t0
    return wall, {"result": acc, "io_requests": rep.n_io_requests,
                  "launches": rep.n_kernel_launches,
                  "files": rep.files_total, "scanned": rep.files_scanned,
                  "pruned": rep.files_pruned, "retries": rep.retries,
                  "fragments_quarantined": rep.fragments_quarantined}


def _emit_arm(name: str, wall: float, info: dict, seq_wall: float) -> None:
    emit(name, wall * 1e6,
         f"launches={info['launches']};io_requests={info['io_requests']};"
         f"files={info['files']};scanned={info['scanned']};"
         f"pruned={info['pruned']};"
         f"retries={info.get('retries', 0)};"
         f"fragments_quarantined={info.get('fragments_quarantined', 0)};"
         f"speedup_vs_seq={seq_wall / max(wall, 1e-12):.2f}x;measured")


def run() -> None:
    emit_cpu_reference()
    base = ensure_tpch(CPU_DEFAULT, "fig5_base")
    line = TabFileReader(base["lineitem_path"]).read_table()
    data_root = os.path.dirname(base["lineitem_path"])
    rounds = max(1, int(os.environ.get("BENCH_ROUNDS", "3")))
    service = ScanService()

    datasets = {f: _dataset(line, os.path.join(data_root, f"ds_{f}"),
                            f, TUNED) for f in FILES}
    raw_root = os.path.join(data_root, "ds_raw_16")
    raw_is_new = not os.path.exists(os.path.join(raw_root,
                                                 "manifest.json"))
    raw = _dataset(line, raw_root, 16, CPU_DEFAULT)
    compact_root = os.path.join(data_root, "ds_compacted_16")
    if not os.path.exists(os.path.join(compact_root, "manifest.json")):
        # compact a private copy so the raw arm keeps its raw files
        compacted = _dataset(line, compact_root, 16, CPU_DEFAULT)
        compact_dataset(compacted, target_config=TUNED)
    compacted = Dataset.load(compact_root)
    if raw_is_new:
        # sanity: the pruning planner sees the paper's FY1994 shape
        from repro.core.query import q6_rg_stats_predicate
        p = plan_dataset_scan(datasets[16],
                              predicate_stats=q6_rg_stats_predicate)
        assert p.files_pruned >= 8, p.summary()

    # warm plan/dict caches and the jitted consumers outside timing
    for ds in (*datasets.values(), raw, compacted):
        q6(ds, prune=False, service=service, window=WINDOW,
           open_opts=SIM_OPTS)

    for f in FILES:
        ds = datasets[f]
        best: dict = {}
        for _ in range(rounds):
            for arm, fn in (("seq", lambda d=ds: _seq_loop(d, service)),
                            ("sharded", lambda d=ds: _sharded(
                                d, service, prune=True)),
                            ("unpruned", lambda d=ds: _sharded(
                                d, service, prune=False))):
                wall, info = fn()
                if arm not in best or wall < best[arm][0]:
                    best[arm] = (wall, info)
        # pruning correctness: bit-identical to the full scan, every time
        assert best["sharded"][1]["result"] == best["unpruned"][1]["result"]
        seq_wall = best["seq"][0]
        for arm in ("seq", "sharded", "unpruned"):
            _emit_arm(f"ds_q6_f{f}_{arm}", best[arm][0], best[arm][1],
                      seq_wall)

    best = {}
    for _ in range(rounds):
        for arm, d in (("raw", raw), ("compacted", compacted)):
            wall, info = _sharded(d, service, prune=True)
            if arm not in best or wall < best[arm][0]:
                best[arm] = (wall, info)
    raw_wall = best["raw"][0]
    for arm in ("raw", "compacted"):
        _emit_arm(f"ds_q6_16_{arm}", best[arm][0], best[arm][1], raw_wall)
    service.shutdown()


if __name__ == "__main__":
    import argparse

    from benchmarks.common import flush_csv

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (tiny SF)")
    args = ap.parse_args()
    if args.smoke:
        os.environ.setdefault("BENCH_SF", "0.01")
        os.environ.setdefault("BENCH_ROUNDS", "5")
        os.environ["BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    run()
    flush_csv(f"dataset{'_smoke' if args.smoke else ''}.csv")
