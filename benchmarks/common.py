"""Shared benchmark infrastructure.

Dataset scale: BENCH_SF (default 0.05 ≈ 300k lineitem rows).  Storage-lane
numbers come from the calibrated simulator (labeled ``sim``); decode and
rewrite times are measured on this host (labeled ``measured``).
"""

from __future__ import annotations

import os
import time
from collections.abc import Callable

import numpy as np

BENCH_SF = float(os.environ.get("BENCH_SF", "0.05"))
DATA_DIR = os.environ.get("BENCH_DATA", "/tmp/repro_bench")
RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/benchmarks")

_ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def flush_csv(filename: str) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, filename), "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in _ROWS:
            f.write(r + "\n")
    _ROWS.clear()


def ensure_tpch(config, tag: str, sf: float = None) -> dict:
    """Write (or reuse) a TPC-H pair under the given file config."""
    from repro.data import tpch
    sf = BENCH_SF if sf is None else sf
    d = os.path.join(DATA_DIR, f"tpch_{tag}_sf{sf}")
    lpath = os.path.join(d, "lineitem.tab")
    if os.path.exists(lpath):
        return {"lineitem_path": lpath,
                "orders_path": os.path.join(d, "orders.tab")}
    metas = tpch.write_tpch(d, sf=sf, config=config, seed=1234,
                            include_strings=False, threads=4)
    return metas


def cpu_reference_seconds() -> float:
    """Fixed decode-shaped workload (zlib inflate + numpy widen/cumsum),
    best of 5.  Emitted as a ``cpu_reference`` row in the smoke CSVs so
    tools/check_regression.py can normalize wall times by machine speed —
    without it, a slower CI runner (or a noisy window on a shared host)
    reads as a perf regression of every row at once."""
    import zlib
    rng = np.random.default_rng(0)
    data = rng.integers(0, 50, 1_000_000).astype(np.int32).tobytes()
    comp = zlib.compress(data, 1)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        zlib.decompress(comp)
        np.frombuffer(data, np.int32).astype(np.int64).cumsum()
        best = min(best, time.perf_counter() - t0)
    return best


def emit_cpu_reference() -> None:
    emit("cpu_reference", cpu_reference_seconds() * 1e6,
         "machine-speed calibration;measured")


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1,
           reduce: str = "median") -> float:
    """``reduce="min"`` filters scheduler noise on shared/throttled hosts
    (the CI perf gate compares these numbers across runs); median remains
    the default for suites that want a typical-case figure."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.min(times) if reduce == "min" else np.median(times))
