"""§5 overheads: rewriter throughput and thread scaling.

The paper reports minutes for 100 GB with a multithreaded Rust rewriter
and 10-20% extra write time for the closest proprietary tool; we report
logical MB/s on this host and the thread-scaling curve.
"""

from __future__ import annotations

from benchmarks.common import emit, ensure_tpch
from repro.core.config import ACCELERATOR_OPTIMIZED, CPU_DEFAULT
from repro.core.rewriter import rewrite_file


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT.replace(rows_per_rg=500_000),
                       "rw_base")
    for threads in (1, 2, 4, 8):
        rep = rewrite_file(base["lineitem_path"],
                           base["lineitem_path"] + f".rw{threads}",
                           ACCELERATOR_OPTIMIZED.replace(
                               rows_per_rg=1_000_000),
                           threads=threads)
        emit(f"rewriter_threads_{threads}", rep.seconds * 1e6,
             f"logical_MBps={rep.rewrite_bandwidth/1e6:.1f};"
             f"size_ratio={rep.size_ratio:.3f}")
