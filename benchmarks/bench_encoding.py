"""Fig. 3: effective bandwidth vs SSD count, with and without encoding
flexibility (Insight 3) and selective compression (Insight 4).

Effective bandwidth = logical raw bytes after decode ÷ overlapped scan
time (modeled storage ∥ measured decode).  Compression ratios are
annotated like the paper's figure.
"""

from __future__ import annotations

from benchmarks.common import emit, ensure_tpch
from repro.core.compression import chunk_decompress_memo
from repro.core.config import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT,
                               CompressionSpec, EncodingPolicy, FileConfig)
from repro.core.scheduler import clear_delivered_windows
from repro.dataset.result_cache import clear_all_result_caches
from repro.kernels.dict_decode import dict_cache_clear
from repro.core.query import Q6_COLUMNS
from repro.core.reader import TabFileReader
from repro.core.rewriter import rewrite_file
from repro.core.scan import open_scanner

LANES = (1, 2, 4)

CONFIGS = {
    "rg_size_v1": FileConfig(rows_per_rg=1_000_000,
                             target_pages_per_chunk=100,
                             encodings=EncodingPolicy.V1_ONLY,
                             compression=CompressionSpec(codec="gzip",
                                                         min_gain=0.0)),
    "encoding_flex": FileConfig(rows_per_rg=1_000_000,
                                target_pages_per_chunk=100,
                                encodings=EncodingPolicy.FLEX,
                                compression=CompressionSpec(
                                    codec="gzip", min_gain=0.0)),
    "optimized": ACCELERATOR_OPTIMIZED.replace(rows_per_rg=1_000_000),
}


def run() -> None:
    base = ensure_tpch(CPU_DEFAULT.replace(rows_per_rg=1_000_000),
                       "fig3_base")
    for name, cfg in CONFIGS.items():
        path = base["lineitem_path"] + f".{name}"
        rewrite_file(base["lineitem_path"], path, cfg)
        meta = TabFileReader(path).meta
        ratio = meta.logical_nbytes / max(1, meta.stored_bytes)
        # full logical table; best-of-3 to damp host-decode jitter.
        # Cold-scan per round: a hot decompress memo / dict cache would
        # erase exactly the gzip decode cost this figure shows
        # (tests/test_system.py clears the same way).
        for lanes in LANES:
            best = None
            for _ in range(3):
                chunk_decompress_memo().clear()
                dict_cache_clear()
                clear_delivered_windows()
                clear_all_result_caches()
                sc = open_scanner(path, columns=None,
                                  backend="sim", n_lanes=lanes,
                                  decode_backend="host")
                _, m = sc.scan_with_metrics()
                assert sc.storage.stats.requests > 0, \
                    "cold arm was served from a cache"
                if best is None or m.overlapped_seconds \
                        < best.overlapped_seconds:
                    best = m
            ebw = best.effective_bandwidth(overlapped=True)
            emit(f"fig3_{name}_ssd{lanes}",
                 best.overlapped_seconds * 1e6,
                 f"effective_GBps={ebw/1e9:.3f};ratio={ratio:.2f};"
                 f"stored_MB={meta.stored_bytes/1e6:.1f}")
