"""Pallas kernel: fused blockwise (flash) attention with online softmax.

The perf-critical attention hot loop as an explicit TPU kernel: one grid
step computes one q block for one (batch·head); K/V rows stream through
VMEM; GQA is expressed in the K/V BlockSpec index maps (head h reads KV
head h // group — no materialized head expansion); causal blocks beyond
the q block are skipped via the fori upper bound, so compute is the
causal half, not the full S².

VMEM sizing: this variant holds one (S, dh) K/V row per grid step —
fine to ~16k×128 bf16.  Longer sequences would add a third grid dim with
revisited outputs; the jnp blockwise path in models/attention.py remains
the production fallback and the numerical oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, scale: float,
            causal: bool, cap: float, seq_len: int):
    qb = q_ref.shape[1]
    dh = q_ref.shape[2]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale               # (qb, dh)
    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)

    n_kv = seq_len // kv_block
    if causal:
        # only kv blocks that intersect the causal triangle
        n_kv_eff = jnp.minimum(((qi + 1) * qb + kv_block - 1) // kv_block,
                               n_kv)
    else:
        n_kv_eff = n_kv

    def body(ki, carry):
        m, l, acc = carry
        kblk = pl.load(k_ref, (0, pl.dslice(ki * kv_block, kv_block),
                               slice(None))).astype(jnp.float32)
        vblk = pl.load(v_ref, (0, pl.dslice(ki * kv_block, kv_block),
                               slice(None))).astype(jnp.float32)
        s = q @ kblk.T                                     # (qb, kv_block)
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1)
        mask = jnp.ones((qb, kv_block), jnp.bool_)
        if causal:
            mask = kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * corr + p @ vblk
        return m_new, l_new, acc_new

    m0 = jnp.full((qb, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb, 1), jnp.float32)
    a0 = jnp.zeros((qb, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "cap", "q_block", "kv_block", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, cap: float = 0.0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q (B,S,H,dh), k/v (B,S,KV,dh) → (B,S,H,dh).  S % blocks == 0."""
    if interpret is None:
        interpret = interpret_default()
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = dh ** -0.5
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dh)
    nq = s // q_block

    out = pl.pallas_call(
        functools.partial(_kernel, kv_block=kv_block, scale=scale,
                          causal=causal, cap=cap, seq_len=s),
        grid=(b * h, nq),
        in_specs=[
            pl.BlockSpec((1, q_block, dh), lambda bh, qi: (bh, qi, 0)),
            # GQA via index map: query head bh reads KV row bh // g
            pl.BlockSpec((1, s, dh), lambda bh, qi: (bh // g, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda bh, qi: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, dh),
                               lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
