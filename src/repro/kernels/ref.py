"""Pure-jnp oracles for every Pallas kernel (same padded-array contracts).

Tests assert_allclose each kernel (interpret=True) against these across
shape/dtype sweeps; the host numpy decoders in core/encodings.py are a
second, independent oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (BLOCK_VALUES, LANES, MB_GROUPS, MB_VALUES,
                                  MINIBLOCKS)


def unpack_words_static_ref(words: jnp.ndarray, width: int) -> jnp.ndarray:
    g = words.shape[0] // width
    w = words.reshape(g, width).astype(jnp.uint32)
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    vals = jnp.zeros((g, LANES), jnp.uint32)
    for k in range(width):
        vals = vals | ((((w[:, k:k + 1] >> lane[None, :]) & 1)
                        << jnp.uint32(k)))
    return vals.reshape(-1)


def bitunpack_pages_ref(words: jnp.ndarray, *, width: int) -> jnp.ndarray:
    return jax.vmap(lambda w: unpack_words_static_ref(w, width))(words)


def dict_decode_pages_ref(words: jnp.ndarray, dictionary: jnp.ndarray, *,
                          width: int) -> jnp.ndarray:
    codes = bitunpack_pages_ref(words, width=width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dictionary.shape[0] - 1)
    return dictionary[codes]


def delta_decode_pages_ref(payload, mb_off, mb_width, min_delta, first_value,
                           *, n_blocks: int) -> jnp.ndarray:
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    karr = jnp.arange(LANES, dtype=jnp.int32)

    def one_mb(slab, off, w):
        g = jnp.arange(MB_GROUPS, dtype=jnp.int32)
        idx = jnp.clip(off + g[:, None] * w + karr[None, :], 0,
                       slab.shape[0] - 1)
        words = slab[idx]
        bits = (words[:, :, None] >> lane[None, None, :]) & jnp.uint32(1)
        contrib = jnp.where(karr[None, :, None] < w,
                            bits << karr[None, :, None].astype(jnp.uint32),
                            jnp.uint32(0))
        return jnp.sum(contrib, axis=1, dtype=jnp.uint32).reshape(-1)

    def one_page(slab, offs, widths, mins, first):
        rel = jnp.concatenate([
            one_mb(slab, offs[b * MINIBLOCKS + m], widths[b * MINIBLOCKS + m])
            for b in range(n_blocks) for m in range(MINIBLOCKS)])
        deltas = rel.astype(jnp.int32) + jnp.repeat(mins, BLOCK_VALUES)
        ecs = jnp.cumsum(deltas) - deltas
        vals = first[0] + ecs
        tail = jnp.full((128,), first[0] + jnp.sum(deltas), jnp.int32)
        return jnp.concatenate([vals, tail])

    return jax.vmap(one_page)(payload, mb_off, mb_width, min_delta,
                              first_value)


def rle_decode_pages_ref(run_values, run_counts, *, n_out: int):
    def one(vals, counts):
        cum = jnp.cumsum(counts.astype(jnp.int32))
        pos = jnp.arange(n_out, dtype=jnp.int32)
        ridx = jnp.sum((cum[None, :] <= pos[:, None]).astype(jnp.int32),
                       axis=1)
        return vals[jnp.clip(ridx, 0, vals.shape[0] - 1)]

    return jax.vmap(one)(run_values, run_counts)


def bss_decode_pages_ref(payload, *, stride_words: int, n_out: int):
    def one(slab):
        j = jnp.arange(n_out, dtype=jnp.int32)
        widx = jnp.clip(j // 4, 0, stride_words - 1)
        shift = ((j % 4) * 8).astype(jnp.uint32)

        def plane(s):
            w = jax.lax.dynamic_slice(slab, (s * stride_words,),
                                      (stride_words,))
            return (w[widx] >> shift) & jnp.uint32(0xFF)

        out = (plane(0) | (plane(1) << jnp.uint32(8))
               | (plane(2) << jnp.uint32(16)) | (plane(3) << jnp.uint32(24)))
        return jax.lax.bitcast_convert_type(out, jnp.float32)

    return jax.vmap(one)(payload)


def cascade_decode_pages_ref(val_words, cnt_words, *, value_width: int,
                             count_width: int, n_runs: int, n_out: int):
    def one(vw, cw):
        vals = unpack_words_static_ref(vw, value_width)[:n_runs]
        counts = unpack_words_static_ref(cw, count_width)[:n_runs]
        cum = jnp.cumsum(counts.astype(jnp.int32))
        pos = jnp.arange(n_out, dtype=jnp.int32)
        ridx = jnp.sum((cum[None, :] <= pos[:, None]).astype(jnp.int32),
                       axis=1)
        return vals[jnp.clip(ridx, 0, n_runs - 1)]

    return jax.vmap(one)(val_words, cnt_words)


def filter_agg_q6_ref(key, qty, disc, price, *, lo, hi, dlo, dhi, qmax):
    mask = ((key >= lo) & (key < hi) & (disc >= dlo) & (disc <= dhi)
            & (qty < qmax))
    return jnp.sum(jnp.where(mask, price * disc, jnp.float32(0)))
