"""Pallas kernel: bit-transposed unpack (the primitive under DICT/DELTA).

grid = (num_pages,) — one grid step unpacks one page (Insight 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, unpack_words_static


def _kernel(words_ref, out_ref, *, width: int):
    out_ref[0, :] = unpack_words_static(words_ref[0, :], width)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def bitunpack_pages(words: jnp.ndarray, *, width: int,
                    interpret: bool | None = None) -> jnp.ndarray:
    """words: (n_pages, G*width) uint32 → (n_pages, G*32) uint32."""
    if interpret is None:
        interpret = interpret_default()
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=(n_pages,),
        in_specs=[pl.BlockSpec((1, n_words), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals), jnp.uint32),
        interpret=interpret,
    )(words)
