"""Pallas kernel: CASCADE codec decompression (TPU-native, beyond-paper).

The cascade frame is word-level RLE with bit-transposed packed run values
and counts (core/compression.py).  Decompression = two static-width unpacks
+ run expansion, i.e. exactly the vector primitives the VPU is good at —
this is the TPU-idiomatic replacement for GPU Snappy kernels (DESIGN.md §2).

grid = (num_pages, num_tiles), tiled like rle_decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (count_launch, expand_runs_tile,
                                  interpret_default, unpack_words_static)

TILE = 1024


def _kernel(val_words_ref, cnt_words_ref, out_ref, *,
            value_width: int, count_width: int, n_runs: int):
    vals = unpack_words_static(val_words_ref[0, :], value_width)[:n_runs]
    counts = unpack_words_static(cnt_words_ref[0, :], count_width)[:n_runs]
    tile_start = pl.program_id(1) * TILE
    out_ref[0, :] = expand_runs_tile(vals, counts.astype(jnp.int32),
                                     tile_start, TILE)


def cascade_decode_pages(val_words: jnp.ndarray, cnt_words: jnp.ndarray, *,
                         value_width: int, count_width: int, n_runs: int,
                         n_out: int, interpret: bool | None = None
                         ) -> jnp.ndarray:
    """val_words/cnt_words: (n_pages, Wv)/(n_pages, Wc) uint32.

    n_runs: padded run count (common to the batch; padding runs count 0).
    n_out: output words per page, multiple of TILE.
    → (n_pages, n_out) uint32 — the decompressed page payload words.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _cascade_decode_pages_jit(val_words, cnt_words,
                                     value_width=value_width,
                                     count_width=count_width,
                                     n_runs=n_runs, n_out=n_out,
                                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "value_width", "count_width", "n_runs", "n_out", "interpret"))
def _cascade_decode_pages_jit(val_words, cnt_words, *,
                              value_width: int, count_width: int,
                              n_runs: int, n_out: int,
                              interpret: bool) -> jnp.ndarray:
    n_pages = val_words.shape[0]
    assert n_out % TILE == 0
    n_tiles = n_out // TILE
    wv, wc = val_words.shape[1], cnt_words.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel, value_width=value_width,
                          count_width=count_width, n_runs=n_runs),
        grid=(n_pages, n_tiles),
        in_specs=[
            pl.BlockSpec((1, wv), lambda i, j: (i, 0)),
            pl.BlockSpec((1, wc), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_out), jnp.uint32),
        interpret=interpret,
    )(val_words, cnt_words)
