"""Pallas kernel: fused decode→filter→aggregate over page blocks.

The late-materialization path (core/fused.py, DESIGN.md §7) collapses the
stage-B half of a predicated scan into ONE pallas launch per row group:
every kernel-fusable operand column — dictionary-coded (codes unpacked and
gathered in-kernel) or PLAIN 32-bit (bitcast in-kernel) — rides into the
same call together with the stage-A selection mask, and each grid step
emits one per-page float32 partial of ``sum(where(mask, left*right, 0))``.
The selected values never touch HBM as a materialized column.

Bit-identity contract: the arithmetic after in-kernel decode is the
shared traced expression ``mask_and_reduce`` below.  The unfused
reference twin (``reference_page_reduce``) evaluates the *same* function
on the same (1, P) page block of fully-decoded values, so both paths
lower to the same jaxpr on the same values and the per-page partials are
bitwise identical — the CI bit-identity step pins this forever.

Operand config (static, hashable) — one tuple per operand, in order:
    (kind, width, vdtype, lo, hi, lo_incl, hi_incl, in_set, role)
kind   : 'dict' (bit-transposed codes + dictionary gather) | 'plain'
         (uint32 words bitcast to vdtype)
width  : dict code bit width (0 for plain)
vdtype : 'float32' | 'int32' — decoded value dtype
lo/hi  : optional interval predicate bounds applied to this operand
in_set : optional tuple of allowed values (OR of equality tests)
role   : '' | 'left' | 'right' | 'both' — the aggregate product factors
         ('both' when the same column is squared: left == right)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import (count_launch, interpret_default,
                                  unpack_words_static)


def mask_and_reduce(mask, vals_list, cfg):
    """The canonical page-block reduce — shared bit-for-bit by the fused
    kernel body and the unfused reference twin.

    mask: (P,) bool — stage-A predicate AND validity; vals_list: one (P,)
    decoded value array per cfg operand.  Returns the float32 scalar
    partial for this page.  Lanes with mask False contribute exactly +0.0
    (``where`` selects, it never propagates the discarded product), which
    is what lets zone/selection-skipped pages be backfilled with literal
    0.0 on the fused side without breaking identity.
    """
    left = right = None
    for op, vals in zip(cfg, vals_list):
        _, _, vdtype, lo, hi, lo_incl, hi_incl, in_set, role = op
        cast = np.dtype(vdtype).type
        if lo is not None:
            mask = mask & (vals >= cast(lo) if lo_incl else vals > cast(lo))
        if hi is not None:
            mask = mask & (vals <= cast(hi) if hi_incl else vals < cast(hi))
        if in_set is not None:
            member = None
            for allowed in in_set:
                eq = vals == cast(allowed)
                member = eq if member is None else (member | eq)
            mask = mask & member
        if role == "left":
            left = vals
        elif role == "right":
            right = vals
        elif role == "both":
            left = right = vals
    prod = left * right
    if prod.dtype != jnp.float32:
        prod = prod.astype(jnp.float32)
    return jnp.sum(jnp.where(mask, prod, jnp.float32(0)))


def apply_predicates(mask, vals, op):
    """Interval/set predicate of one cfg operand over decoded values —
    the same compares ``mask_and_reduce`` folds in, exposed for the
    stage-A mask build (host-side numpy arrays work too: the expressions
    are pure comparisons, exact in any backend)."""
    _, _, vdtype, lo, hi, lo_incl, hi_incl, in_set, _ = op
    cast = np.dtype(vdtype).type
    if lo is not None:
        mask = mask & (vals >= cast(lo) if lo_incl else vals > cast(lo))
    if hi is not None:
        mask = mask & (vals <= cast(hi) if hi_incl else vals < cast(hi))
    if in_set is not None:
        member = None
        for allowed in in_set:
            eq = vals == cast(allowed)
            member = eq if member is None else (member | eq)
        mask = mask & member
    return mask


def _kernel(*refs, cfg):
    """refs = mask_ref, then per operand: words_ref [, dict_ref], out_ref.

    Blocks carry B pages: mask (B, P), dict words (B, W), plain words
    (B, P), out (B, 1).  The per-page arithmetic is ``mask_and_reduce``
    vmapped over the page axis — bitwise identical to applying it to
    each (1, P) page block (XLA's row-wise reduce accumulates in the
    same order as the 1D reduce; pinned by tests/test_fused.py)."""
    mask_ref, out_ref = refs[0], refs[-1]
    mask = mask_ref[...] != 0                       # (B, P)
    vals_list = []
    i = 1
    for op in cfg:
        kind, width, vdtype = op[0], op[1], op[2]
        words = refs[i][...]
        i += 1
        if kind == "dict":
            codes = jax.vmap(
                lambda w, width=width: unpack_words_static(w, width)
            )(words).astype(jnp.int32)
            d = refs[i][:]
            i += 1
            codes = jnp.clip(codes, 0, d.shape[0] - 1)
            vals_list.append(d[codes])
        else:
            target = jnp.float32 if vdtype == "float32" else jnp.int32
            vals_list.append(jax.lax.bitcast_convert_type(words, target))
    out_ref[...] = jax.vmap(
        lambda m, *vs: mask_and_reduce(m, list(vs), cfg)
    )(mask, *vals_list)[:, None]


def fused_page_agg(mask, arrays, *, cfg, interpret: bool | None = None):
    """One launch: decode + filter + aggregate every page of a row group.

    mask: (n_pages, P) uint8 — stage-A predicate AND validity per lane.
    arrays: flat operand inputs matching ``cfg`` in order — for a 'dict'
    operand a (n_pages, W) uint32 words array then its (D,) dictionary;
    for a 'plain' operand a (n_pages, P) uint32 words array.  For dict
    operands W must be (P // 32) * width.

    Returns (n_pages,) float32 canonical per-page partials.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _fused_page_agg_jit(mask, *arrays, cfg=cfg, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _fused_page_agg_jit(mask, *arrays, cfg, interpret: bool):
    n_pages, p = mask.shape
    # Interpret mode pays a fixed emulation cost *per grid step*, so
    # under interpretation the whole row group rides in one
    # (n_pages, P) block; on a real accelerator the per-page (1, P)
    # grid keeps each block VMEM-sized.  Same kernel body either way.
    b = n_pages if interpret else 1
    in_specs = [pl.BlockSpec((b, p), lambda i: (i, 0))]
    i = 0
    for op in cfg:
        w = arrays[i].shape[1]
        in_specs.append(pl.BlockSpec((b, w), lambda i: (i, 0)))
        i += 1
        if op[0] == "dict":
            d = arrays[i].shape[0]
            in_specs.append(pl.BlockSpec((d,), lambda i: (0,)))
            i += 1
    out = pl.pallas_call(
        functools.partial(_kernel, cfg=cfg),
        grid=(n_pages // b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, 1), jnp.float32),
        interpret=interpret,
    )(mask, *arrays)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("cfg",))
def reference_page_reduce(mask_row, *vals_rows, cfg):
    """The unfused twin of one fused grid step: identical expression over
    one (1, P) page block of already-materialized values.  Used by the
    reference execution mode and the host decode backend, so every layer
    produces the same canonical bits as the pallas kernel."""
    return mask_and_reduce(mask_row[0, :] != 0,
                           [v[0, :] for v in vals_rows], cfg)
