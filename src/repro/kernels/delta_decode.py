"""Pallas kernel: DELTA_BINARY_PACKED page decode (V2).

grid = (num_pages,).  Each grid step decodes one page: a fori_loop walks the
page's 1024-value blocks carrying the running prefix; each block unpacks its
four miniblocks (dynamic per-miniblock widths via masked gathers), applies
min_delta, and materializes values with an exclusive cumsum.

Device path is int32 (x32 JAX); ops.py routes int64-range pages to the host
decoder.  The varint-free page manifests (encodings.build_delta_manifest)
supply per-miniblock word offsets/widths so the kernel never parses headers —
the same split cuDF uses (lightweight header pass, bulk decode pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (BLOCK_VALUES, MINIBLOCKS, count_launch,
                                  interpret_default,
                                  unpack_miniblock_dynamic)

TAIL = 128  # lane-aligned tail block holding the final value


def _kernel(payload_ref, mb_off_ref, mb_width_ref, min_delta_ref, first_ref,
            out_ref, *, n_blocks: int):
    slab = payload_ref[0, :]
    mb_off = mb_off_ref[0, :]
    mb_width = mb_width_ref[0, :]
    min_delta = min_delta_ref[0, :]
    first = first_ref[0, 0]

    def body(b, carry):
        parts = []
        for m in range(MINIBLOCKS):
            i = b * MINIBLOCKS + m
            parts.append(unpack_miniblock_dynamic(slab, mb_off[i],
                                                  mb_width[i]))
        rel = jnp.concatenate(parts).astype(jnp.int32)
        deltas = rel + min_delta[b]
        ecs = jnp.cumsum(deltas) - deltas          # exclusive prefix sum
        vals = carry + ecs
        pl.store(out_ref,
                 (pl.dslice(0, 1), pl.dslice(b * BLOCK_VALUES, BLOCK_VALUES)),
                 vals[None, :])
        return carry + jnp.sum(deltas)

    last = jax.lax.fori_loop(0, n_blocks, body, first)
    # deltas count n-1: the final value (index n_blocks*1024) lands in the
    # tail lane block
    pl.store(out_ref,
             (pl.dslice(0, 1), pl.dslice(n_blocks * BLOCK_VALUES, TAIL)),
             jnp.full((1, TAIL), last, jnp.int32))


def delta_decode_pages(payload: jnp.ndarray, mb_off: jnp.ndarray,
                       mb_width: jnp.ndarray, min_delta: jnp.ndarray,
                       first_value: jnp.ndarray, *, n_blocks: int,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Decode DELTA_BINARY_PACKED pages.

    payload     (n_pages, W)        uint32, padded page payloads
    mb_off      (n_pages, n_blocks*4) int32, miniblock word offsets
    mb_width    (n_pages, n_blocks*4) int32
    min_delta   (n_pages, n_blocks) int32
    first_value (n_pages, 1)        int32
    → (n_pages, n_blocks*1024 + 128) int32  (exclusive-cumsum semantics:
      position 0 is first_value; the final value — index n_blocks*1024 when
      the page holds exactly n_blocks·1024 deltas — fills the tail block)
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _delta_decode_pages_jit(payload, mb_off, mb_width, min_delta,
                                   first_value, n_blocks=n_blocks,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_blocks", "interpret"))
def _delta_decode_pages_jit(payload, mb_off, mb_width, min_delta,
                            first_value, *, n_blocks: int,
                            interpret: bool) -> jnp.ndarray:
    n_pages, n_words = payload.shape
    n_mb = n_blocks * MINIBLOCKS
    n_out = n_blocks * BLOCK_VALUES + TAIL
    return pl.pallas_call(
        functools.partial(_kernel, n_blocks=n_blocks),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mb), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mb), lambda i: (i, 0)),
            pl.BlockSpec((1, n_blocks), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_out), jnp.int32),
        interpret=interpret,
    )(payload, mb_off, mb_width, min_delta, first_value)
