"""Shared in-kernel helpers for the TabFile decode kernels.

All decode kernels share two conventions (DESIGN.md §2):

* **grid = (num_pages, …)** — the paper's Insight 1 made structural: each
  grid step decodes one page, so the file's page count *is* the device
  parallelism, exactly as cuDF maps pages to its kernel grid.
* **bit-transposed packing** — a 32-value group with width ``w`` occupies
  ``w`` uint32 words; word ``k`` holds bit ``k`` of all 32 values.  Unpacking
  is ``w`` shift/mask/or steps over full vector lanes (VPU-shaped, no
  byte-serial dependencies).

``interpret_default()`` returns True off-TPU so every kernel runs through the
Pallas interpreter on CPU (the container's validation mode).
"""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp

from repro.core import trace

MB_GROUPS = 8          # packing groups per DELTA miniblock (256 values)
MB_VALUES = 256
BLOCK_VALUES = 1024
MINIBLOCKS = 4
LANES = 32             # values per packing group

# Pallas dispatch counter: every decode-kernel entry point increments this
# once per pallas_call it issues (outside jit, so retraces don't matter).
# The DecodePlan's launch economy — O(encoding groups) instead of
# O(columns × stride groups) per row group — is asserted against it.
# Lock-guarded: the pipeline executor's decode workers dispatch kernels
# concurrently with the consume thread.
_kernel_launches = 0
_launch_lock = threading.Lock()


def count_launch(n: int = 1) -> None:
    global _kernel_launches
    with _launch_lock:
        _kernel_launches += n
    tr = trace.active()
    if tr is not None:
        tr.instant("kernel_launch", "kernel", n=n)
        trace.registry().counter_inc("kernels.launches", n)


def kernel_launch_count() -> int:
    return _kernel_launches


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def unpack_words_static(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """Unpack bit-transposed words with a *static* width.

    words: (G * width,) uint32 → (G * 32,) uint32, group-major.
    """
    g = words.shape[0] // width
    w = words.reshape(g, width)
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    vals = jnp.zeros((g, LANES), jnp.uint32)
    for k in range(width):
        bit = (w[:, k:k + 1] >> lane[None, :]) & jnp.uint32(1)
        vals = vals | (bit << jnp.uint32(k))
    return vals.reshape(-1)


def unpack_miniblock_dynamic(slab: jnp.ndarray, off, width) -> jnp.ndarray:
    """Unpack one 256-value miniblock whose width is a *traced* scalar.

    slab: (S,) uint32 page payload; ``off`` word offset of the miniblock;
    ``width`` ∈ [1, 32].  Returns (256,) uint32 relative deltas.

    The dynamic width is handled with a masked 32-step gather: value bit k of
    group g lives at word ``off + g*width + k`` (k < width).  All shapes are
    static; only indices are traced — this lowers to vectorized gathers.
    """
    g = jnp.arange(MB_GROUPS, dtype=jnp.int32)
    k = jnp.arange(LANES, dtype=jnp.int32)
    idx = off + g[:, None] * width + k[None, :]              # (8, 32)
    idx = jnp.clip(idx, 0, slab.shape[0] - 1)
    words = slab[idx]                                        # (8, 32) gather
    lane = jnp.arange(LANES, dtype=jnp.uint32)
    bits = (words[:, :, None] >> lane[None, None, :]) & jnp.uint32(1)
    kmask = (k[None, :, None] < width)
    contrib = jnp.where(kmask, bits << k[None, :, None].astype(jnp.uint32),
                        jnp.uint32(0))
    vals = jnp.sum(contrib, axis=1, dtype=jnp.uint32)        # or-sum over k
    return vals.reshape(-1)                                  # (256,)


def expand_runs_tile(run_values: jnp.ndarray, run_counts: jnp.ndarray,
                     tile_start, tile: int) -> jnp.ndarray:
    """RLE run expansion for one output tile.

    run_values/run_counts: (R,) padded (count 0 for padding runs).
    Output element j (global position tile_start + j) takes
    run_values[#{r : cum_counts[r] <= pos}] — a compare-sum, O(R · tile),
    fully vectorizable.
    """
    cum = jnp.cumsum(run_counts.astype(jnp.int32))
    pos = tile_start + jnp.arange(tile, dtype=jnp.int32)
    run_idx = jnp.sum((cum[None, :] <= pos[:, None]).astype(jnp.int32),
                      axis=1)
    run_idx = jnp.clip(run_idx, 0, run_values.shape[0] - 1)
    return run_values[run_idx]
