"""Pallas kernel: fused predicate + aggregate (the TPC-H Q6 hot loop).

Beyond-paper: the paper overlaps the *reading* stage with query operators;
fusing the Q6 filter+aggregate into one kernel removes a full HBM round-trip
of the filtered columns.  grid = (num_tiles,) over the decoded column
stream; each tile emits one partial sum, reduced outside.

Predicate (Q6 shape):  lo <= key < hi  AND  dlo <= disc <= dhi  AND
qty < qmax;  aggregate: sum(price * disc).
Padding convention: tiles are padded with key = INT32_MAX (predicate false).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default

TILE = 8192


def _kernel(key_ref, qty_ref, disc_ref, price_ref, out_ref, *,
            lo: int, hi: int, dlo: float, dhi: float, qmax: float):
    key = key_ref[0, :]
    disc = disc_ref[0, :]
    mask = ((key >= lo) & (key < hi)
            & (disc >= dlo) & (disc <= dhi)
            & (qty_ref[0, :] < qmax))
    out_ref[0, 0] = jnp.sum(
        jnp.where(mask, price_ref[0, :] * disc, jnp.float32(0)))


@functools.partial(jax.jit, static_argnames=(
    "lo", "hi", "dlo", "dhi", "qmax", "interpret"))
def filter_agg_q6(key: jnp.ndarray, qty: jnp.ndarray, disc: jnp.ndarray,
                  price: jnp.ndarray, *, lo: int, hi: int, dlo: float,
                  dhi: float, qmax: float,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Inputs: (n,) padded to TILE multiple (key padding = INT32_MAX).

    Returns scalar float32 revenue.
    """
    if interpret is None:
        interpret = interpret_default()
    n = key.shape[0]
    assert n % TILE == 0, "pad inputs to TILE"
    n_tiles = n // TILE
    partials = pl.pallas_call(
        functools.partial(_kernel, lo=lo, hi=hi, dlo=dlo, dhi=dhi,
                          qmax=qmax),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (0, i)),
            pl.BlockSpec((1, TILE), lambda i: (0, i)),
            pl.BlockSpec((1, TILE), lambda i: (0, i)),
            pl.BlockSpec((1, TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_tiles), jnp.float32),
        interpret=interpret,
    )(key.reshape(1, n), qty.reshape(1, n), disc.reshape(1, n),
      price.reshape(1, n))
    return jnp.sum(partials)
