"""Device decode entry points: chunk payloads → padded arrays → kernels.

This is the cuDF-reader analogue: a lightweight host pass turns varint-free
page headers/manifests into flat int32 arrays, pages are stacked into padded
(n_pages, …) batches, and one Pallas call per column chunk decodes every
page in parallel (grid = page count — Insight 1).

Dispatch rules (documented fallbacks, DESIGN.md §2):
  * numeric int32/float32 payloads decode on device;
  * int64 pages whose chunk stats fit int32 are narrowed, otherwise host;
  * strings and float64 decode on the host path;
  * gzip chunks are host-decompressed first (no TPU LZ77); cascade chunks
    are decompressed on-device by cascade_decode_pages.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.compression import (Codec, cascade_manifest, decompress,
                                    verify_page)
from repro.core.encodings import (Encoding, build_delta_manifest,
                                  decode_page, decode_plain_page)
from repro.core.metadata import ChunkMeta, PageMeta
from repro.core.schema import Field, PhysicalType
from repro.kernels.bss_decode import bss_decode_pages
from repro.kernels.cascade_decode import cascade_decode_pages
from repro.kernels.delta_decode import delta_decode_pages
from repro.kernels.dict_decode import (dict_decode_pages,
                                       dict_decode_pages_multi)
from repro.kernels.rle_decode import rle_decode_pages

_INT32_SAFE = 2 ** 30  # conservative: keeps deltas within int32 too
_RLE_MAX_RUNS = 8192   # beyond this the host path wins (and Insight 3 would
                       # not have selected RLE anyway)


@dataclasses.dataclass
class DecodeResult:
    array: object              # jnp.ndarray (device) or np/StringColumn (host)
    on_device: bool
    n_values: int
    encoding: int
    codec: int
    stored_bytes: int          # bytes moved from storage
    logical_bytes: int         # decoded raw bytes (effective-bw numerator)


def _stack_pad_u32(payloads: Sequence[bytes]) -> np.ndarray:
    words = [np.frombuffer(p, dtype=np.uint32) for p in payloads]
    w = max((x.shape[0] for x in words), default=1)
    w = max(w, 1)
    out = np.zeros((len(words), w), dtype=np.uint32)
    for i, x in enumerate(words):
        out[i, :x.shape[0]] = x
    return out


def _stack_pad(arrs: Sequence[np.ndarray], width: int, dtype) -> np.ndarray:
    out = np.zeros((len(arrs), max(width, 1)), dtype=dtype)
    for i, a in enumerate(arrs):
        out[i, :a.shape[0]] = a
    return out


def _compact(batch: jnp.ndarray, counts: Sequence[int]) -> jnp.ndarray:
    """(n_pages, P) → (sum counts,) honoring per-page true value counts."""
    rpp = counts[0] if counts else 0
    total = sum(counts)
    if all(c == rpp for c in counts[:-1]) and batch.shape[1] >= rpp:
        return batch[:, :rpp].reshape(-1)[:total]
    return jnp.concatenate([batch[i, :c] for i, c in enumerate(counts)])


def _stats_fit_int32(chunk: ChunkMeta) -> bool:
    s = chunk.stats
    return (s is not None and isinstance(s.get("min"), int)
            and -_INT32_SAFE <= s["min"] <= _INT32_SAFE
            and -_INT32_SAFE <= s["max"] <= _INT32_SAFE)


# ---------------------------------------------------------------------------
# group-level device decoders (pre-batched inputs)
#
# These accept already-batched (n_pages, …) arrays so a caller may batch
# pages from *many* column chunks into one pallas_call (the DecodePlan path,
# core/decode_plan.py).  The per-chunk decoders below are thin assemblers
# over these and remain the reference/fallback path.
# ---------------------------------------------------------------------------

def decode_dict_group(words: np.ndarray, dictionaries: np.ndarray,
                      width: int) -> jnp.ndarray:
    """words (n_pages, G*width) u32; dictionaries (n_pages, D) — one padded
    dictionary row per page (pages may come from different columns)."""
    return dict_decode_pages_multi(jnp.asarray(words),
                                   jnp.asarray(dictionaries), width=width)


def decode_dict_group_shared(words: np.ndarray, dictionary: np.ndarray,
                             width: int) -> jnp.ndarray:
    """Single-column group: one dictionary shared by every page — no
    per-page duplication (same kernel as the per-chunk reference path)."""
    return dict_decode_pages(jnp.asarray(words), jnp.asarray(dictionary),
                             width=width)


def decode_delta_group(payload: np.ndarray, mb_off: np.ndarray,
                       mb_width: np.ndarray, min_delta: np.ndarray,
                       first: np.ndarray, n_blocks: int) -> jnp.ndarray:
    return delta_decode_pages(
        jnp.asarray(payload), jnp.asarray(mb_off), jnp.asarray(mb_width),
        jnp.asarray(min_delta), jnp.asarray(first), n_blocks=n_blocks)


def decode_rle_group(vals: np.ndarray, counts: np.ndarray,
                     n_out: int) -> jnp.ndarray:
    return rle_decode_pages(jnp.asarray(vals), jnp.asarray(counts),
                            n_out=n_out)


def decode_bss_group(payload: np.ndarray, stride: int) -> jnp.ndarray:
    return bss_decode_pages(jnp.asarray(payload), stride_words=stride,
                            n_out=stride * 4)


def delta_group_arrays(mans: Sequence[dict], payloads: Sequence[bytes],
                       n_blocks: int) -> tuple[np.ndarray, ...]:
    """Assemble the batched host arrays for a DELTA group.  ``n_blocks`` may
    exceed any page's true block count (class padding): padded miniblocks get
    width 0 / min_delta 0, which the kernel decodes as constant carry —
    positions below each page's n_values are unaffected."""
    n_mb = n_blocks * 4
    payload = _stack_pad_u32(payloads)
    mb_off = _stack_pad([m["mb_off"] for m in mans], n_mb, np.int32)
    mb_width = _stack_pad([m["mb_width"] for m in mans], n_mb, np.int32)
    min_delta = _stack_pad(
        [m["min_delta"][:m["n_blocks"]].astype(np.int32) for m in mans],
        n_blocks, np.int32)
    first = np.array([[m["first_value"]] for m in mans], dtype=np.int32)
    return payload, mb_off, mb_width, min_delta, first


def rle_group_arrays(pages_runs: Sequence[tuple[np.ndarray, np.ndarray]]
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(vals, counts) per page → padded (n_pages, R) int32 pair."""
    r_max = max(max((v.shape[0] for v, _ in pages_runs), default=1), 1)
    vals = _stack_pad([v for v, _ in pages_runs], r_max, np.int32)
    counts = _stack_pad([c for _, c in pages_runs], r_max, np.int32)
    return vals, counts


# ---------------------------------------------------------------------------
# per-encoding device decoders (per-chunk reference path)
# ---------------------------------------------------------------------------

def _decode_plain_device(pages, field):
    dt = {PhysicalType.INT32: np.int32, PhysicalType.FLOAT: np.float32,
          PhysicalType.BOOLEAN: np.uint8}.get(field.physical)
    if dt is None:
        return None
    parts = [np.frombuffer(p, dtype=dt, count=pm.n_values)
             for pm, p in pages]
    return jnp.asarray(np.concatenate(parts))  # PLAIN decode is a memcpy


def _decode_dict_device(chunk, field, dict_payload, pages):
    if field.physical == PhysicalType.BYTE_ARRAY:
        return None
    dp = chunk.dict_page
    dictionary = decode_plain_page(dict_payload, dp.n_values, field, dp.extra)
    if field.physical == PhysicalType.INT64:
        if not _stats_fit_int32(chunk):
            return None
        dictionary = dictionary.astype(np.int32)
    elif field.physical == PhysicalType.DOUBLE:
        return None
    elif field.physical == PhysicalType.BOOLEAN:
        dictionary = dictionary.astype(np.uint8)
    width = pages[0][0].extra["bitwidth"]
    words = _stack_pad_u32([p for _, p in pages])
    out = dict_decode_pages(jnp.asarray(words), jnp.asarray(dictionary),
                            width=width)
    return _compact(out, [pm.n_values for pm, _ in pages])


def _decode_delta_device(chunk, field, pages):
    if not _stats_fit_int32(chunk):
        return None
    mans = [build_delta_manifest(p, pm.n_values, pm.extra)
            for pm, p in pages]
    n_blocks = max(m["n_blocks"] for m in mans)
    if n_blocks == 0:
        return None
    if any(abs(int(m["min_delta"].min(initial=0))) > _INT32_SAFE
           for m in mans):
        return None
    arrays = delta_group_arrays(mans, [p for _, p in pages], n_blocks)
    out = decode_delta_group(*arrays, n_blocks=n_blocks)
    return _compact(out, [pm.n_values for pm, _ in pages])


def _decode_rle_device(chunk, field, pages):
    if field.physical == PhysicalType.INT64 and not _stats_fit_int32(chunk):
        return None
    vdt = np.int64 if field.physical == PhysicalType.INT64 else np.int32
    vals, counts = [], []
    for pm, p in pages:
        r = pm.extra["n_runs"]
        if r > _RLE_MAX_RUNS:
            return None
        vals.append(np.frombuffer(p, dtype=vdt, count=r).astype(np.int32))
        counts.append(np.frombuffer(p, dtype=np.int32, count=r,
                                    offset=r * np.dtype(vdt).itemsize))
    max_nv = max(pm.n_values for pm, _ in pages)
    n_out = -(-max_nv // 1024) * 1024
    bvals, bcounts = rle_group_arrays(list(zip(vals, counts)))
    out = decode_rle_group(bvals, bcounts, n_out=n_out)
    res = _compact(out, [pm.n_values for pm, _ in pages])
    if field.physical == PhysicalType.BOOLEAN:
        res = res.astype(jnp.uint8)
    return res


def _decode_bss_device(chunk, field, pages):
    if field.physical != PhysicalType.FLOAT:
        return None  # float64 host path (x32)
    groups = {}
    for pm, p in pages:
        n = pm.n_values
        stride = (n + (-n) % 4) // 4
        groups.setdefault(stride, []).append((pm, p))
    outs = {}
    for stride, grp in groups.items():
        payload = _stack_pad_u32([p for _, p in grp])
        dec = decode_bss_group(payload, stride)
        for (pm, _), row in zip(grp, dec):
            outs[id(pm)] = row[:pm.n_values]
    return jnp.concatenate([outs[id(pm)] for pm, _ in pages])


_DEVICE_DECODERS = {
    Encoding.PLAIN: lambda c, f, d, p: _decode_plain_device(p, f),
    Encoding.RLE_DICTIONARY: _decode_dict_device,
    Encoding.DELTA_BINARY_PACKED:
        lambda c, f, d, p: _decode_delta_device(c, f, p),
    Encoding.RLE: lambda c, f, d, p: _decode_rle_device(c, f, p),
    Encoding.BYTE_STREAM_SPLIT:
        lambda c, f, d, p: _decode_bss_device(c, f, p),
}


# ---------------------------------------------------------------------------
# cascade decompression on device
# ---------------------------------------------------------------------------

def cascade_decompress_pages_grouped(raw_pages: list[tuple[PageMeta, bytes]]
                                     ) -> list[bytes]:
    """One device launch decompressing pages that share a (value_width,
    count_width) class — the caller grouped them (either the DecodePlan's
    plan-time (vw, cw) groups or cascade_decompress_device's execute-time
    grouping).  Returns the decompressed payload per page, input order."""
    mans = [cascade_manifest(p) for _, p in raw_pages]
    vw = mans[0]["value_width"]
    cw = mans[0]["count_width"]
    n_runs = max(max(m["n_runs"] for m in mans), 1)
    n_words = max(m["n_words"] for m in mans)
    n_out = -(-n_words // 1024) * 1024
    from repro.core import bitpack
    vwords = _stack_pad([m["value_words"] for m in mans],
                        bitpack.packed_words(n_runs, vw), np.uint32)
    cwords = _stack_pad([m["count_words"] for m in mans],
                        bitpack.packed_words(n_runs, cw), np.uint32)
    dec = cascade_decode_pages(jnp.asarray(vwords), jnp.asarray(cwords),
                               value_width=vw, count_width=cw,
                               n_runs=n_runs, n_out=n_out)
    return [np.asarray(row[:m["n_words"]]).tobytes()[:pm.uncompressed_size]
            for row, m, (pm, _) in zip(dec, mans, raw_pages)]


def cascade_decompress_device(raw_pages: list[tuple[PageMeta, bytes]]
                              ) -> list[tuple[PageMeta, bytes]]:
    """Decompress CASCADE page payloads on-device; returns bytes again so the
    per-encoding decoders above can run unchanged (in a fused deployment the
    words would stay resident in HBM).  Pages are grouped by their manifest
    (vw, cw) pair — one launch per class; the DecodePlan path skips this
    re-grouping by precomputing the classes at plan time."""
    mans = [cascade_manifest(p) for _, p in raw_pages]
    groups: dict = {}
    for i, m in enumerate(mans):
        groups.setdefault((m["value_width"], m["count_width"]), []).append(i)
    out: dict = {}
    for idxs in groups.values():
        datas = cascade_decompress_pages_grouped(
            [raw_pages[i] for i in idxs])
        for i, data in zip(idxs, datas):
            out[i] = data
    return [(pm, out[i]) for i, (pm, _) in enumerate(raw_pages)]


# ---------------------------------------------------------------------------
# public chunk decode
# ---------------------------------------------------------------------------

def decode_chunk(chunk: ChunkMeta, field: Field, raw: bytes,
                 use_kernels: bool = True,
                 payloads: dict | None = None) -> DecodeResult:
    """Decode one column chunk from its raw stored bytes.

    ``raw`` covers chunk.byte_range (dict page + data pages, possibly
    compressed).  Device-decodable encodings go through the Pallas kernels;
    everything else uses the host decoders.

    ``payloads``, if given, is pre-decompressed page data keyed by page
    index (plus ``"dict"``) — the DecodePlanner passes it so fallback
    columns share the chunk-level decompress memo instead of re-inflating
    per scan (core/compression.py).
    """
    off0, _ = chunk.byte_range
    codec = Codec(chunk.codec)
    encoding = Encoding(chunk.encoding)

    def stored(pm):
        data = raw[pm.offset - off0:pm.offset - off0 + pm.stored_size]
        verify_page(data, pm, where=f"{chunk.name} page@{pm.offset}")
        return data

    # --- decompression stage ------------------------------------------------
    if payloads is not None:
        pages = [(pm, payloads[pi]) for pi, pm in enumerate(chunk.pages)]
        dict_payload = payloads.get("dict")
    elif codec == Codec.CASCADE and use_kernels:
        pages = cascade_decompress_device(
            [(pm, stored(pm)) for pm in chunk.pages])
        dict_payload = None
        if chunk.dict_page is not None:
            dict_payload = decompress(stored(chunk.dict_page), codec,
                                      chunk.dict_page.uncompressed_size)
    else:
        pages = [(pm, decompress(stored(pm), codec, pm.uncompressed_size))
                 for pm in chunk.pages]
        dict_payload = None
        if chunk.dict_page is not None:
            dict_payload = decompress(stored(chunk.dict_page), codec,
                                      chunk.dict_page.uncompressed_size)

    # --- decode stage --------------------------------------------------------
    arr = None
    if use_kernels:
        dec = _DEVICE_DECODERS.get(encoding)
        if dec is not None:
            arr = dec(chunk, field, dict_payload, pages)
    on_device = arr is not None
    if arr is None:  # host fallback
        dictionary = None
        if dict_payload is not None:
            dp = chunk.dict_page
            dictionary = decode_plain_page(dict_payload, dp.n_values, field,
                                           dp.extra)
        parts = [decode_page(encoding, payload, pm.n_values, field, pm.extra,
                             dictionary) for pm, payload in pages]
        from repro.core.table import StringColumn
        if isinstance(parts[0], StringColumn):
            if len(parts) == 1:
                arr = parts[0]
            else:
                lens = np.concatenate([p.lengths() for p in parts])
                offsets = np.zeros(lens.shape[0] + 1, dtype=np.int64)
                np.cumsum(lens, out=offsets[1:])
                arr = StringColumn(offsets,
                                   np.concatenate([p.payload for p in parts]))
        else:
            arr = np.concatenate(parts)

    n_values = chunk.n_values
    from repro.core.table import StringColumn as _SC
    logical = (arr.nbytes if isinstance(arr, _SC)
               else int(np.dtype(field.numpy_dtype or np.int64).itemsize
                        * n_values)
               if not on_device else int(arr.dtype.itemsize) * n_values)
    return DecodeResult(array=arr, on_device=on_device, n_values=n_values,
                        encoding=int(encoding), codec=int(codec),
                        stored_bytes=chunk.stored_bytes,
                        logical_bytes=int(logical))
