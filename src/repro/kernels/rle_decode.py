"""Pallas kernel: RLE run expansion.

grid = (num_pages, num_tiles): page-parallel (Insight 1) *and* tile-parallel
within a page, because one long-run page would otherwise serialize.  Each
tile recomputes the (small) run cumsum and expands its slice with a
compare-sum — O(R · tile) vector ops.  ops.py bounds R (the run count) and
falls back to the host for high-run-count pages, where RLE would not have
been selected anyway (Insight 3 picks the smallest encoding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (count_launch, expand_runs_tile,
                                  interpret_default)

TILE = 1024


def _kernel(vals_ref, counts_ref, out_ref):
    tile_start = pl.program_id(1) * TILE
    out_ref[0, :] = expand_runs_tile(vals_ref[0, :], counts_ref[0, :],
                                     tile_start, TILE)


def rle_decode_pages(run_values: jnp.ndarray, run_counts: jnp.ndarray,
                     *, n_out: int, interpret: bool | None = None
                     ) -> jnp.ndarray:
    """run_values/run_counts: (n_pages, R) int32 (padding runs have count 0).

    n_out: padded output length per page (multiple of TILE).
    → (n_pages, n_out) int32; positions past a page's true value count hold
    the last run's value (callers slice by true counts).
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _rle_decode_pages_jit(run_values, run_counts, n_out=n_out,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def _rle_decode_pages_jit(run_values, run_counts, *, n_out: int,
                          interpret: bool) -> jnp.ndarray:
    n_pages, r = run_values.shape
    assert n_out % TILE == 0
    n_tiles = n_out // TILE
    return pl.pallas_call(
        _kernel,
        grid=(n_pages, n_tiles),
        in_specs=[
            pl.BlockSpec((1, r), lambda i, j: (i, 0)),
            pl.BlockSpec((1, r), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_out), jnp.int32),
        interpret=interpret,
    )(run_values, run_counts)
