"""Pallas kernel: BYTE_STREAM_SPLIT float32 reassembly (V2).

grid = (num_pages,).  A BSS page stores the 4 byte-planes of the float
stream contiguously (each plane padded to a word boundary); the kernel
re-interleaves them with word-level shifts and a bitcast — no byte-serial
work, ideal for the VPU.  float64 pages use the host path (x32 JAX).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import count_launch, interpret_default


def _kernel(payload_ref, out_ref, *, stride_words: int, n_out: int):
    slab = payload_ref[0, :]
    j = jnp.arange(n_out, dtype=jnp.int32)
    word_idx = j // 4
    shift = ((j % 4) * 8).astype(jnp.uint32)

    def plane(s):
        w = jax.lax.dynamic_slice(slab, (s * stride_words,), (stride_words,))
        return (w[jnp.clip(word_idx, 0, stride_words - 1)] >> shift) \
            & jnp.uint32(0xFF)

    out = (plane(0)
           | (plane(1) << jnp.uint32(8))
           | (plane(2) << jnp.uint32(16))
           | (plane(3) << jnp.uint32(24)))
    out_ref[0, :] = jax.lax.bitcast_convert_type(out, jnp.float32)


def bss_decode_pages(payload: jnp.ndarray, *, stride_words: int, n_out: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """payload: (n_pages, ≥4*stride_words) uint32 → (n_pages, n_out) f32."""
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _bss_decode_pages_jit(payload, stride_words=stride_words,
                                 n_out=n_out, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("stride_words", "n_out", "interpret"))
def _bss_decode_pages_jit(payload, *, stride_words: int, n_out: int,
                          interpret: bool) -> jnp.ndarray:
    n_pages, n_words = payload.shape
    return pl.pallas_call(
        functools.partial(_kernel, stride_words=stride_words, n_out=n_out),
        grid=(n_pages,),
        in_specs=[pl.BlockSpec((1, n_words), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_out), jnp.float32),
        interpret=interpret,
    )(payload)
