"""Pallas kernel: RLE_DICTIONARY page decode (unpack codes + gather).

grid = (num_pages,).  The dictionary itself lives in VMEM for the whole
call (one dictionary per column chunk); ops.py falls back to the host path
when a dictionary would not fit VMEM.
"""

from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lru import ByteCappedLRU
from repro.kernels.common import (count_launch, interpret_default,
                                  unpack_words_static)


def _kernel(words_ref, dict_ref, out_ref, *, width: int):
    codes = unpack_words_static(words_ref[0, :], width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dict_ref.shape[0] - 1)
    out_ref[0, :] = dict_ref[:][codes]


def dict_decode_pages(words: jnp.ndarray, dictionary: jnp.ndarray, *,
                      width: int, interpret: bool | None = None
                      ) -> jnp.ndarray:
    """words: (n_pages, G*width) uint32; dictionary: (D,) int32/uint32/f32.

    Returns (n_pages, G*32) of dictionary.dtype.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _dict_decode_pages_jit(words, dictionary, width=width,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _dict_decode_pages_jit(words, dictionary, *, width: int,
                           interpret: bool) -> jnp.ndarray:
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    d = dictionary.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals), dictionary.dtype),
        interpret=interpret,
    )(words, dictionary)


def _kernel_multi(words_ref, dict_ref, out_ref, *, width: int):
    codes = unpack_words_static(words_ref[0, :], width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dict_ref.shape[1] - 1)
    out_ref[0, :] = dict_ref[0, :][codes]


def dict_decode_pages_multi(words: jnp.ndarray, dictionaries: jnp.ndarray, *,
                            width: int, interpret: bool | None = None
                            ) -> jnp.ndarray:
    """Cross-column batched variant: one dictionary row *per page*.

    words: (n_pages, G*width) uint32; dictionaries: (n_pages, D) — row i is
    page i's (padded) dictionary, so pages of many column chunks decode in
    a single pallas_call (the DecodePlan group path).
    Returns (n_pages, G*32) of dictionaries.dtype.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _dict_decode_pages_multi_jit(words, dictionaries, width=width,
                                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _dict_decode_pages_multi_jit(words, dictionaries, *, width: int,
                                 interpret: bool) -> jnp.ndarray:
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    d = dictionaries.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel_multi, width=width),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals),
                                       dictionaries.dtype),
        interpret=interpret,
    )(words, dictionaries)


# ---------------------------------------------------------------------------
# device-resident dictionary cache
#
# A dictionary page decodes to the same array every time a scan revisits its
# chunk (repeated queries over one file, Q6 then Q12, the serving loop).
# Caching the decoded dictionary — and its device copy — skips both the host
# PLAIN-decode and the host→device staging on every revisit.  Keyed by
# (file token, column, dict-page offset): the token carries st_size/mtime so
# a same-path rewrite can never serve a stale dictionary.
# ---------------------------------------------------------------------------

class CachedDictionary:
    """One decoded dictionary: host array + lazily materialized device copy.

    The device copy is built on first use and then stays resident, so row
    groups that share a dictionary shape — and repeated scans of the same
    row group — reuse one device buffer instead of re-staging per launch.
    """

    __slots__ = ("host", "_device", "_lock")

    def __init__(self, host):
        self.host = host
        self._device = None
        self._lock = threading.Lock()

    @property
    def device(self) -> jnp.ndarray:
        if self._device is None:
            with self._lock:
                if self._device is None:
                    self._device = jnp.asarray(self.host)
        return self._device

    @property
    def nbytes(self) -> int:
        return int(self.host.nbytes)


_DICT_CACHE = ByteCappedLRU(64 * 1024 * 1024, lambda e: e.nbytes)


def dict_cache_get(key: tuple) -> CachedDictionary | None:
    return _DICT_CACHE.get(key)


def dict_cache_put(key: tuple, host_array) -> CachedDictionary:
    return _DICT_CACHE.put(key, CachedDictionary(host_array))


def dict_cache_evict(pred) -> int:
    """Evict entries whose key matches ``pred`` (fault recovery: drop
    dictionaries a failed/retried scan may have decoded from bad bytes)."""
    return _DICT_CACHE.pop_matching(pred)


def dict_cache_stats() -> dict:
    return {"entries": len(_DICT_CACHE), "bytes": _DICT_CACHE.bytes,
            "hits": _DICT_CACHE.hits, "misses": _DICT_CACHE.misses}


def dict_cache_clear() -> None:
    _DICT_CACHE.clear()
