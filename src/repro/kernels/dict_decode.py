"""Pallas kernel: RLE_DICTIONARY page decode (unpack codes + gather).

grid = (num_pages,).  The dictionary itself lives in VMEM for the whole
call (one dictionary per column chunk); ops.py falls back to the host path
when a dictionary would not fit VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, unpack_words_static


def _kernel(words_ref, dict_ref, out_ref, *, width: int):
    codes = unpack_words_static(words_ref[0, :], width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dict_ref.shape[0] - 1)
    out_ref[0, :] = dict_ref[:][codes]


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def dict_decode_pages(words: jnp.ndarray, dictionary: jnp.ndarray, *,
                      width: int, interpret: bool | None = None
                      ) -> jnp.ndarray:
    """words: (n_pages, G*width) uint32; dictionary: (D,) int32/uint32/f32.

    Returns (n_pages, G*32) of dictionary.dtype.
    """
    if interpret is None:
        interpret = interpret_default()
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    d = dictionary.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals), dictionary.dtype),
        interpret=interpret,
    )(words, dictionary)
