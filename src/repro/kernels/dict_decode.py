"""Pallas kernel: RLE_DICTIONARY page decode (unpack codes + gather).

grid = (num_pages,).  The dictionary itself lives in VMEM for the whole
call (one dictionary per column chunk); ops.py falls back to the host path
when a dictionary would not fit VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (count_launch, interpret_default,
                                  unpack_words_static)


def _kernel(words_ref, dict_ref, out_ref, *, width: int):
    codes = unpack_words_static(words_ref[0, :], width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dict_ref.shape[0] - 1)
    out_ref[0, :] = dict_ref[:][codes]


def dict_decode_pages(words: jnp.ndarray, dictionary: jnp.ndarray, *,
                      width: int, interpret: bool | None = None
                      ) -> jnp.ndarray:
    """words: (n_pages, G*width) uint32; dictionary: (D,) int32/uint32/f32.

    Returns (n_pages, G*32) of dictionary.dtype.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _dict_decode_pages_jit(words, dictionary, width=width,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _dict_decode_pages_jit(words, dictionary, *, width: int,
                           interpret: bool) -> jnp.ndarray:
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    d = dictionary.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals), dictionary.dtype),
        interpret=interpret,
    )(words, dictionary)


def _kernel_multi(words_ref, dict_ref, out_ref, *, width: int):
    codes = unpack_words_static(words_ref[0, :], width).astype(jnp.int32)
    codes = jnp.clip(codes, 0, dict_ref.shape[1] - 1)
    out_ref[0, :] = dict_ref[0, :][codes]


def dict_decode_pages_multi(words: jnp.ndarray, dictionaries: jnp.ndarray, *,
                            width: int, interpret: bool | None = None
                            ) -> jnp.ndarray:
    """Cross-column batched variant: one dictionary row *per page*.

    words: (n_pages, G*width) uint32; dictionaries: (n_pages, D) — row i is
    page i's (padded) dictionary, so pages of many column chunks decode in
    a single pallas_call (the DecodePlan group path).
    Returns (n_pages, G*32) of dictionaries.dtype.
    """
    if interpret is None:
        interpret = interpret_default()
    count_launch()
    return _dict_decode_pages_multi_jit(words, dictionaries, width=width,
                                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _dict_decode_pages_multi_jit(words, dictionaries, *, width: int,
                                 interpret: bool) -> jnp.ndarray:
    n_pages, n_words = words.shape
    n_vals = (n_words // width) * 32
    d = dictionaries.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel_multi, width=width),
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, n_words), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_vals), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, n_vals),
                                       dictionaries.dtype),
        interpret=interpret,
    )(words, dictionaries)
