"""Train-step builders.

``build_train_step`` — pure step function: microbatch gradient accumulation
(lax.scan), fp32 grad accumulators, AdamW.  Remat policy comes from the
model config (applied inside the layer scans).

``make_sharded_step`` — the production SPMD path: pjit over the
(pod, data, model) mesh with param specs from parallel.sharding (FSDP via
zero=True), donated state.  XLA emits the DP all-reduce / FSDP all-gathers.
Also returns the abstract state + shardings, which the dry-run lowers
directly (no allocation).

``build_manual_dp_step`` — explicit-collectives path: shard_map over the
data axis with compressed gradient all-reduce (bf16 / int8 + error
feedback).  Pure-DP (params replicated); validates compression numerics
and is the template for the wire-compressed deployment mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.model import Model
from repro.parallel.collectives import reduce_gradients
from repro.parallel.sharding import param_pspecs, spec
from repro.train.optimizer import OptConfig, apply_adamw, init_opt_state


def init_train_state(model: Model, rng, opt_cfg: OptConfig) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def abstract_train_state(model: Model, opt_cfg: OptConfig):
    """ShapeDtypeStruct pytree of the state — dry-run input, no allocation."""
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), opt_cfg))


def _split_microbatches(batch: dict, accum: int) -> dict:
    from repro.parallel.sharding import constrain

    def r(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        x = x.reshape(accum, b // accum, *x.shape[1:])
        # keep microbatches sharded over DP after the reshape
        return constrain(x, None, "batch", *([None] * (x.ndim - 2)))

    return jax.tree.map(r, batch)


def build_train_step(model: Model, opt_cfg: OptConfig,
                     grad_accum: int = 1):
    """Pure step(state, batch) -> (state, metrics)."""

    def loss_fn(params, microbatch):
        return model.train_loss(params, microbatch)

    def grads_of(params, batch):
        if grad_accum == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        micro = _split_microbatches(batch, grad_accum)
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            (_, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / grad_accum,
                acc, g)
            return acc, metrics

        grads, metrics = jax.lax.scan(body, zero_g, micro)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return grads, metrics

    def step(state, batch):
        grads, metrics = grads_of(state["params"], batch)
        params, opt, opt_metrics = apply_adamw(
            state["params"], grads, state["opt"], opt_cfg)
        metrics.update(opt_metrics)
        return {"params": params, "opt": opt}, metrics

    return step


def state_shardings(state_like, mesh, zero: bool):
    """NamedSharding pytree for a train state (concrete or abstract)."""
    axes = tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = param_pspecs(state_like["params"], zero=zero, mesh_axes=axes,
                          mesh_sizes=sizes)
    sspecs = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "step": P()},
    }
    return jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_like, mesh):
    axes = tuple(mesh.axis_names)
    bspec = spec("batch", mesh_axes=axes)
    return jax.tree.map(lambda _: NamedSharding(mesh, bspec), batch_like)


def make_sharded_step(model: Model, opt_cfg: OptConfig, mesh, *,
                      grad_accum: int = 1, zero: bool = False,
                      donate: bool = True):
    """Returns (jitted_step, abstract_state, state_sh, batch_sharding_fn)."""
    step = build_train_step(model, opt_cfg, grad_accum)
    state_abs = abstract_train_state(model, opt_cfg)
    state_sh = state_shardings(state_abs, mesh, zero)

    def jit_for(batch_like):
        batch_sh = batch_shardings(batch_like, mesh)
        return jax.jit(step, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,) if donate else ())

    return step, state_abs, state_sh, jit_for


def build_manual_dp_step(model: Model, opt_cfg: OptConfig, mesh,
                         compression: str = "bf16",
                         grad_accum: int = 1):
    """shard_map DP step with compressed gradient all-reduce.

    State gains a "comp_error" field when compression == "int8_ef".
    """
    axis = "data"

    def local_grads(params, batch):
        def loss_fn(p, b):
            return model.train_loss(p, b)

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def step_local(state, batch):
        grads, metrics = local_grads(state["params"], batch)
        err = state.get("comp_error")
        grads, new_err = reduce_gradients(grads, axis, compression, err)
        metrics = jax.tree.map(
            lambda m: jax.lax.pmean(m, axis), metrics)
        params, opt, opt_metrics = apply_adamw(
            state["params"], grads, state["opt"], opt_cfg)
        metrics.update(opt_metrics)
        new_state = {"params": params, "opt": opt}
        if compression == "int8_ef":
            new_state["comp_error"] = new_err
        return new_state, metrics

    # prefix pytree specs: state/metrics replicated, batch sharded on data
    fn = jax.shard_map(step_local, mesh=mesh,
                       in_specs=(P(), P(axis)), out_specs=(P(), P()),
                       check_vma=False)
    return jax.jit(fn)


def init_manual_dp_state(model: Model, rng, opt_cfg: OptConfig,
                         compression: str) -> dict:
    state = init_train_state(model, rng, opt_cfg)
    if compression == "int8_ef":
        state["comp_error"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
    return state
