"""AdamW + schedules, from scratch (pytree-native).

Moments dtype is configurable: fp32 (default) or bf16 — halving optimizer
HBM is one of the §Perf memory-term levers for the 671B-class cells.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"    # "bfloat16" halves optimizer HBM


def lr_at(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: OptConfig) -> dict:
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.moments_dtype]
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_adamw(params, grads, state: dict, cfg: OptConfig
                ) -> tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/biases
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return (newp.astype(p.dtype), mf.astype(m.dtype),
                vf.astype(v.dtype))

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
