"""Fault-tolerant training runner.

Auto-resume contract: on start the runner restores the latest COMMITTED
checkpoint (params, optimizer, loader cursor) and continues; a preemption
or crash between checkpoints loses at most ``save_every`` steps.  A
``fail_at_step`` hook simulates preemption for the restart tests.

Straggler posture (single-process container, design carried in code):
input prefetch depth decouples host I/O stalls from the step loop, step
wall-times are tracked, and slow steps beyond ``straggler_factor``× the
trailing median are logged — on a real pod this feeds the health monitor
that triggers hot-spare swaps.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from collections.abc import Callable

import jax
import numpy as np

from repro.data.loader import LoaderState, PrefetchLoader, TabLoader
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.step import build_train_step, init_train_state


class SimulatedPreemption(RuntimeError):
    pass


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    save_every: int = 50
    log_every: int = 10
    prefetch_depth: int = 2
    straggler_factor: float = 3.0
    fail_at_step: int | None = None     # simulate preemption once


class TrainRunner:
    def __init__(self, model: Model, opt_cfg: OptConfig,
                 loader: TabLoader, ckpt_dir: str,
                 run_cfg: RunnerConfig = RunnerConfig(),
                 grad_accum: int = 1, seed: int = 0):
        self.model = model
        self.opt_cfg = opt_cfg
        self.loader = loader
        self.run_cfg = run_cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=3)
        self.step_fn = jax.jit(build_train_step(model, opt_cfg, grad_accum),
                               donate_argnums=(0,))
        self.seed = seed
        self.history: list[dict] = []

    def _init_or_restore(self):
        state, extra = self.ckpt.restore()
        if state is not None:
            step0 = extra["step"]
            self.loader.restore(LoaderState.from_json(extra["loader"]))
            return state, step0
        state = init_train_state(self.model, jax.random.PRNGKey(self.seed),
                                 self.opt_cfg)
        return state, 0

    def run(self, on_step: Callable | None = None) -> dict:
        cfg = self.run_cfg
        state, step = self._init_or_restore()
        prefetch = PrefetchLoader(self.loader, depth=cfg.prefetch_depth)
        it = iter(prefetch)
        durations: list[float] = []
        failed = False
        try:
            while step < cfg.total_steps:
                inputs, labels = next(it)
                batch = {"tokens": jax.numpy.asarray(inputs),
                         "labels": jax.numpy.asarray(labels)}
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                durations.append(dt)
                step += 1
                if len(durations) > 8:
                    med = statistics.median(durations[-32:])
                    if dt > cfg.straggler_factor * med:
                        print(f"[straggler] step {step}: {dt:.3f}s "
                              f"vs median {med:.3f}s")
                if step % cfg.log_every == 0:
                    rec = {"step": step, "loss": loss,
                           "lr": float(metrics["lr"]),
                           "grad_norm": float(metrics["grad_norm"]),
                           "sec_per_step": dt}
                    self.history.append(rec)
                    print(f"step {step:>6} loss {loss:8.4f} "
                          f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.3f} "
                          f"{dt*1e3:7.1f} ms")
                    if on_step:
                        on_step(rec)
                if step % cfg.save_every == 0 or step == cfg.total_steps:
                    self.ckpt.save(step, state, extra={
                        "step": step,
                        "loader": self.loader.snapshot().to_json()})
                if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                    failed = True
                    raise SimulatedPreemption(f"at step {step}")
        finally:
            prefetch.close()
            if not failed:
                self.ckpt.wait()
        return {"final_step": step, "history": self.history,
                "state": state}
