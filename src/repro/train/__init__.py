# Training substrate: optimizer, step builder (remat/microbatch/sharding),
# fault-tolerant checkpointing, and the auto-resume runner.
