"""Fault-tolerant checkpointing.

Layout (one directory per step):
    <dir>/step_000123/
        arrays/<flat-key>.npy       one file per leaf (gathered to host)
        manifest.json               step, tree structure, loader state,
                                    config fingerprint, leaf dtypes/shapes
    <dir>/step_000123.COMMITTED     write-barrier marker (atomic rename)

Guarantees:
  * atomicity — a checkpoint without its COMMITTED marker is ignored and
    garbage-collected on the next save (torn writes survive restarts);
  * async save — arrays are snapshotted to host then written on a
    background thread so the step loop keeps running;
  * keep-k GC;
  * cross-mesh restore (elastic rescale) — leaves are stored gathered, so
    restore works onto any mesh/sharding: pass ``shardings`` to place
    shards directly on the target topology.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_SEP = "||"


def _flatten(tree) -> dict[str, object]:
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + [str(i)])
        elif node is None:
            flat[_SEP.join(path) + _SEP + "__none__"] = None
        else:
            flat[_SEP.join(path)] = node

    walk(tree, [])
    return flat


def _unflatten(flat: dict[str, object]):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        if parts[-1] == "__none__":
            parts = parts[:-1]
            val = None
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state, extra: dict | None = None) -> str:
        """Snapshot to host, then write (async by default)."""
        flat = _flatten(state)
        host = {k: (None if v is None else np.asarray(v))
                for k, v in flat.items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})
        return self._path(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _write(self, step: int, host: dict[str, np.ndarray],
               extra: dict) -> None:
        path = self._path(step)
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        arrays_dir = os.path.join(tmp, "arrays")
        os.makedirs(arrays_dir)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for i, (key, arr) in enumerate(host.items()):
            if arr is None:
                manifest["leaves"][key] = None
                continue
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(arrays_dir, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        open(path + ".COMMITTED", "w").close()      # write barrier
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
            try:
                os.remove(self._path(s) + ".COMMITTED")
            except FileNotFoundError:
                pass
        # torn checkpoints (no marker) are dead weight — remove
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (name.startswith("step_") and os.path.isdir(full)
                    and not os.path.exists(full + ".COMMITTED")):
                shutil.rmtree(full, ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and name.endswith(".COMMITTED"):
                out.append(int(name[len("step_"):-len(".COMMITTED")]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None,
                shardings=None) -> tuple[object | None, dict | None]:
        """Returns (state, extra).  ``shardings``: optional pytree of
        NamedSharding for elastic restore onto a different mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, meta in manifest["leaves"].items():
            if meta is None:
                flat[key] = None
                continue
            arr = np.load(os.path.join(path, "arrays", meta["file"]))
            flat[key] = arr
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if a is not None else a,
                state, shardings)
        else:
            state = jax.tree.map(
                lambda a: jax.numpy.asarray(a) if a is not None else a,
                state)
        return state, manifest["extra"]
