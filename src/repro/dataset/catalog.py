"""Dataset catalog: many TabFiles behind one JSON manifest.

A *dataset* is a directory of TabFile fragments plus a ``manifest.json``
recording, per fragment: row count, stored bytes, per-column min/max zone
maps (merged from the fragment's row-group footers — no data scan), the
partition-key value/range, and the ``FileConfig`` fingerprint the
fragment was written under.  The manifest is the unit of atomicity: every
mutation (append, compaction) builds the new fragment files first, then
swaps the manifest with one ``os.replace`` — readers holding the old
manifest keep a consistent view until the swap lands.

Partitioning:

  none    fragments are contiguous row slices (``fragments=N``)
  range   rows are bucketed by equal-count quantiles of a numeric key
          column; each fragment records its [lo, hi] key range, which the
          planner prunes like a file-level zone map
  hash    rows are bucketed by a multiplicative hash of the key; a query
          with an equality predicate computes ``Partitioning.bucket_of``
          and prunes every other bucket
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil

import numpy as np

from repro.core.compression import ChecksumError, page_crc
from repro.core.config import FileConfig
from repro.core.metadata import FileMeta
from repro.core.reader import read_footer
from repro.core.scan import Scanner, open_scanner
from repro.core.table import StringColumn, Table
from repro.core.writer import write_table

MANIFEST_NAME = "manifest.json"
MANIFEST_PREV_NAME = "manifest.prev.json"   # last-known-good generation
MANIFEST_VERSION = 1

#: generation-tagged fragment file names: ``part-00003.g7.tab``
_FRAGMENT_RE = re.compile(r"^part-\d+\.g(\d+)\.tab$")

_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)   # Fibonacci hashing constant


def _manifest_crc(payload: dict) -> int:
    """CRC32 over the canonical (sorted-key) JSON of a crc-less manifest
    payload — whitespace/key-order independent, so a hand-reformatted
    manifest still verifies."""
    return page_crc(json.dumps(payload, sort_keys=True).encode())


@dataclasses.dataclass
class Partitioning:
    """How a dataset's rows map to fragments."""

    kind: str = "none"            # "none" | "hash" | "range"
    column: str | None = None
    num_buckets: int | None = None   # hash only

    def __post_init__(self) -> None:
        if self.kind not in ("none", "hash", "range"):
            raise ValueError(f"unknown partitioning kind {self.kind!r}")
        if self.kind != "none" and not self.column:
            raise ValueError(f"{self.kind} partitioning needs a column")

    def bucket_of(self, values) -> np.ndarray:
        """Hash bucket for each key value (the pruning contract for
        equality predicates: a query computes the bucket of its literal
        and skips every other fragment).  Numeric keys only."""
        assert self.kind == "hash" and self.num_buckets
        arr = np.asarray(values)
        if arr.dtype.kind not in "iuf" or isinstance(values, StringColumn):
            raise TypeError("hash partitioning needs a numeric key "
                            f"column, got dtype {arr.dtype}")
        v = arr.astype(np.int64).view(np.uint64)
        mixed = (v * _HASH_MULT) >> np.uint64(33)
        return (mixed % np.uint64(self.num_buckets)).astype(np.int64)

    def to_json(self) -> dict:
        return {"kind": self.kind, "column": self.column,
                "num_buckets": self.num_buckets}

    @staticmethod
    def from_json(o: dict) -> "Partitioning":
        return Partitioning(o.get("kind", "none"), o.get("column"),
                            o.get("num_buckets"))


@dataclasses.dataclass
class FragmentInfo:
    """One TabFile of the dataset, as the manifest records it."""

    path: str                     # relative to the dataset root
    num_rows: int
    stored_bytes: int
    logical_nbytes: int
    column_stats: dict            # name -> {"min":…, "max":…}
    partition: dict | None        # see Partitioning docstring shapes
    config: dict                  # FileConfig.fingerprint() provenance

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(o: dict) -> "FragmentInfo":
        return FragmentInfo(
            path=o["path"], num_rows=o["num_rows"],
            stored_bytes=o["stored_bytes"],
            logical_nbytes=o.get("logical_nbytes", 0),
            column_stats=o.get("column_stats", {}),
            partition=o.get("partition"), config=o.get("config", {}))


def file_column_stats(meta: FileMeta) -> dict:
    """File-level zone maps: per-column min/max merged over the footer's
    row-group chunk stats (columns without stats are omitted — absent
    stats never prune, same as the row-group contract)."""
    out: dict = {}
    for rg in meta.row_groups:
        for chunk in rg.columns:
            if chunk.stats is None:
                continue
            cur = out.get(chunk.name)
            if cur is None:
                out[chunk.name] = dict(chunk.stats)
            else:
                cur["min"] = min(cur["min"], chunk.stats["min"])
                cur["max"] = max(cur["max"], chunk.stats["max"])
    return out


def _fragment_from_meta(rel_path: str, meta: FileMeta,
                        partition: dict | None) -> FragmentInfo:
    return FragmentInfo(
        path=rel_path, num_rows=meta.num_rows,
        stored_bytes=meta.stored_bytes,
        logical_nbytes=meta.logical_nbytes,
        column_stats=file_column_stats(meta),
        partition=partition, config=dict(meta.writer_config))


class Dataset:
    """A manifest-backed collection of TabFile fragments."""

    def __init__(self, root: str, partitioning: Partitioning | None = None,
                 fragments: list[FragmentInfo] | None = None,
                 generation: int = 0):
        self.root = root
        self.partitioning = partitioning or Partitioning()
        self.fragments: list[FragmentInfo] = list(fragments or [])
        self.generation = generation   # bumped by every manifest swap
        #: set by ``load`` when the live manifest was corrupt and the
        #: last-known-good generation was used instead
        self.recovered_from: str | None = None

    # -- identity ----------------------------------------------------------

    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST_NAME)

    @property
    def num_rows(self) -> int:
        return sum(f.num_rows for f in self.fragments)

    @property
    def stored_bytes(self) -> int:
        return sum(f.stored_bytes for f in self.fragments)

    def fragment_path(self, frag: FragmentInfo) -> str:
        return os.path.join(self.root, frag.path)

    def describe(self) -> dict:
        return {
            "root": self.root,
            "n_fragments": len(self.fragments),
            "num_rows": self.num_rows,
            "stored_bytes": self.stored_bytes,
            "partitioning": self.partitioning.to_json(),
            "generation": self.generation,
        }

    # -- manifest I/O ------------------------------------------------------

    def to_json(self) -> dict:
        payload = {
            "version": MANIFEST_VERSION,
            "generation": self.generation,
            "partitioning": self.partitioning.to_json(),
            "fragments": [f.to_json() for f in self.fragments],
        }
        payload["crc32"] = _manifest_crc(payload)
        return payload

    @property
    def manifest_prev_path(self) -> str:
        return os.path.join(self.root, MANIFEST_PREV_NAME)

    def save(self) -> None:
        """Atomic manifest swap: the new manifest is fully written to a
        temp file in the same directory, then ``os.replace``d over the
        live one — a concurrent reader sees either the old manifest or
        the new one, never a torn write.  Before the swap, the current
        manifest is copied to ``manifest.prev.json`` so a corrupted swap
        (torn disk write, bit rot) leaves a last-known-good generation
        to recover from (DESIGN.md §6)."""
        os.makedirs(self.root, exist_ok=True)
        if os.path.exists(self.manifest_path):
            shutil.copyfile(self.manifest_path, self.manifest_prev_path)
        tmp = self.manifest_path + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.manifest_path)
        # the manifest swap is the result-cache invalidation point
        # (DESIGN.md §11): cached fragment partials keyed by any other
        # generation of this root are now stale.  A crashed mutation
        # never reaches this line, so prior-generation entries stay
        # valid exactly as long as the prior manifest stays live.
        from repro.dataset.result_cache import invalidate_dataset
        invalidate_dataset(self.root, self.generation)

    @staticmethod
    def _parse_manifest(path: str, root: str) -> "Dataset":
        with open(path) as f:
            o = json.load(f)
        crc = o.pop("crc32", None)
        if crc is not None and crc != _manifest_crc(o):
            raise ChecksumError("manifest", crc, _manifest_crc(o),
                                path=path)
        if o.get("version") != MANIFEST_VERSION:
            raise ValueError(f"unsupported manifest version "
                             f"{o.get('version')!r}")
        return Dataset(
            root=root,
            partitioning=Partitioning.from_json(o.get("partitioning", {})),
            fragments=[FragmentInfo.from_json(x)
                       for x in o.get("fragments", [])],
            generation=o.get("generation", 0))

    @staticmethod
    def load(root: str, recover: bool = True) -> "Dataset":
        """Load the manifest, verifying its embedded CRC (manifests
        written before checksumming load as legacy).  A corrupt or
        unparseable manifest falls back to ``manifest.prev.json`` — the
        last-known-good generation — when ``recover`` is on; with no
        recovery candidate the original error propagates."""
        path = os.path.join(root, MANIFEST_NAME)
        try:
            return Dataset._parse_manifest(path, root)
        except (ChecksumError, json.JSONDecodeError, KeyError) as e:
            prev = os.path.join(root, MANIFEST_PREV_NAME)
            if not recover or not os.path.exists(prev):
                raise
            ds = Dataset._parse_manifest(prev, root)
            ds.recovered_from = repr(e)
            return ds

    @staticmethod
    def open(root: str, recover: bool = True,
             sweep: bool = True) -> "Dataset":
        """``load`` plus crash hygiene: validates every manifest-listed
        fragment file exists (a manifest referencing a missing file is
        corrupt — recovery kicks in), then sweeps orphaned temp files and
        stale-generation fragments left by interrupted publications."""
        ds = Dataset.load(root, recover=recover)
        missing = [f.path for f in ds.fragments
                   if not os.path.exists(ds.fragment_path(f))]
        if missing:
            prev = os.path.join(root, MANIFEST_PREV_NAME)
            if recover and ds.recovered_from is None \
                    and os.path.exists(prev):
                ds = Dataset._parse_manifest(prev, root)
                ds.recovered_from = f"missing fragments: {missing}"
                missing = [f.path for f in ds.fragments
                           if not os.path.exists(ds.fragment_path(f))]
            if missing:
                raise FileNotFoundError(
                    f"dataset {root}: manifest references missing "
                    f"fragment(s) {missing}")
        if sweep:
            ds.sweep_orphans()
        return ds

    def sweep_orphans(self) -> list[str]:
        """Delete files a crashed publication left behind; returns the
        deleted names.  Two classes are orphans: (1) any ``*.tmp*`` file
        (interrupted ``os.replace`` staging), and (2) an *unreferenced*
        generation-tagged fragment whose generation is **at or above**
        the manifest's — a crashed append/compaction wrote it but never
        published it.  Unreferenced fragments from *older* generations
        are preserved: they are ``keep_old`` compaction inputs a reader
        holding the previous manifest may still be scanning."""
        removed: list[str] = []
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return removed
        live = {f.path for f in self.fragments}
        for name in sorted(names):
            if name in (MANIFEST_NAME, MANIFEST_PREV_NAME) or name in live:
                continue
            m = _FRAGMENT_RE.match(name)
            orphan = (".tmp" in name
                      or (m is not None
                          and int(m.group(1)) >= self.generation))
            if orphan:
                try:
                    os.unlink(os.path.join(self.root, name))
                    removed.append(name)
                except OSError:
                    pass    # best-effort hygiene; never fail an open
        return removed

    # -- builders ----------------------------------------------------------

    def next_fragment_name(self) -> str:
        """Collision-free fragment file name: generation-tagged so
        compaction's replacement files never overwrite live ones."""
        taken = {f.path for f in self.fragments}
        k = len(self.fragments)
        while True:
            name = f"part-{k:05d}.g{self.generation}.tab"
            if name not in taken and not os.path.exists(
                    os.path.join(self.root, name)):
                return name
            k += 1

    def append_table(self, table: Table, config: FileConfig,
                     partition: dict | None = None,
                     threads: int = 1) -> FragmentInfo:
        """Write ``table`` as one new fragment and swap the manifest.
        The fragment file lands fully before the manifest references it,
        so a crash between the two leaves the dataset unchanged (plus one
        unreferenced file)."""
        os.makedirs(self.root, exist_ok=True)
        name = self.next_fragment_name()
        meta = write_table(table, os.path.join(self.root, name), config,
                           threads=threads)
        frag = _fragment_from_meta(name, meta, partition)
        self.fragments.append(frag)
        self.generation += 1
        self.save()
        return frag

    def adopt_file(self, path: str,
                   partition: dict | None = None) -> FragmentInfo:
        """Register an existing TabFile (inside the dataset root) as a
        fragment, reading its footer for stats and provenance."""
        rel = os.path.relpath(path, self.root)
        frag = _fragment_from_meta(rel, read_footer(path), partition)
        self.fragments.append(frag)
        self.generation += 1
        self.save()
        return frag

    # -- scan access -------------------------------------------------------

    def open_fragment(self, frag: FragmentInfo | int,
                      columns: list[str] | None = None,
                      backend: str = "real", n_lanes: int = 1,
                      decode_backend: str = "pallas",
                      lane_bandwidth: float | None = None,
                      latency: float | None = None,
                      use_plan: bool = True,
                      coalesce_gap: int | None = None,
                      retry=None, fault_plan=None,
                      fused_spec=None, prefetch: bool = False,
                      prefetch_threads: int = 2) -> Scanner:
        if isinstance(frag, int):
            frag = self.fragments[frag]
        return open_scanner(self.fragment_path(frag), columns=columns,
                            backend=backend, n_lanes=n_lanes,
                            decode_backend=decode_backend,
                            lane_bandwidth=lane_bandwidth, latency=latency,
                            use_plan=use_plan, coalesce_gap=coalesce_gap,
                            retry=retry, fault_plan=fault_plan,
                            fused_spec=fused_spec, prefetch=prefetch,
                            prefetch_threads=prefetch_threads)


# ---------------------------------------------------------------------------
# dataset writer
# ---------------------------------------------------------------------------


def _take(table: Table, idx: np.ndarray) -> Table:
    cols = {}
    for name, col in table.columns.items():
        cols[name] = (col.take(idx) if isinstance(col, StringColumn)
                      else col[idx])
    return Table(cols, table.schema)


def _range_buckets(keys: np.ndarray, n: int) -> list[np.ndarray]:
    """Equal-count range buckets: ascending key order split into n runs
    (stable within a run, so row order inside a fragment is the sort
    order — the locality the planner's fragment ordering preserves)."""
    order = np.argsort(keys, kind="stable")
    return [chunk for chunk in np.array_split(order, n)
            if chunk.shape[0] > 0]


def write_dataset(table: Table, root: str, config: FileConfig,
                  partition_by: str | None = None, how: str = "range",
                  fragments: int = 16, threads: int = 1) -> Dataset:
    """Partition ``table`` into a new dataset at ``root``.

    ``partition_by=None`` slices rows contiguously into ``fragments``
    files.  ``how="range"`` buckets by equal-count quantiles of the key
    (each fragment records its [lo, hi] key range); ``how="hash"``
    buckets by ``Partitioning.bucket_of`` (each fragment records its
    bucket id).  One manifest swap publishes all fragments at once.
    """
    os.makedirs(root, exist_ok=True)
    n_frags = max(1, int(fragments))
    if partition_by is not None and isinstance(table[partition_by],
                                               StringColumn):
        raise TypeError("partitioning needs a numeric key column; "
                        f"{partition_by!r} is a string column")
    if partition_by is None:
        part = Partitioning()
        per = max(1, -(-table.num_rows // n_frags))
        parts: list[tuple[Table, dict | None]] = [
            (table.slice(s, s + per), None)
            for s in range(0, table.num_rows, per)]
    elif how == "range":
        part = Partitioning("range", partition_by)
        keys = np.asarray(table[partition_by])
        parts = []
        for idx in _range_buckets(keys, n_frags):
            sub = _take(table, idx)
            ks = np.asarray(sub[partition_by])
            parts.append((sub, {
                "kind": "range", "column": partition_by,
                "lo": ks.min().item(), "hi": ks.max().item()}))
    elif how == "hash":
        part = Partitioning("hash", partition_by, num_buckets=n_frags)
        buckets = part.bucket_of(table[partition_by])
        parts = []
        for b in range(n_frags):
            idx = np.flatnonzero(buckets == b)
            if idx.shape[0] == 0:
                continue
            parts.append((_take(table, idx), {
                "kind": "hash", "column": partition_by, "bucket": b,
                "buckets": n_frags}))
    else:
        raise ValueError(f"unknown partitioning how={how!r}")

    ds = Dataset(root, part)
    for sub, pinfo in parts:
        name = ds.next_fragment_name()
        meta = write_table(sub, os.path.join(root, name), config,
                           threads=threads)
        ds.fragments.append(_fragment_from_meta(name, meta, pinfo))
    ds.generation += 1
    ds.save()
    return ds
