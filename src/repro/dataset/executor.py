"""Sharded dataset execution through the shared ScanService.

Every surviving fragment of a ``DatasetScanPlan`` becomes one concurrent
scan submitted to the process-wide ScanService (core/scheduler.py): a
bounded *fragment window* of scans is in flight at once, so fragment B's
chunks decode inside fragment A's pipeline bubbles (the same cross-scan
sharing bench_concurrent measures), while each scan's own ``depth``
credits keep per-fragment memory bounded.  Per-fragment results are
reduced **in plan order** — float accumulation order is deterministic, so
a pruned scan is bit-identical to an unpruned one (pruned-away fragments
contribute exact zeros) and repeated runs agree bitwise.

``prioritize="order"`` submits fragment k at ScanService priority k, the
strict-priority hook that biases the shared pool toward the earliest
unfinished fragment so window slots free in plan order.

**Failure policy** (DESIGN.md §6).  Fragments are the executor's
isolation unit: a fragment scan that fails after the inner layers'
retries (storage backoff, ScanService requeue) is retried whole — a
*fresh* scanner over fresh bytes, ``fragment_retries`` times — then
**quarantined**.  ``on_error="strict"`` (default) raises a structured
``FragmentError`` naming every quarantined fragment; ``"best_effort"``
returns the partial result plus an explicit *gap manifest*
(``DatasetRunReport.quarantined``) so a caller can never mistake a
partial answer for a complete one.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections.abc import Callable

import numpy as np

from repro.core import trace as trace_mod
from repro.core.faults import DeadlineExceeded, is_retryable
from repro.core.overlap import Consume, RunReport, run_overlapped
from repro.core.scan import Scanner
from repro.dataset.planner import DatasetScanPlan
from repro.kernels.common import kernel_launch_count

Combine = Callable[[object, object], object]

#: keyword arguments forwarded to ``Dataset.open_fragment`` per fragment
DEFAULT_OPEN_OPTS: dict = {"backend": "real", "decode_backend": "pallas"}


class FragmentError(RuntimeError):
    """One or more fragments failed permanently under ``on_error="strict"``.

    ``failures`` is the structured report: one dict per quarantined
    fragment with ``fragment`` (relative path), ``index`` (plan
    position), ``attempts``, ``error`` and ``error_type``."""

    def __init__(self, failures: list[dict]):
        self.failures = list(failures)
        names = ", ".join(f["fragment"] for f in self.failures)
        first = self.failures[0]["error"] if self.failures else "?"
        super().__init__(
            f"{len(self.failures)} fragment(s) failed permanently: "
            f"{names} (first: {first})")


@dataclasses.dataclass
class DatasetRunReport:
    """Merged accounting of one sharded dataset scan."""

    files_total: int
    files_scanned: int
    pruned_partition: int
    pruned_stats: int
    measured_wall: float
    window: int
    fragment_walls: list[float]            # per-fragment wall, plan order
    reports: list[RunReport]               # per-fragment RunReports
    n_kernel_launches: int = 0    # process-wide delta across the run (per-
                                  # fragment deltas would double-count
                                  # concurrent fragments' launches)
    n_io_requests: int = 0        # sum over fragments (private storages)
    shared_rgs: int = 0           # cooperative deliveries to THIS run's
                                  # fragment scans (summed per handle)
    n_row_groups: int = 0
    stored_bytes: int = 0
    logical_bytes: int = 0
    # fault-recovery accounting (DESIGN.md §6): per-fragment ScanMetrics
    # counters summed, plus whole-fragment retry attempts; ``quarantined``
    # is the best-effort gap manifest — one dict per fragment the result
    # does NOT cover ({fragment, index, attempts, error, error_type})
    retries: int = 0
    checksum_failures: int = 0
    timeouts: int = 0
    quarantined: list[dict] = dataclasses.field(default_factory=list)
    # distributed execution (run_distributed_scan, DESIGN.md §8):
    # fragments scanned per device (plan-order shards + steals) and how
    # many fragments finished on a device other than their home shard
    devices: int = 1
    device_names: list[str] = dataclasses.field(default_factory=list)
    device_fragments: list[int] = dataclasses.field(default_factory=list)
    stolen_fragments: int = 0
    # per-backend observability (never gated): prefetch economics summed
    # over fragments, request-weighted latency percentiles, and stored
    # bytes split by storage backend kind
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_hidden_seconds: float = 0.0
    prefetch_stall_seconds: float = 0.0
    io_p50_us: float = 0.0
    io_p95_us: float = 0.0
    bytes_by_backend: dict = dataclasses.field(default_factory=dict)
    # observability (core/trace.py, DESIGN.md §10; never gated): number of
    # flight-recorder events captured during the run and the process-wide
    # metrics-registry snapshot at run end (empty when tracing is off)
    trace_events: int = 0
    registry_snapshot: dict = dataclasses.field(default_factory=dict)
    # serving front end (DESIGN.md §11): fragments answered from the
    # fragment result cache — no open, no fetch, no decode
    result_cache_hits: int = 0

    @property
    def fragments_quarantined(self) -> int:
        return len(self.quarantined)

    @property
    def complete(self) -> bool:
        """Whether the result covers every planned fragment (False only
        under ``on_error="best_effort"`` with quarantined fragments)."""
        return not self.quarantined

    @property
    def files_pruned(self) -> int:
        return self.pruned_partition + self.pruned_stats

    def wall_percentile(self, q: float) -> float:
        if not self.fragment_walls:
            return 0.0
        return float(np.percentile(self.fragment_walls, q))

    def effective_bandwidth(self) -> float:
        return self.logical_bytes / max(1e-12, self.measured_wall)

    def summary(self) -> str:
        base = (f"files={self.files_total};scanned={self.files_scanned};"
                f"pruned={self.files_pruned};window={self.window};"
                f"launches={self.n_kernel_launches};"
                f"io_requests={self.n_io_requests};"
                f"shared_rgs={self.shared_rgs};"
                f"retries={self.retries};"
                f"checksum_failures={self.checksum_failures};"
                f"timeouts={self.timeouts};"
                f"fragments_quarantined={self.fragments_quarantined};"
                f"result_cache_hits={self.result_cache_hits};"
                f"frag_p50_us={self.wall_percentile(50) * 1e6:.0f};"
                f"frag_p95_us={self.wall_percentile(95) * 1e6:.0f}")
        if self.devices > 1 or self.prefetch_hits or self.prefetch_misses:
            base += (f";devices={self.devices};"
                     f"stolen_fragments={self.stolen_fragments};"
                     f"prefetch_hits={self.prefetch_hits};"
                     f"prefetch_misses={self.prefetch_misses};"
                     f"io_p50_us={self.io_p50_us:.0f};"
                     f"io_p95_us={self.io_p95_us:.0f}")
            for kind in sorted(self.bytes_by_backend):
                base += f";bytes_{kind}={self.bytes_by_backend[kind]}"
        return base


def run_dataset_scan(plan: DatasetScanPlan, consume: Consume | None = None,
                     combine: Combine | None = None, *,
                     window: int = 4, depth: int = 2,
                     decode_workers: int | None = None, service=None,
                     prioritize: str | None = None,
                     open_opts: dict | None = None,
                     fragment_retries: int = 2,
                     on_error: str = "strict",
                     retries: int = 3, deadline: float | None = None,
                     trace=None, tenant: str | None = None,
                     result_cache=None, fingerprint: str | None = None):
    """Execute a planned dataset scan; returns ``(acc, DatasetRunReport)``.

    ``consume`` is the per-row-group reducer every fragment scan runs
    (the ``run_overlapped`` contract); ``combine`` merges per-fragment
    accumulators **in plan order** (``None`` returns the plan-ordered
    list of per-fragment accumulators instead).  ``window`` bounds how
    many fragment scans are in flight; ``depth``/``decode_workers``/
    ``service`` are forwarded to each ``run_overlapped``.  ``open_opts``
    are ``Dataset.open_fragment`` keyword arguments (storage backend,
    decode backend, retry policy, fault plan, …).  ``prioritize="order"``
    submits fragment k at service priority k.

    Failure policy (module docstring): a fragment that still fails after
    the inner retries is re-scanned whole with a fresh scanner up to
    ``fragment_retries`` times, then quarantined.  ``on_error="strict"``
    raises ``FragmentError``; ``"best_effort"`` returns the partial
    result with the gap manifest in ``DatasetRunReport.quarantined``.
    ``retries``/``deadline`` are each fragment scan's per-scan budget
    (``run_overlapped`` contract); a ``DeadlineExceeded`` fragment is
    never retried.  ``trace`` enables the flight recorder for this run
    (``core/trace.py``): True records, a path string records and exports
    Chrome-trace JSON there on exit, None defers to ``REPRO_TRACE``.

    ``tenant`` attributes every fragment scan to a ScanService tenant
    (weighted fair scheduling + admission, DESIGN.md §11).
    ``result_cache``/``fingerprint`` enable the fragment result cache:
    a fragment whose partial is cached under (root, manifest
    generation, fragment path, fingerprint) is answered without a scan;
    fresh partials are stored on success.  ``fingerprint`` must digest
    the predicate + consume identity — both must be given to
    participate.
    """
    if on_error not in ("strict", "best_effort"):
        raise ValueError(f"on_error must be 'strict' or 'best_effort', "
                         f"got {on_error!r}")
    with trace_mod.request(trace):
        return _run_dataset_scan(
            plan, consume, combine, window=window, depth=depth,
            decode_workers=decode_workers, service=service,
            prioritize=prioritize, open_opts=open_opts,
            fragment_retries=fragment_retries, on_error=on_error,
            retries=retries, deadline=deadline, tenant=tenant,
            result_cache=result_cache, fingerprint=fingerprint)


def _run_dataset_scan(plan: DatasetScanPlan, consume, combine, *,
                      window, depth, decode_workers, service, prioritize,
                      open_opts, fragment_retries, on_error, retries,
                      deadline, tenant=None, result_cache=None,
                      fingerprint=None):
    opts = dict(DEFAULT_OPEN_OPTS, **(open_opts or {}))
    opts["columns"] = plan.columns
    n = len(plan.fragments)
    window = max(1, min(window, max(1, n)))
    if decode_workers is None:
        from repro.core.overlap import default_decode_workers
        decode_workers = default_decode_workers()
    svc = service
    if svc is None and (decode_workers is None or decode_workers >= 1):
        from repro.core.scheduler import scan_service
        svc = scan_service()

    accs: list[object] = [None] * n
    reports: list[RunReport | None] = [None] * n
    walls: list[float] = [0.0] * n
    errors: list[BaseException] = []
    quarantined: list[dict] = []
    frag_retries = [0]            # whole-fragment re-scan attempts spent
    cache_hits = [0]              # fragments answered from result_cache
    next_pos = [0]
    lock = threading.Lock()
    launches0 = kernel_launch_count()
    use_cache = result_cache is not None and fingerprint is not None
    if use_cache:
        from repro.dataset.result_cache import MISS

    def scan_fragment(pos: int) -> None:
        """One fragment through retry-then-quarantine."""
        frag = plan.fragments[pos]
        if use_cache:
            cached = result_cache.get(plan.dataset.root,
                                      plan.dataset.generation,
                                      frag.path, fingerprint)
            if cached is not MISS:
                accs[pos] = cached
                with lock:
                    cache_hits[0] += 1
                tr = trace_mod.active()
                if tr is not None:
                    tr.instant("result_cache_hit", "fragment",
                               fragment=frag.path, index=pos,
                               **({"tenant": tenant}
                                  if tenant is not None else {}))
                trace_mod.registry().counter_inc(
                    "executor.result_cache_hits")
                return
        budget = 1 + max(0, fragment_retries)
        failure: BaseException | None = None
        for attempt in range(budget):
            with lock:
                if errors:          # strict mode is already aborting
                    return
            try:
                scanner: Scanner = plan.dataset.open_fragment(
                    frag, **opts)
                t0 = time.perf_counter()
                acc, report = run_overlapped(
                    scanner, consume,
                    predicate_stats=plan.predicate_stats, depth=depth,
                    decode_workers=decode_workers, service=svc,
                    priority=pos if prioritize == "order" else 0,
                    retries=retries, deadline=deadline, tenant=tenant)
                t1 = time.perf_counter()
                walls[pos] = t1 - t0
                tr = trace_mod.active()
                if tr is not None:
                    tr.complete("fragment", "fragment", t0, t1,
                                fragment=frag.path,
                                index=pos, attempt=attempt)
                accs[pos] = acc
                reports[pos] = report
                if attempt:
                    with lock:
                        frag_retries[0] += attempt
                if use_cache:
                    result_cache.put(plan.dataset.root,
                                     plan.dataset.generation,
                                     frag.path, fingerprint, acc)
                return
            except BaseException as e:  # noqa: BLE001 — classified below
                failure = e
                if (isinstance(e, DeadlineExceeded)
                        or not is_retryable(e)):
                    break           # budgets and logic errors never retry
        entry = {"fragment": plan.fragments[pos].path, "index": pos,
                 "attempts": min(attempt + 1, budget),
                 "error": repr(failure),
                 "error_type": type(failure).__name__}
        tr = trace_mod.active()
        if tr is not None:
            tr.instant("quarantine", "fragment", **entry)
        trace_mod.registry().counter_inc("executor.quarantined")
        with lock:
            frag_retries[0] += min(attempt, budget - 1)
            quarantined.append(entry)
            if on_error == "strict":
                errors.append(failure)

    def worker() -> None:
        while True:
            with lock:
                if errors or next_pos[0] >= n:
                    return
                pos = next_pos[0]
                next_pos[0] += 1
            scan_fragment(pos)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, daemon=True,
                                name=f"dataset-scan-{k}")
               for k in range(window)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_end = time.perf_counter()
    measured_wall = t_end - t0
    tr = trace_mod.active()
    if tr is not None:
        tr.complete("dataset_scan", "scan", t0, t_end,
                    fragments=n, window=window)
    if errors:
        # structured report: every quarantined fragment, worst first; the
        # original failure is chained for its traceback
        raise FragmentError(sorted(quarantined,
                                   key=lambda q: q["index"])) \
            from errors[0]

    done = [r for r in reports if r is not None]
    rep = _build_report(plan, measured_wall=measured_wall, window=window,
                        walls=walls, done=done, launches0=launches0,
                        frag_retries=frag_retries[0],
                        quarantined=quarantined)
    rep.result_cache_hits = cache_hits[0]
    if combine is None:
        return list(accs), rep
    acc = functools.reduce(
        lambda a, b: b if a is None else (a if b is None
                                          else combine(a, b)),
        accs, None)
    return acc, rep


def _build_report(plan: DatasetScanPlan, *, measured_wall: float,
                  window: int, walls: list[float], done: list[RunReport],
                  launches0: int, frag_retries: int,
                  quarantined: list[dict], devices: int = 1,
                  device_names: list[str] | None = None,
                  device_fragments: list[int] | None = None,
                  stolen_fragments: int = 0) -> DatasetRunReport:
    """Merge per-fragment RunReports into one DatasetRunReport (shared by
    the windowed and the distributed executors)."""
    bytes_by_backend: dict[str, int] = {}
    for r in done:
        kind = r.metrics.backend
        bytes_by_backend[kind] = (bytes_by_backend.get(kind, 0)
                                  + r.metrics.stored_bytes)
    # request-weighted average of per-fragment latency percentiles —
    # raw samples live in the (closed) fragment storages, so this is the
    # best report-level view; informational only, never gated
    reqs = sum(r.metrics.n_io_requests for r in done)
    p50 = p95 = 0.0
    if reqs:
        p50 = sum(r.metrics.io_p50_us * r.metrics.n_io_requests
                  for r in done) / reqs
        p95 = sum(r.metrics.io_p95_us * r.metrics.n_io_requests
                  for r in done) / reqs
    rep = DatasetRunReport(
        files_total=plan.files_total, files_scanned=plan.files_scanned,
        pruned_partition=plan.pruned_partition,
        pruned_stats=plan.pruned_stats,
        measured_wall=measured_wall, window=window,
        fragment_walls=list(walls), reports=done,
        n_kernel_launches=kernel_launch_count() - launches0,
        n_io_requests=reqs,
        shared_rgs=sum(r.metrics.shared_rgs for r in done),
        n_row_groups=sum(r.metrics.n_row_groups for r in done),
        stored_bytes=sum(r.metrics.stored_bytes for r in done),
        logical_bytes=sum(r.metrics.logical_bytes for r in done),
        retries=(sum(r.metrics.retries for r in done) + frag_retries),
        checksum_failures=sum(r.metrics.checksum_failures for r in done),
        timeouts=sum(r.metrics.timeouts for r in done),
        quarantined=sorted(quarantined, key=lambda q: q["index"]),
        devices=devices, device_names=list(device_names or []),
        device_fragments=list(device_fragments or []),
        stolen_fragments=stolen_fragments,
        prefetch_hits=sum(r.metrics.prefetch_hits for r in done),
        prefetch_misses=sum(r.metrics.prefetch_misses for r in done),
        prefetch_hidden_seconds=sum(r.metrics.prefetch_hidden_seconds
                                    for r in done),
        prefetch_stall_seconds=sum(r.metrics.prefetch_stall_seconds
                                   for r in done),
        io_p50_us=p50, io_p95_us=p95,
        bytes_by_backend=bytes_by_backend)
    tr = trace_mod.active()
    if tr is not None:
        rep.trace_events = tr.event_count()
        rep.registry_snapshot = trace_mod.registry().snapshot()
    return rep


def run_distributed_scan(plan: DatasetScanPlan,
                         consume: Consume | None = None,
                         combine: Combine | None = None, *,
                         devices=None, depth: int = 2,
                         decode_workers: int | None = None,
                         open_opts: dict | None = None,
                         open_opts_for: Callable | None = None,
                         fragment_retries: int = 2,
                         on_error: str = "strict",
                         retries: int = 3, deadline: float | None = None,
                         fetch_threads: int | None = None,
                         prefetch_lookahead: int | None = None,
                         steal: bool = True, trace=None):
    """Multi-device dataset scan; returns ``(acc, DatasetRunReport)``.

    The tentpole of DESIGN.md §8: surviving fragments are split into
    key-range **contiguous shards** weighted by stored bytes
    (``parallel.sharding.contiguous_shards`` over the planner's
    partition-sorted order), one shard per device.  Each device runs its
    own ScanService — a private fetch pool (``fetch_threads``, default 4
    on the object backend, 1 on NVMe) and decode workers that dispatch
    under ``jax.default_device(device)`` so decode lands device-resident
    — and scans its shard serially; a device that drains its shard
    **steals** from the tail of the largest remaining shard
    (``steal=False`` pins the static assignment for tests).

    Determinism: per-fragment partials land in a plan-ordered slot list
    and are combined with the balanced ``tree_reduce`` whose shape
    depends only on the plan — so devices ∈ {1, 2, 4} are bit-identical,
    whatever device scanned which fragment (``combine=None`` returns the
    plan-ordered partials).  Note this pairing differs from
    ``run_dataset_scan``'s left fold, so compare distributed runs against
    distributed runs.

    ``devices`` is None (all jax devices), an int (first n, cycling on
    small hosts), or an explicit device list.  With
    ``open_opts={"prefetch": True, ...}`` each device opens the next
    ``prefetch_lookahead`` (default 2) fragments of its own shard early
    and issues their coalesced reads in the background, hiding remote
    latency behind the current fragment's decode.  ``open_opts_for(pos,
    fragment) -> dict`` overlays per-fragment open options (the chaos
    tests aim a FaultPlan at one shard with it).  Failure policy matches
    ``run_dataset_scan``: per-fragment retry-then-quarantine,
    strict/best_effort.  ``trace`` enables the flight recorder for this
    run (``run_dataset_scan`` contract).
    """
    with trace_mod.request(trace):
        return _run_distributed_scan(
            plan, consume, combine, devices=devices, depth=depth,
            decode_workers=decode_workers, open_opts=open_opts,
            open_opts_for=open_opts_for,
            fragment_retries=fragment_retries, on_error=on_error,
            retries=retries, deadline=deadline,
            fetch_threads=fetch_threads,
            prefetch_lookahead=prefetch_lookahead, steal=steal)


def _run_distributed_scan(plan: DatasetScanPlan, consume, combine, *,
                          devices, depth, decode_workers, open_opts,
                          open_opts_for, fragment_retries, on_error,
                          retries, deadline, fetch_threads,
                          prefetch_lookahead, steal):
    import jax

    from collections import deque

    from repro.launch.mesh import scan_devices
    from repro.parallel.collectives import tree_reduce
    from repro.parallel.sharding import contiguous_shards

    if on_error not in ("strict", "best_effort"):
        raise ValueError(f"on_error must be 'strict' or 'best_effort', "
                         f"got {on_error!r}")
    base_opts = dict(DEFAULT_OPEN_OPTS, **(open_opts or {}))
    base_opts["columns"] = plan.columns
    if devices is None or isinstance(devices, int):
        devs = scan_devices(devices)
    else:
        devs = list(devices)
    ndev = max(1, len(devs))
    backend = base_opts.get("backend", "real")
    if fetch_threads is None:
        fetch_threads = 4 if backend == "object" else 1
    if prefetch_lookahead is None:
        prefetch_lookahead = 2 if base_opts.get("prefetch") else 0
    if decode_workers is None:
        from repro.core.overlap import default_decode_workers
        decode_workers = default_decode_workers()
    services: list = [None] * ndev
    if decode_workers is None or decode_workers >= 1:
        from repro.core.scheduler import ScanService
        services = [ScanService(fetch_threads=fetch_threads, device=dev)
                    for dev in devs]

    n = len(plan.fragments)
    weights = [max(1, f.stored_bytes) for f in plan.fragments]
    shards = contiguous_shards(weights, ndev)
    queues = [deque(range(lo, hi)) for lo, hi in shards]
    tr0 = trace_mod.active()
    if tr0 is not None:
        for d, (lo, hi) in enumerate(shards):
            tr0.instant("shard_assign", "fragment", device=d,
                        lo=lo, hi=hi, fragments=hi - lo)

    accs: list[object] = [None] * n
    reports: list[RunReport | None] = [None] * n
    walls: list[float] = [0.0] * n
    device_counts = [0] * ndev
    stolen = [0]
    errors: list[BaseException] = []
    quarantined: list[dict] = []
    frag_retries = [0]
    lock = threading.Lock()
    launches0 = kernel_launch_count()

    def opts_for(pos: int) -> dict:
        if open_opts_for is None:
            return base_opts
        extra = open_opts_for(pos, plan.fragments[pos])
        if not extra:
            return base_opts
        merged = dict(base_opts, **extra)
        merged["columns"] = plan.columns
        return merged

    def claim(d: int) -> int | None:
        with lock:
            if errors:
                return None
            if queues[d]:
                return queues[d].popleft()
            if steal:
                victim = max(range(ndev), key=lambda j: len(queues[j]))
                if queues[victim]:
                    stolen[0] += 1
                    pos = queues[victim].pop()    # tail: farthest from
                                                  # the victim's cursor
                    tr = trace_mod.active()
                    if tr is not None:
                        tr.instant("steal", "fragment", thief=d,
                                   victim=victim, index=pos)
                    trace_mod.registry().counter_inc("executor.steals")
                    return pos
            return None

    def prefetch_ahead(d: int, cache: dict) -> None:
        if not prefetch_lookahead:
            return
        with lock:
            ahead = list(queues[d])[:prefetch_lookahead]
        for p in ahead:
            if p in cache:
                continue
            try:
                sc: Scanner = plan.dataset.open_fragment(
                    plan.fragments[p], **opts_for(p))
                sc.prefetch_rgs(sc.plan(plan.predicate_stats))
                cache[p] = sc
            except BaseException:  # noqa: BLE001 — prefetch is advisory;
                pass               # the demand path retries and reports

    def scan_one(d: int, pos: int, cache: dict) -> None:
        budget = 1 + max(0, fragment_retries)
        failure: BaseException | None = None
        for attempt in range(budget):
            with lock:
                if errors:
                    return
            try:
                scanner = cache.pop(pos, None)
                if scanner is None:
                    scanner = plan.dataset.open_fragment(
                        plan.fragments[pos], **opts_for(pos))
                t0 = time.perf_counter()
                acc, report = run_overlapped(
                    scanner, consume,
                    predicate_stats=plan.predicate_stats, depth=depth,
                    decode_workers=decode_workers, service=services[d],
                    retries=retries, deadline=deadline)
                t1 = time.perf_counter()
                walls[pos] = t1 - t0
                tr = trace_mod.active()
                if tr is not None:
                    tr.complete("fragment", "fragment", t0, t1,
                                fragment=plan.fragments[pos].path,
                                index=pos, attempt=attempt, device=d)
                accs[pos] = acc
                reports[pos] = report
                if attempt:
                    with lock:
                        frag_retries[0] += attempt
                return
            except BaseException as e:  # noqa: BLE001 — classified below
                failure = e
                if (isinstance(e, DeadlineExceeded)
                        or not is_retryable(e)):
                    break
        entry = {"fragment": plan.fragments[pos].path, "index": pos,
                 "attempts": min(attempt + 1, budget),
                 "error": repr(failure),
                 "error_type": type(failure).__name__}
        tr = trace_mod.active()
        if tr is not None:
            tr.instant("quarantine", "fragment", device=d, **entry)
        trace_mod.registry().counter_inc("executor.quarantined")
        with lock:
            frag_retries[0] += min(attempt, budget - 1)
            quarantined.append(entry)
            if on_error == "strict":
                errors.append(failure)

    def device_worker(d: int) -> None:
        cache: dict[int, Scanner] = {}
        # consume runs on this thread; default_device routes its kernels
        # (and the inline decode path, when decode_workers=0) to the
        # device — the per-device ScanService pins its own workers
        with jax.default_device(devs[d]):
            while True:
                pos = claim(d)
                if pos is None:
                    break
                prefetch_ahead(d, cache)
                scan_one(d, pos, cache)
                device_counts[d] += 1
        cache.clear()   # drop unconsumed prefetched scanners (stolen)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=device_worker, daemon=True,
                                args=(d,), name=f"dataset-device-{d}")
               for d in range(ndev)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_end = time.perf_counter()
    measured_wall = t_end - t0
    tr = trace_mod.active()
    if tr is not None:
        tr.complete("distributed_scan", "scan", t0, t_end,
                    fragments=n, devices=ndev, stolen=stolen[0])
    for svc in services:
        if svc is not None:
            svc.shutdown()
    if errors:
        raise FragmentError(sorted(quarantined,
                                   key=lambda q: q["index"])) \
            from errors[0]

    done = [r for r in reports if r is not None]
    rep = _build_report(plan, measured_wall=measured_wall, window=1,
                        walls=walls, done=done, launches0=launches0,
                        frag_retries=frag_retries[0],
                        quarantined=quarantined, devices=ndev,
                        device_names=[str(dv) for dv in devs],
                        device_fragments=device_counts,
                        stolen_fragments=stolen[0])
    if combine is None:
        return list(accs), rep
    return tree_reduce(accs, combine), rep
