"""Online dataset compaction: small/misconfigured fragments → tuned files.

A dataset accumulates fragments written under whatever config the
producer used (streaming appends are often tiny, CPU-era defaults are
common).  Compaction detects fragments that are *small* (fewer rows than
a fraction of the target row-group size — their scans are all pipeline
head/tail) or *misconfigured* (footer ``FileConfig`` fingerprint differs
from the target), merges mergeable neighbors, and rewrites them to the
GPU-aware target config through the streaming rewriter (bounded memory).
The target config comes from the operator or from ``core/autotune`` on a
sample of the data.

**Atomicity contract**: all replacement fragment files are fully written
*before* the manifest is touched, then one ``Dataset.save()`` —
``os.replace`` of the manifest — publishes them.  A reader (or a crash)
at any point before the swap sees the old manifest over the old files,
both still intact; old files are unlinked only after the swap lands.
A failure mid-rewrite deletes the partial replacement files and leaves
the dataset exactly as it was.

Scope of "online": scans already *running* at swap time finish
correctly — their scanners hold open fds, which POSIX unlink does not
invalidate.  The unguarded window is a reader that loaded the old
manifest but has not yet opened a replaced fragment: its open raises
``FileNotFoundError`` after the swap.  Single-process serving (the
ScanService model) never hits this mid-scan; multi-process deployments
should pass ``keep_old=True`` and garbage-collect old generations once
their readers drain.
"""

from __future__ import annotations

import dataclasses
import os
import time

from repro.core.autotune import autotune
from repro.core.config import FileConfig
from repro.core.metadata import FileMeta
from repro.core.reader import TabFileReader
from repro.core.schema import Schema
from repro.core.table import Table
from repro.core.writer import TabFileWriter
from repro.dataset.catalog import (Dataset, FragmentInfo,
                                   _fragment_from_meta)


@dataclasses.dataclass
class CompactionPlan:
    target_config: FileConfig
    groups: list[list[int]]        # manifest indices merged per output
    reasons: dict[int, str]        # candidate index -> why it was picked
    notes: list[str] = dataclasses.field(default_factory=list)

    @property
    def n_inputs(self) -> int:
        return sum(len(g) for g in self.groups)

    @property
    def n_outputs(self) -> int:
        return len(self.groups)


@dataclasses.dataclass
class CompactionReport:
    seconds: float
    n_inputs: int
    n_outputs: int
    rows: int
    src_stored_bytes: int
    dst_stored_bytes: int
    target_fingerprint: dict
    reasons: dict[int, str]

    @property
    def size_ratio(self) -> float:
        return self.dst_stored_bytes / max(1, self.src_stored_bytes)


def _sample_table(dataset: Dataset, rows: int = 100_000) -> Table:
    """A representative sample for the autotuner: the first row group(s)
    of the dataset's largest fragment."""
    frag = max(dataset.fragments, key=lambda f: f.num_rows)
    reader = TabFileReader(dataset.fragment_path(frag))
    tbl = reader.read_table(row_groups=[0])
    return tbl.slice(0, min(rows, tbl.num_rows))


def _partition_group_key(frag: FragmentInfo):
    """Fragments may merge only within this identity: hash buckets must
    never mix (bucket routing would break); range/unpartitioned
    neighbors merge freely (their zone maps union)."""
    p = frag.partition
    if p is None:
        return ("none",)
    if p.get("kind") == "hash":
        return ("hash", p.get("column"), p.get("bucket"))
    return (p.get("kind"), p.get("column"))


def _merged_partition(frags: list[FragmentInfo]) -> dict | None:
    parts = [f.partition for f in frags]
    if parts[0] is None:
        return None
    if parts[0].get("kind") == "range":
        return {"kind": "range", "column": parts[0]["column"],
                "lo": min(p["lo"] for p in parts),
                "hi": max(p["hi"] for p in parts)}
    return dict(parts[0])


def plan_compaction(dataset: Dataset,
                    target_config: FileConfig | None = None,
                    small_fraction: float = 0.5,
                    max_group_rows: int | None = None,
                    sample_rows: int = 100_000,
                    autotune_kw: dict | None = None) -> CompactionPlan:
    """Decide what to rewrite.  A fragment is a candidate when its footer
    config fingerprint differs from the target's, or when it holds fewer
    than ``small_fraction * target.rows_per_rg`` rows.  Consecutive
    candidates with a compatible partition identity merge into one
    output, capped at ``max_group_rows`` (default 4× the target row-group
    size) so compaction never collapses a partitioned dataset into one
    unprunable file; each group is one streamed rewrite."""
    notes = []
    if target_config is None:
        tune = autotune(_sample_table(dataset, sample_rows),
                        **(autotune_kw or {}))
        target_config = tune.config
        notes.extend(tune.notes)
    fp = target_config.fingerprint()
    small_rows = int(small_fraction * target_config.rows_per_rg)
    if max_group_rows is None:
        max_group_rows = 4 * target_config.rows_per_rg

    reasons: dict[int, str] = {}
    for i, frag in enumerate(dataset.fragments):
        if frag.config != fp:
            reasons[i] = "misconfigured"
        elif frag.num_rows < small_rows:
            reasons[i] = "small"

    groups: list[list[int]] = []
    group_rows = 0
    prev_key = None
    for i in sorted(reasons):
        key = _partition_group_key(dataset.fragments[i])
        rows = dataset.fragments[i].num_rows
        if (groups and prev_key == key and groups[-1][-1] == i - 1
                and group_rows + rows <= max_group_rows):
            groups[-1].append(i)
            group_rows += rows
        else:
            groups.append([i])
            group_rows = rows
        prev_key = key
    return CompactionPlan(target_config=target_config, groups=groups,
                          reasons=reasons, notes=notes)


def _merge_rewrite(paths: list[str], dst: str, config: FileConfig,
                   threads: int) -> FileMeta:
    """Stream several source fragments through one writer, re-bucketing
    rows to the target ``rows_per_rg`` at bounded memory (the multi-file
    generalization of core/rewriter.rewrite_file)."""
    readers = [TabFileReader(p) for p in paths]
    names = readers[0].meta.schema.names
    schema = Schema([readers[0].meta.schema.field(n) for n in names])
    writer = TabFileWriter(dst, config, threads=threads).begin(schema)
    pending: list[Table] = []
    pending_rows = 0

    def flush(final: bool) -> None:
        nonlocal pending, pending_rows
        while pending_rows >= config.rows_per_rg or (final and pending_rows):
            buf = pending[0] if len(pending) == 1 else Table.concat(pending)
            n = min(config.rows_per_rg, buf.num_rows)
            writer.write_row_group(buf.slice(0, n))
            rest = buf.slice(n, buf.num_rows)
            pending = [rest] if rest.num_rows > 0 else []
            pending_rows = rest.num_rows

    for reader in readers:
        for rg_idx in range(len(reader.meta.row_groups)):
            tbl = reader.read_table(columns=names, row_groups=[rg_idx])
            pending.append(tbl)
            pending_rows += tbl.num_rows
            flush(final=False)
    flush(final=True)
    return writer.finish()


def compact_dataset(dataset: Dataset,
                    plan: CompactionPlan | None = None,
                    target_config: FileConfig | None = None,
                    threads: int = 4, keep_old: bool = False
                    ) -> tuple[Dataset, CompactionReport]:
    """Execute a compaction plan against ``dataset`` (mutated in place and
    returned).  New fragment files are written first; one atomic manifest
    swap publishes them; old files are unlinked after (unless
    ``keep_old``)."""
    t0 = time.perf_counter()
    if plan is None:
        plan = plan_compaction(dataset, target_config=target_config)
    if not plan.groups:
        report = CompactionReport(
            seconds=time.perf_counter() - t0, n_inputs=0, n_outputs=0,
            rows=0, src_stored_bytes=0, dst_stored_bytes=0,
            target_fingerprint=plan.target_config.fingerprint(),
            reasons={})
        return dataset, report

    gen = dataset.generation + 1
    new_paths: list[str] = []
    replacements: dict[int, FragmentInfo] = {}   # first index -> new frag
    replaced: set[int] = set()
    try:
        for k, group in enumerate(plan.groups):
            frags = [dataset.fragments[i] for i in group]
            name = f"part-{k:05d}.g{gen}.tab"
            dst = os.path.join(dataset.root, name)
            srcs = [dataset.fragment_path(f) for f in frags]
            # register dst BEFORE writing so a mid-write failure unlinks
            # the partial output too, not just fully-written predecessors
            new_paths.append(dst)
            meta = _merge_rewrite(srcs, dst, plan.target_config,
                                  threads=threads)
            replacements[group[0]] = _fragment_from_meta(
                name, meta, _merged_partition(frags))
            replaced.update(group)
    except BaseException:
        # leave the dataset exactly as it was: manifest untouched, the
        # partially-written replacement files removed
        for p in new_paths:
            try:
                os.unlink(p)
            except OSError:
                pass
        raise

    old_files = [dataset.fragment_path(dataset.fragments[i])
                 for i in sorted(replaced)]
    src_stored = sum(dataset.fragments[i].stored_bytes
                     for i in sorted(replaced))
    rows = sum(dataset.fragments[i].num_rows for i in sorted(replaced))
    new_fragments: list[FragmentInfo] = []
    for i, frag in enumerate(dataset.fragments):
        if i in replacements:
            new_fragments.append(replacements[i])
        elif i not in replaced:
            new_fragments.append(frag)
    dataset.fragments = new_fragments
    dataset.generation = gen
    dataset.save()                      # the atomic publish point
    if not keep_old:
        for p in old_files:
            try:
                os.unlink(p)
            except OSError:
                pass
    report = CompactionReport(
        seconds=time.perf_counter() - t0,
        n_inputs=plan.n_inputs, n_outputs=plan.n_outputs, rows=rows,
        src_stored_bytes=src_stored,
        dst_stored_bytes=sum(f.stored_bytes
                             for f in replacements.values()),
        target_fingerprint=plan.target_config.fingerprint(),
        reasons=dict(plan.reasons))
    return dataset, report
