"""Dataset layer: partitioned multi-file catalogs over TabFiles.

Real analytical systems never scan one file — they scan partitioned
datasets of many files with heterogeneous configs, where file-level
pruning and parallel multi-file scheduling dominate end-to-end latency.
This package turns N single-file scans into one planned, pruned, sharded
multi-file query (DESIGN.md §5):

  catalog   Dataset + JSON manifest (per-fragment row counts, zone maps,
            partition values, FileConfig fingerprints); builders
  planner   DatasetScanPlan: partition + zone-map file pruning, locality
            ordering of surviving fragments
  executor  sharded execution through the shared ScanService with a
            bounded fragment window; DatasetRunReport
  compact   online compaction: small/misconfigured fragments rewritten
            to the tuned config behind an atomic manifest swap
"""

from repro.dataset.catalog import (Dataset, FragmentInfo, Partitioning,
                                   write_dataset)
from repro.dataset.compact import (CompactionPlan, CompactionReport,
                                   compact_dataset, plan_compaction)
from repro.dataset.executor import (DatasetRunReport, run_dataset_scan,
                                    run_distributed_scan)
from repro.dataset.planner import DatasetScanPlan, plan_dataset_scan

__all__ = [
    "Dataset", "FragmentInfo", "Partitioning", "write_dataset",
    "DatasetScanPlan", "plan_dataset_scan",
    "DatasetRunReport", "run_dataset_scan", "run_distributed_scan",
    "CompactionPlan", "CompactionReport", "plan_compaction",
    "compact_dataset",
]
