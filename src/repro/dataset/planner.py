"""Dataset scan planning: file-level pruning + locality ordering.

Pruning reuses the zone-map stats contract of ``core/query.py`` /
``TabFileReader.plan_row_groups`` unchanged: a predicate is a callable
``(column_name, {"min":…, "max":…}) -> keep`` (e.g.
``q6_rg_stats_predicate``).  The planner applies it one level up, to each
fragment's *file-level* zone maps from the manifest, so a fragment whose
whole key range misses the predicate is never opened, fetched, or
planned — and the same callable then prunes row groups *inside* each
surviving fragment during the scan.  Range-partition bounds are folded
into the same contract (the partition [lo, hi] is consulted as a
synthetic ``{"min": lo, "max": hi}`` stat for the partition column), so
one predicate drives partition pruning and zone-map pruning alike.

Surviving fragments are ordered for locality: range partitions ascend by
key range (consumers see keys roughly sorted; adjacent fragments were
written adjacently), everything else keeps manifest (write) order.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

from repro.dataset.catalog import Dataset, FragmentInfo

PredicateStats = Callable[[str, dict], bool]
PartitionFilter = Callable[[dict | None], bool]


@dataclasses.dataclass
class DatasetScanPlan:
    """Outcome of planning one dataset scan."""

    dataset: Dataset
    columns: list[str] | None
    fragments: list[FragmentInfo]      # surviving, locality-ordered
    indices: list[int]                 # manifest index of each survivor
    pruned_partition: int = 0          # dropped by partition value/range
    pruned_stats: int = 0              # dropped by file-level zone maps
    predicate_stats: PredicateStats | None = None

    @property
    def files_total(self) -> int:
        return len(self.dataset.fragments)

    @property
    def files_scanned(self) -> int:
        return len(self.fragments)

    @property
    def files_pruned(self) -> int:
        return self.pruned_partition + self.pruned_stats

    def summary(self) -> str:
        return (f"files={self.files_total};scanned={self.files_scanned};"
                f"pruned_partition={self.pruned_partition};"
                f"pruned_stats={self.pruned_stats}")


def _partition_as_stats(partition: dict | None) -> tuple[str, dict] | None:
    """A range partition's bounds as a synthetic zone-map stat."""
    if partition and partition.get("kind") == "range":
        return partition["column"], {"min": partition["lo"],
                                     "max": partition["hi"]}
    return None


def plan_dataset_scan(dataset: Dataset,
                      columns: list[str] | None = None,
                      predicate_stats: PredicateStats | None = None,
                      partition_filter: PartitionFilter | None = None
                      ) -> DatasetScanPlan:
    """Prune the manifest down to the fragments a scan must touch.

    ``predicate_stats`` is the shared zone-map contract (applied to
    range-partition bounds, then to every recorded file-level column
    stat); ``partition_filter`` is an optional direct test on the raw
    partition dict (e.g. hash-bucket equality: keep only
    ``part["bucket"] == Partitioning.bucket_of(literal)``).  Both must be
    conservative — keep on uncertainty — exactly like row-group stats.
    """
    survivors: list[tuple[int, FragmentInfo]] = []
    pruned_partition = 0
    pruned_stats = 0
    for i, frag in enumerate(dataset.fragments):
        if partition_filter is not None and not partition_filter(
                frag.partition):
            pruned_partition += 1
            continue
        part_stat = _partition_as_stats(frag.partition)
        if (predicate_stats is not None and part_stat is not None
                and not predicate_stats(*part_stat)):
            pruned_partition += 1
            continue
        if predicate_stats is not None and not all(
                predicate_stats(name, stats)
                for name, stats in frag.column_stats.items()):
            pruned_stats += 1
            continue
        survivors.append((i, frag))

    if dataset.partitioning.kind == "range":
        survivors.sort(key=lambda t: (t[1].partition or {}).get(
            "lo", float("-inf")))
    return DatasetScanPlan(
        dataset=dataset, columns=columns,
        fragments=[f for _, f in survivors],
        indices=[i for i, _ in survivors],
        pruned_partition=pruned_partition, pruned_stats=pruned_stats,
        predicate_stats=predicate_stats)
