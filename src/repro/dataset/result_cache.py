"""Fragment-level result cache for dataset scans (DESIGN.md §11).

The ScanService's delivered-result window reuses decoded *row groups*;
this cache sits one level up and reuses whole per-fragment **partial
accumulators** — the value ``run_overlapped(scanner, consume)`` reduced
for one fragment.  A repeated identical dataset query (same fragments,
same predicate fingerprint) then skips the fragment scan entirely: no
open, no fetch, no decode.

Keys are ``(dataset root, manifest generation, fragment path,
predicate fingerprint)``:

  * **generation** is the manifest generation the value was computed
    under.  Every manifest swap (append, compaction) bumps the
    generation and ``Dataset.save()`` calls
    :func:`invalidate_dataset`, evicting every entry of that root whose
    generation is stale — conservative (an append keeps old fragment
    files byte-identical) but unconditionally safe, and it makes the
    invalidation contract one sentence: *a cached result never outlives
    the manifest it was computed under*.  A crashed compaction never
    reaches ``save()``, so current-generation entries stay valid
    (pinned in tests/test_tenancy.py mirroring tests/test_faults.py).
  * **fingerprint** is the caller's digest of everything else that
    shapes the partial: the query's predicate + consume function
    identity (q6/q12 pass a constant per query form).  Callers that
    cannot fingerprint their consume must not pass a cache.

The cache is opt-in per call (``run_dataset_scan(result_cache=...,
fingerprint=...)``); the serving front end (serve/engine.py) owns one
per process.  Thread-safe; entry-capped LRU.
"""

from __future__ import annotations

import threading
import weakref
from collections import OrderedDict

from ..core import trace

#: sentinel distinguishing "no entry" from a cached ``None`` partial
MISS = object()

#: every live cache, for process-wide invalidation and cold-ladder clears
_ALL_CACHES: "weakref.WeakSet[FragmentResultCache]" = weakref.WeakSet()


class FragmentResultCache:
    """Entry-capped LRU of per-fragment partial accumulators."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max(1, int(max_entries))
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidated = 0
        _ALL_CACHES.add(self)

    @staticmethod
    def _key(root: str, generation: int, fragment_path: str,
             fingerprint: str) -> tuple:
        return (root, int(generation), fragment_path, fingerprint)

    def get(self, root: str, generation: int, fragment_path: str,
            fingerprint: str):
        """The cached partial, or :data:`MISS`."""
        key = self._key(root, generation, fragment_path, fingerprint)
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                trace.registry().counter_inc("result_cache.hits")
                tr = trace.active()
                if tr is not None:
                    tr.instant("result_cache_hit", "io",
                               fragment=fragment_path)
                return self._entries[key]
            self.misses += 1
            trace.registry().counter_inc("result_cache.misses")
            return MISS

    def put(self, root: str, generation: int, fragment_path: str,
            fingerprint: str, value) -> None:
        key = self._key(root, generation, fragment_path, fingerprint)
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
                trace.registry().counter_inc("result_cache.evictions")

    def invalidate(self, root: str, current_generation: int) -> int:
        """Evict every entry of ``root`` whose generation is not
        ``current_generation`` (the manifest-swap contract); returns the
        eviction count."""
        with self._lock:
            stale = [k for k in self._entries
                     if k[0] == root and k[1] != int(current_generation)]
            for k in stale:
                del self._entries[k]
            self.invalidated += len(stale)
            if stale:
                trace.registry().counter_inc("result_cache.invalidated",
                                             len(stale))
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


def invalidate_dataset(root: str, current_generation: int) -> int:
    """Manifest-swap hook (``Dataset.save()``): evict stale-generation
    entries of ``root`` from every live cache."""
    n = 0
    for cache in list(_ALL_CACHES):
        n += cache.invalidate(root, current_generation)
    return n


def clear_all_result_caches() -> None:
    """Cold-scan-ladder hook: empty every live cache."""
    for cache in list(_ALL_CACHES):
        cache.clear()
