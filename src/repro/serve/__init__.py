# Serving substrate: cache shardings, batched prefill/decode engine.
