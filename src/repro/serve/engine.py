"""Batched serving engine: length-bucketed scheduler + prefill/decode loop.

Requests are grouped into equal-prompt-length buckets (the scheduler pads
the tail batch), each bucket runs one prefill then greedy/temperature
decode against the cache pytree.  Throughput metrics (prefill tokens/s,
decode steps/s) are reported per bucket — the serving-side face of the
paper's pipeline: prompt tokens stream out of TabFiles through the
configured scan, and the decode loop overlaps host batch assembly with
device steps via async dispatch.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_seconds: float
    decode_seconds: float


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int = 8,
                 max_seq: int = 512, temperature: float = 0.0,
                 seed: int = 0):
        if model.cfg.encoder_only:
            raise ValueError("encoder-only archs are not served")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._rng = jax.random.PRNGKey(seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(
            k, logits / self.temperature, axis=-1).astype(jnp.int32)

    def _run_bucket(self, requests: list[Request]) -> list[Completion]:
        b = len(requests)
        lp = requests[0].prompt.shape[0]
        assert all(r.prompt.shape[0] == lp for r in requests)
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]),
                              jnp.int32)
        max_new = max(r.max_new_tokens for r in requests)
        caches = self.model.init_caches(b, min(self.max_seq,
                                               lp + max_new + 1))
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, {"tokens": prompts},
                                       caches)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out = np.zeros((b, max_new), dtype=np.int32)
        tok = self._sample(logits)[:, None]
        t0 = time.perf_counter()
        for i in range(max_new):
            out[:, i] = np.asarray(tok[:, 0])
            if i == max_new - 1:
                break
            logits, caches = self._decode(
                self.params, tok, jnp.asarray(lp + i, jnp.int32), caches)
            tok = self._sample(logits)[:, None]
        t_decode = time.perf_counter() - t0

        completions = []
        for j, r in enumerate(requests):
            toks = out[j, :r.max_new_tokens]
            if r.eos_id is not None:
                stop = np.flatnonzero(toks == r.eos_id)
                if stop.size:
                    toks = toks[:stop[0] + 1]
            completions.append(Completion(r.uid, toks, t_prefill, t_decode))
        return completions

    def generate(self, requests: list[Request]) -> dict[int, Completion]:
        """Length-bucketed batch scheduling."""
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(r.prompt.shape[0], []).append(r)
        results: dict[int, Completion] = {}
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                chunk = reqs[i:i + self.max_batch]
                for c in self._run_bucket(chunk):
                    results[c.uid] = c
        return results

    def throughput_report(self, completions: dict[int, Completion]) -> dict:
        n_prompt = sum(c.tokens.shape[0] for c in completions.values())
        total_decode = sum(c.decode_seconds for c in completions.values())
        total_prefill = sum(c.prefill_seconds for c in completions.values())
        return {
            "n_requests": len(completions),
            "prefill_seconds": total_prefill,
            "decode_seconds": total_decode,
            "new_tokens": int(n_prompt),
            "decode_tokens_per_s": n_prompt / max(1e-9, total_decode),
        }
