"""Batched serving engine: length-bucketed scheduler + prefill/decode loop.

Requests are grouped into equal-prompt-length buckets (the scheduler pads
the tail batch), each bucket runs one prefill then greedy/temperature
decode against the cache pytree.  Throughput metrics (prefill tokens/s,
decode steps/s) are reported per bucket — the serving-side face of the
paper's pipeline: prompt tokens stream out of TabFiles through the
configured scan, and the decode loop overlaps host batch assembly with
device steps via async dispatch.

This module also hosts the **multi-tenant query front end**
(:class:`QueryFrontEnd`, DESIGN.md §11): a session API — ``submit`` /
``poll`` / ``cancel`` with tenant identity — over a ScanService
configured for serving (weighted fair shares, admission control, a
delivered-result window) plus a process-level fragment result cache.
Queries route through q6/q12 and the dataset executor exactly as the
library paths do; the front end only adds tenancy, ticketing, and
caching on top.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_seconds: float
    decode_seconds: float


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int = 8,
                 max_seq: int = 512, temperature: float = 0.0,
                 seed: int = 0):
        if model.cfg.encoder_only:
            raise ValueError("encoder-only archs are not served")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._rng = jax.random.PRNGKey(seed)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(
            k, logits / self.temperature, axis=-1).astype(jnp.int32)

    def _run_bucket(self, requests: list[Request]) -> list[Completion]:
        b = len(requests)
        lp = requests[0].prompt.shape[0]
        assert all(r.prompt.shape[0] == lp for r in requests)
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]),
                              jnp.int32)
        max_new = max(r.max_new_tokens for r in requests)
        caches = self.model.init_caches(b, min(self.max_seq,
                                               lp + max_new + 1))
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, {"tokens": prompts},
                                       caches)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out = np.zeros((b, max_new), dtype=np.int32)
        tok = self._sample(logits)[:, None]
        t0 = time.perf_counter()
        for i in range(max_new):
            out[:, i] = np.asarray(tok[:, 0])
            if i == max_new - 1:
                break
            logits, caches = self._decode(
                self.params, tok, jnp.asarray(lp + i, jnp.int32), caches)
            tok = self._sample(logits)[:, None]
        t_decode = time.perf_counter() - t0

        completions = []
        for j, r in enumerate(requests):
            toks = out[j, :r.max_new_tokens]
            if r.eos_id is not None:
                stop = np.flatnonzero(toks == r.eos_id)
                if stop.size:
                    toks = toks[:stop[0] + 1]
            completions.append(Completion(r.uid, toks, t_prefill, t_decode))
        return completions

    def generate(self, requests: list[Request]) -> dict[int, Completion]:
        """Length-bucketed batch scheduling."""
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(r.prompt.shape[0], []).append(r)
        results: dict[int, Completion] = {}
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                chunk = reqs[i:i + self.max_batch]
                for c in self._run_bucket(chunk):
                    results[c.uid] = c
        return results

    def throughput_report(self, completions: dict[int, Completion]) -> dict:
        n_prompt = sum(c.tokens.shape[0] for c in completions.values())
        total_decode = sum(c.decode_seconds for c in completions.values())
        total_prefill = sum(c.prefill_seconds for c in completions.values())
        return {
            "n_requests": len(completions),
            "prefill_seconds": total_prefill,
            "decode_seconds": total_decode,
            "new_tokens": int(n_prompt),
            "decode_tokens_per_s": n_prompt / max(1e-9, total_decode),
        }


# ---------------------------------------------------------------------------
# multi-tenant query front end (DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryTicket:
    """One submitted query's lifecycle record.  ``state`` walks
    queued → running → done | failed | rejected | cancelled."""

    id: str
    tenant: str
    query: str
    state: str = "queued"
    result: object = None
    reports: tuple = ()
    error: BaseException | None = None
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def finished(self) -> bool:
        return self.state in ("done", "failed", "rejected", "cancelled")


class QueryFrontEnd:
    """Session API over the multi-tenant ScanService: ``submit`` /
    ``poll`` / ``cancel`` with tenant identity.

    The front end owns (unless given) a ScanService with the
    delivered-result window enabled and a process-level
    FragmentResultCache, and routes every query through the library
    paths — ``q6``/``q12`` and the dataset executor — with
    ``tenant=``/``result_cache=`` attached.  Tenants are registered with
    a fair-share ``weight``, an admission bound ``max_active`` (typed
    rejection or queueing), and an optional ``slo_s`` latency target
    feeding the adaptive pool sizer.  Each submitted query runs on its
    own thread; ``cancel`` is best-effort — a queued ticket never runs,
    a running ticket's result is discarded at completion."""

    DEFAULT_WINDOW_BYTES = 64 << 20

    def __init__(self, service=None,
                 window_bytes: int = DEFAULT_WINDOW_BYTES,
                 result_cache=None, workers: int | None = None):
        from repro.core.scheduler import ScanService
        from repro.dataset.result_cache import FragmentResultCache
        self._own_service = service is None
        self._service = service if service is not None else \
            ScanService(workers=workers, window_bytes=window_bytes)
        self.result_cache = (result_cache if result_cache is not None
                             else FragmentResultCache())
        self._lock = threading.Lock()
        self._tickets: dict[str, QueryTicket] = {}
        self._threads: dict[str, threading.Thread] = {}
        self._ids = itertools.count(1)
        self._shutdown = False

    @property
    def service(self):
        return self._service

    def register_tenant(self, name: str, weight: int = 1,
                        max_active: int | None = None,
                        on_limit: str = "reject",
                        slo_s: float | None = None):
        return self._service.register_tenant(
            name, weight=weight, max_active=max_active,
            on_limit=on_limit, slo_s=slo_s)

    def submit(self, tenant: str, query: str, source,
               **query_kwargs) -> str:
        """Submit one query for ``tenant``; returns a ticket id.

        ``query`` is ``"q6"`` (source: a Scanner or Dataset) or
        ``"q12"`` (source: a ``(lineitem, orders)`` pair).  Extra
        keyword arguments forward to the query function.  Admission
        happens inside the query's scan submission: a tenant at its
        bound with ``on_limit="reject"`` lands the ticket in state
        ``rejected``; ``"queue"`` keeps it ``running`` until a slot
        frees."""
        if query not in ("q6", "q12"):
            raise ValueError(f"unknown query {query!r}")
        with self._lock:
            if self._shutdown:
                raise RuntimeError("QueryFrontEnd is shut down")
            tid = f"t{next(self._ids)}"
            ticket = QueryTicket(id=tid, tenant=tenant, query=query,
                                 submitted_at=time.monotonic())
            self._tickets[tid] = ticket
            t = threading.Thread(
                target=self._run, args=(ticket, source, query_kwargs),
                daemon=True, name=f"frontend-{tenant}-{tid}")
            self._threads[tid] = t
        t.start()
        return tid

    def _run(self, ticket: QueryTicket, source, kwargs) -> None:
        from repro.core.query import q6, q12
        from repro.core.scheduler import AdmissionRejected
        with self._lock:
            if ticket.state == "cancelled":
                return
            ticket.state = "running"
        try:
            if ticket.query == "q6":
                acc, report = q6(source, service=self._service,
                                 tenant=ticket.tenant,
                                 result_cache=self.result_cache, **kwargs)
                result, reports = acc, (report,)
            else:
                line, orders = source
                res, br, pr = q12(line, orders, service=self._service,
                                  tenant=ticket.tenant,
                                  result_cache=self.result_cache,
                                  **kwargs)
                result, reports = res, (br, pr)
        except AdmissionRejected as e:
            with self._lock:
                if ticket.state != "cancelled":
                    ticket.state = "rejected"
                    ticket.error = e
                ticket.finished_at = time.monotonic()
            return
        except BaseException as e:  # noqa: BLE001 — surfaced via poll
            with self._lock:
                if ticket.state != "cancelled":
                    ticket.state = "failed"
                    ticket.error = e
                ticket.finished_at = time.monotonic()
            return
        with self._lock:
            if ticket.state != "cancelled":   # cancelled → discard result
                ticket.result = result
                ticket.reports = reports
                ticket.state = "done"
            ticket.finished_at = time.monotonic()

    def poll(self, ticket_id: str) -> dict:
        """Non-blocking status: ``state``, ``result`` (when done),
        ``error`` (repr, when failed/rejected), and the wall so far."""
        with self._lock:
            ticket = self._tickets[ticket_id]
            end = (ticket.finished_at if ticket.finished
                   else time.monotonic())
            return {
                "id": ticket.id, "tenant": ticket.tenant,
                "query": ticket.query, "state": ticket.state,
                "result": ticket.result,
                "error": (repr(ticket.error)
                          if ticket.error is not None else None),
                "wall_s": max(0.0, end - ticket.submitted_at),
            }

    def result(self, ticket_id: str, timeout: float | None = None):
        """Block until the ticket finishes; returns ``(result, reports)``
        or re-raises the query's error (AdmissionRejected included)."""
        t = self._threads.get(ticket_id)
        if t is not None:
            t.join(timeout)
        with self._lock:
            ticket = self._tickets[ticket_id]
            if not ticket.finished:
                raise TimeoutError(f"ticket {ticket_id} still "
                                   f"{ticket.state}")
            if ticket.error is not None:
                raise ticket.error
            if ticket.state == "cancelled":
                raise RuntimeError(f"ticket {ticket_id} was cancelled")
            return ticket.result, ticket.reports

    def cancel(self, ticket_id: str) -> bool:
        """Best-effort cancel; True when the ticket had not finished.
        A queued ticket never runs; a running ticket's result is
        discarded when its thread completes."""
        with self._lock:
            ticket = self._tickets[ticket_id]
            if ticket.finished:
                return False
            ticket.state = "cancelled"
            ticket.finished_at = time.monotonic()
            return True

    def tickets(self, tenant: str | None = None) -> list[dict]:
        with self._lock:
            ids = [t.id for t in self._tickets.values()
                   if tenant is None or t.tenant == tenant]
        return [self.poll(i) for i in ids]

    def shutdown(self, timeout: float = 10.0) -> None:
        with self._lock:
            self._shutdown = True
            threads = list(self._threads.values())
        for t in threads:
            t.join(timeout)
        if self._own_service:
            self._service.shutdown()

    def __enter__(self) -> "QueryFrontEnd":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
