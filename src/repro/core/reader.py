"""TabFile reader: footer parse, scan planning, host decode path.

The reader is storage-backend agnostic: all byte access goes through a
``fetch(offset, size) -> bytes`` callable so the same code path serves real
files and the simulated N-lane SSD backend (core/storage.py).
"""

from __future__ import annotations

import os
import struct
from collections.abc import Callable, Sequence

import numpy as np

from repro.core.compression import (ChecksumError, Codec, decompress,
                                    page_crc, verify_checksums, verify_page)
from repro.core.encodings import Encoding, decode_page
from repro.core.metadata import MAGIC, ChunkMeta, FileMeta, RowGroupMeta
from repro.core.schema import Field
from repro.core.storage import DEFAULT_COALESCE_GAP, fetch_ranges
from repro.core.table import StringColumn, Table

Fetch = Callable[[int, int], bytes]


def _parse_footer_block(block: bytes, path: str) -> FileMeta:
    """Parse a footer block ``json + LE32 crc32(json)``.  Crc-less legacy
    footers (whole block is the json) stay readable; a block that is
    neither raises ChecksumError — corrupt metadata must never yield
    bogus page offsets."""
    if len(block) >= 4:
        body, tail = block[:-4], block[-4:]
        expected = struct.unpack("<I", tail)[0]
        if page_crc(body) == expected:
            return FileMeta.from_json_bytes(body)
        if verify_checksums():
            # distinguish "legacy crc-less footer" from "corrupt footer":
            # a legacy block is itself valid JSON end to end
            try:
                return FileMeta.from_json_bytes(block)
            except Exception:
                raise ChecksumError("footer", expected, page_crc(body),
                                    path=path) from None
    return FileMeta.from_json_bytes(block)


def read_footer(path: str) -> FileMeta:
    with open(path, "rb") as f:
        f.seek(0, 2)
        size = f.tell()
        f.seek(size - 16)
        tail = f.read(16)
        footer_len = struct.unpack("<Q", tail[:8])[0]
        if tail[8:] != MAGIC:
            raise ValueError(f"{path}: bad trailing magic")
        f.seek(size - 16 - footer_len)
        meta = _parse_footer_block(f.read(footer_len), path)
        f.seek(0)
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad leading magic")
    return meta


def file_fetcher(path: str) -> Fetch:
    # keep the file object (not a raw fd) so GC closes it with the closure
    f = open(path, "rb")

    def fetch(offset: int, size: int) -> bytes:
        # positionless read: safe under concurrent fetches (no seek lock)
        return os.pread(f.fileno(), size, offset)

    return fetch


class TabFileReader:
    def __init__(self, path: str, fetch: Fetch | None = None):
        self.path = path
        self.meta = read_footer(path)
        self.fetch: Fetch = fetch if fetch is not None else file_fetcher(path)

    # -- planning ----------------------------------------------------------

    def plan_row_groups(self, predicate_stats=None,
                        row_groups: Sequence[int] | None = None
                        ) -> list[int]:
        """Row groups to scan; ``predicate_stats`` is an optional callable
        (col_name -> stats dict -> bool keep) enabling zone-map skipping."""
        idxs = list(range(len(self.meta.row_groups))) \
            if row_groups is None else list(row_groups)
        if predicate_stats is None:
            return idxs
        kept = []
        for i in idxs:
            rg = self.meta.row_groups[i]
            keep = True
            for chunk in rg.columns:
                if chunk.stats is not None and not predicate_stats(
                        chunk.name, chunk.stats):
                    keep = False
                    break
            if keep:
                kept.append(i)
        return kept

    # -- raw access (device scan path uses these) --------------------------

    def chunk_meta(self, rg_index: int, column: str) -> ChunkMeta:
        return self.meta.row_groups[rg_index].column(column)

    def read_chunk_bytes(self, chunk: ChunkMeta) -> bytes:
        off, size = chunk.byte_range
        return self.fetch(off, size)

    def chunk_pages(self, chunk: ChunkMeta, raw: bytes | None = None):
        """Yield (page_meta, decompressed_payload) for each data page;
        first element of the returned tuple list is the dict payload."""
        off0, _ = chunk.byte_range
        if raw is None:
            raw = self.read_chunk_bytes(chunk)
        codec = Codec(chunk.codec)

        def payload(pm):
            data = raw[pm.offset - off0:pm.offset - off0 + pm.stored_size]
            verify_page(data, pm, where=f"{chunk.name} page@{pm.offset}",
                        path=self.path)
            return decompress(data, codec, pm.uncompressed_size)

        dict_payload = payload(chunk.dict_page) if chunk.dict_page else None
        return dict_payload, [(pm, payload(pm)) for pm in chunk.pages]

    # -- host decode path ---------------------------------------------------

    def decode_chunk(self, chunk: ChunkMeta, field: Field,
                     raw: bytes | None = None):
        dict_payload, pages = self.chunk_pages(chunk, raw)
        encoding = Encoding(chunk.encoding)
        dictionary = None
        if dict_payload is not None:
            from repro.core.encodings import decode_plain_page
            dp = chunk.dict_page
            dictionary = decode_plain_page(dict_payload, dp.n_values, field,
                                           dp.extra)
        parts = [decode_page(encoding, payload, pm.n_values, field,
                             pm.extra, dictionary)
                 for pm, payload in pages]
        if isinstance(parts[0], StringColumn):
            if len(parts) == 1:
                return parts[0]
            lens = np.concatenate([p.lengths() for p in parts])
            offsets = np.zeros(lens.shape[0] + 1, dtype=np.int64)
            np.cumsum(lens, out=offsets[1:])
            return StringColumn(offsets,
                                np.concatenate([p.payload for p in parts]))
        return np.concatenate(parts)

    def read_table(self, columns: list[str] | None = None,
                   row_groups: Sequence[int] | None = None,
                   coalesce_gap: int = DEFAULT_COALESCE_GAP) -> Table:
        names = columns if columns is not None else self.meta.schema.names
        rgs = self.plan_row_groups(row_groups=row_groups)
        per_rg: list[Table] = []
        for i in rgs:
            rg = self.meta.row_groups[i]
            # coalesced fetch: adjacent chunk ranges merge into one read
            # (Insight 2), per-chunk views are sliced back zero-copy
            ranges = [rg.column(n).byte_range for n in names]
            raws = fetch_ranges(self.fetch, ranges, coalesce_gap)
            cols: dict[str, object] = {}
            for name, raw in zip(names, raws):
                field = self.meta.schema.field(name)
                cols[name] = self.decode_chunk(rg.column(name), field,
                                               raw=raw)
            from repro.core.schema import Schema
            per_rg.append(Table(cols, Schema(
                [self.meta.schema.field(n) for n in names])))
        return per_rg[0] if len(per_rg) == 1 else Table.concat(per_rg)
