"""Block codecs + the Insight-4 selective-compression gate.

Codecs:
  none     identity
  gzip     zlib/DEFLATE — the paper's host-ecosystem codec.  LZ77
           back-references are byte-serial and have no TPU analogue
           (DESIGN.md §9.1), so gzip pages are decompressed on the host
           before device upload — exactly the cost Insight 4 avoids paying
           when the codec does not actually shrink the chunk.
  cascade  TPU-native word-level codec (beyond-paper): uint32-word RLE with
           bit-transposed packed run values/counts.  Fully vectorizable;
           decoded on-device by kernels/cascade_decode.py.

Cascade frame layout (all 4-byte aligned):
  [0] n_words_orig  int32
  [1] n_runs        int32
  [2] value_width   int32
  [3] count_width   int32
  [4:4+vw]          packed run values (bit-transposed uint32 words)
  [...]             packed run counts
"""

from __future__ import annotations

import enum
import os
import zlib

import numpy as np

from repro.core import bitpack
from repro.core.lru import ByteCappedLRU

# -- accelerated inflate backend --------------------------------------------
#
# gzip inflate is the cold-pass host bottleneck for min_gain=0 files (the
# chunk memo only removes *revisit* inflation).  When an accelerated
# zlib-compatible library is present, prefer it for decompression:
# ISA-L's igzip is ~2-3x stdlib zlib on inflate, zlib-ng ~1.5-2x.  The
# deflate (write) path stays on stdlib zlib — its levels are what the
# Insight-4 gate was calibrated against, and write throughput is not the
# paper's axis.  Fallback is silent: the stdlib module is always correct.
try:
    from isal import isal_zlib as _inflate_zlib
    _INFLATE_BACKEND = "isal"
except ImportError:
    try:
        from zlib_ng import zlib_ng as _inflate_zlib
        _INFLATE_BACKEND = "zlib-ng"
    except ImportError:
        _inflate_zlib = zlib
        _INFLATE_BACKEND = "zlib"


def inflate_backend() -> str:
    """Name of the active gzip-inflate backend: ``isal`` (ISA-L igzip),
    ``zlib-ng``, or stdlib ``zlib``.  Logged in FetchStats/ScanMetrics so
    benchmark rows record which inflate path produced them."""
    return _INFLATE_BACKEND


# -- integrity ---------------------------------------------------------------
#
# The writer stamps a CRC32 of every page's *stored* (post-compression)
# bytes into PageMeta.extra["crc32"] and appends a footer CRC
# (metadata/writer).  The scan path verifies at the decompress boundary —
# before anything enters the arena, the dict cache, or the decompress
# memo — so a flipped byte surfaces as a typed ChecksumError instead of
# silently-wrong decoded values or a poisoned shared cache (DESIGN.md §6).
# Checking the stored bytes (not the inflated ones) keeps the check
# O(stored) and catches corruption whether it happened at rest or in
# transit; gzip's own trailing CRC is backend-dependent (isal/zlib-ng may
# differ in error type), so we never rely on it.


class ChecksumError(ValueError):
    """Stored bytes failed CRC32 verification.  Typed so the recovery
    layers can tell corruption (retryable once — a torn/short read looks
    identical to at-rest corruption until refetched) from logic errors.

    Attributes: ``path`` (when known), ``where`` (page/footer/manifest
    locator string), ``expected``, ``actual``."""

    def __init__(self, where: str, expected: int, actual: int,
                 path: str | None = None):
        self.where = where
        self.expected = expected
        self.actual = actual
        self.path = path
        loc = f"{path}: {where}" if path else where
        super().__init__(
            f"{loc}: crc32 mismatch (expected {expected:#010x}, "
            f"got {actual:#010x})")


def page_crc(data) -> int:
    """CRC32 over stored page bytes (the writer-side stamp)."""
    return zlib.crc32(bytes(data) if isinstance(data, memoryview) else data)


_VERIFY_CHECKSUMS = os.environ.get("REPRO_VERIFY_CHECKSUMS", "1") != "0"


def verify_checksums() -> bool:
    """Whether the scan path verifies page/footer CRCs (default on; the
    one knob — env ``REPRO_VERIFY_CHECKSUMS=0`` or set_verify_checksums)."""
    return _VERIFY_CHECKSUMS


def set_verify_checksums(enabled: bool) -> bool:
    """Flip verification; returns the previous value (for tests)."""
    global _VERIFY_CHECKSUMS
    prev = _VERIFY_CHECKSUMS
    _VERIFY_CHECKSUMS = bool(enabled)
    return prev


def verify_page(data, pm, *, where: str = "page",
                path: str | None = None) -> None:
    """Verify ``data`` (stored page bytes) against ``pm.extra["crc32"]``.

    No-op when verification is disabled or the page predates checksums
    (no ``crc32`` stamp — legacy files stay readable).  Raises
    ChecksumError on mismatch.  MUST be called before the bytes (or
    anything derived from them) are inserted into a shared cache."""
    if not _VERIFY_CHECKSUMS:
        return
    expected = pm.extra.get("crc32") if pm.extra else None
    if expected is None:
        return
    actual = page_crc(data)
    if actual != int(expected):
        raise ChecksumError(where, int(expected), actual, path=path)


class Codec(enum.IntEnum):
    NONE = 0
    GZIP = 2      # matches parquet.thrift CompressionCodec.GZIP
    CASCADE = 100  # TabFile extension


def _codec_of(name: str) -> Codec:
    return {"none": Codec.NONE, "gzip": Codec.GZIP,
            "cascade": Codec.CASCADE}[name]


def _name_of(codec: Codec) -> str:
    return {Codec.NONE: "none", Codec.GZIP: "gzip",
            Codec.CASCADE: "cascade"}[codec]


# ---------------------------------------------------------------------------
# cascade
# ---------------------------------------------------------------------------

def cascade_compress(data: bytes) -> bytes:
    pad = (-len(data)) % 4
    words = np.frombuffer(data + b"\x00" * pad, dtype=np.uint32)
    n = words.shape[0]
    if n == 0:
        header = np.array([0, 0, 1, 1], dtype=np.int32)
        return header.tobytes()
    change = np.flatnonzero(words[1:] != words[:-1]) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    run_vals = words[starts].astype(np.uint64)
    run_counts = (ends - starts).astype(np.uint64)
    vw = bitpack.bit_width(int(run_vals.max())) if run_vals.max() else 1
    cw = bitpack.bit_width(int(run_counts.max()))
    header = np.array([n, run_vals.shape[0], vw, cw], dtype=np.int32)
    return (header.tobytes()
            + bitpack.pack(run_vals, vw).tobytes()
            + bitpack.pack(run_counts, cw).tobytes())


def cascade_decompress(data: bytes, uncompressed_size: int) -> bytes:
    header = np.frombuffer(data, dtype=np.int32, count=4)
    n, n_runs, vw, cw = (int(x) for x in header)
    if n == 0:
        return b""
    off = 16
    nvw = bitpack.packed_words(n_runs, vw)
    vals = bitpack.unpack(
        np.frombuffer(data, dtype=np.uint32, count=nvw, offset=off), vw,
        n_runs, out_dtype=np.uint64)
    off += nvw * 4
    ncw = bitpack.packed_words(n_runs, cw)
    counts = bitpack.unpack(
        np.frombuffer(data, dtype=np.uint32, count=ncw, offset=off), cw,
        n_runs, out_dtype=np.uint64)
    words = np.repeat(vals.astype(np.uint32), counts.astype(np.int64))
    assert words.shape[0] == n
    return words.tobytes()[:uncompressed_size]


def cascade_manifest(data: bytes) -> dict:
    """Header pass for device decode: packed words + widths + counts."""
    header = np.frombuffer(data, dtype=np.int32, count=4)
    n, n_runs, vw, cw = (int(x) for x in header)
    off = 16
    nvw = bitpack.packed_words(n_runs, vw)
    val_words = np.frombuffer(data, dtype=np.uint32, count=nvw, offset=off)
    off += nvw * 4
    ncw = bitpack.packed_words(n_runs, cw)
    cnt_words = np.frombuffer(data, dtype=np.uint32, count=ncw, offset=off)
    return {"n_words": n, "n_runs": n_runs, "value_width": vw,
            "count_width": cw, "value_words": val_words.copy(),
            "count_words": cnt_words.copy()}


# ---------------------------------------------------------------------------
# chunk-level decompress memo
# ---------------------------------------------------------------------------

def _entry_bytes(payloads: dict) -> int:
    return sum(len(p) for p in payloads.values()
               if isinstance(p, (bytes, bytearray, memoryview)))


class DecompressMemo(ByteCappedLRU):
    """Byte-capped LRU of decompressed page payloads, one entry per column
    chunk (entries are dicts keyed by page index, plus ``"dict"``).

    gzip is the host-decompress bottleneck for min_gain=0 files (one zlib
    call per page, ~100 per chunk): when the query loop revisits a chunk —
    repeated Q6/Q12 over the same file, or a second scan in the same
    process — re-inflating identical bytes is pure waste.  The DecodePlan's
    decompress stage consults this memo keyed by
    ``(file token, column, chunk byte range)`` and stores the whole chunk's
    page payloads (data pages + dictionary page) as one entry, so a hit
    skips every zlib call for that chunk.

    Thread-safe: the pipeline executor's decode workers share it.
    """

    def __init__(self, max_bytes: int = 256 * 1024 * 1024):
        super().__init__(max_bytes, _entry_bytes)


_CHUNK_MEMO = DecompressMemo()


def chunk_decompress_memo() -> DecompressMemo:
    """The process-wide chunk decompress memo (see DecompressMemo)."""
    return _CHUNK_MEMO


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compress(data: bytes, codec: str, level: int = 1) -> bytes:
    c = _codec_of(codec)
    if c == Codec.NONE:
        return data
    if c == Codec.GZIP:
        return zlib.compress(data, level)
    return cascade_compress(data)


def decompress(data: bytes, codec: Codec, uncompressed_size: int) -> bytes:
    if codec == Codec.NONE:
        return data
    if codec == Codec.GZIP:
        out = _inflate_zlib.decompress(data)
        assert len(out) == uncompressed_size
        return out
    return cascade_decompress(data, uncompressed_size)


def maybe_compress_chunk(page_payloads, codec: str, min_gain: float,
                         level: int = 1) -> tuple[Codec, list, int, int]:
    """Insight 4: compress the chunk only if it actually pays.

    Returns (codec_used, payloads, uncompressed_total, stored_total).
    The decision is chunk-level (like Parquet's per-chunk codec) but each
    page is compressed independently so pages stay individually decodable.
    """
    uncomp = [len(p) for p in page_payloads]
    total_uncomp = sum(uncomp)
    if _codec_of(codec) == Codec.NONE or total_uncomp == 0:
        return Codec.NONE, list(page_payloads), total_uncomp, total_uncomp
    comp = [compress(p, codec, level) for p in page_payloads]
    total_comp = sum(len(p) for p in comp)
    gain = 1.0 - total_comp / total_uncomp
    if gain >= min_gain:
        return _codec_of(codec), comp, total_uncomp, total_comp
    return Codec.NONE, list(page_payloads), total_uncomp, total_uncomp
