"""Storage backends: real file I/O + the calibrated N-lane SSD model.

The container has no NVMe array, but SSD count is the x-axis of the paper's
Figures 2-3.  ``SimulatedStorage`` reads real bytes from the local file but
*accounts* time against an N-lane model calibrated to the paper's GDS
observations:

    request_time(lane) = latency + size / lane_bandwidth

so a request's achieved bandwidth is  bw · s/(s + latency·bw)  — small
(~100 KB) requests reach less than half of a lane while MiB-scale requests
saturate it (Insight 2).  Requests stripe across lanes; a batch completes
when its slowest lane drains.  Every benchmark labels which numbers come
from this model vs. real measurement (DESIGN.md §2).

Defaults: 7 GB/s per lane (PCIe4 NVMe, the paper's class of device), 20 µs
per-request latency on the accelerator DMA path.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class FetchStats:
    requests: int = 0
    bytes: int = 0
    seconds: float = 0.0     # simulated (sim backend) or measured (real)

    def add(self, other: "FetchStats") -> None:
        self.requests += other.requests
        self.bytes += other.bytes
        self.seconds += other.seconds

    @property
    def bandwidth(self) -> float:
        return self.bytes / max(1e-12, self.seconds)


class RealStorage:
    """Direct file reads with measured wall time."""

    kind = "real"

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        self._lock = threading.Lock()
        self.stats = FetchStats()

    def fetch(self, offset: int, size: int) -> bytes:
        t0 = time.perf_counter()
        with self._lock:
            self._f.seek(offset)
            data = self._f.read(size)
        dt = time.perf_counter() - t0
        self.stats.add(FetchStats(1, len(data), dt))
        return data

    def fetch_batch(self, requests: Sequence[Tuple[int, int]]
                    ) -> Tuple[List[bytes], float]:
        t0 = time.perf_counter()
        out = [self.fetch(o, s) for o, s in requests]
        return out, time.perf_counter() - t0


class SimulatedStorage:
    """N-lane SSD model over a real backing file.

    ``batch_seconds`` is the modeled completion time of a batch of requests
    issued together (per-RG in the scan engine): requests go to the
    least-loaded lane; the batch drains when the slowest lane finishes.
    """

    kind = "sim"

    def __init__(self, path: str, n_lanes: int = 1,
                 lane_bandwidth: float = 7e9, latency: float = 20e-6):
        self.path = path
        self.n_lanes = n_lanes
        self.lane_bandwidth = lane_bandwidth
        self.latency = latency
        self._f = open(path, "rb")
        self._lock = threading.Lock()
        self.stats = FetchStats()

    def _read(self, offset: int, size: int) -> bytes:
        with self._lock:
            self._f.seek(offset)
            return self._f.read(size)

    def request_seconds(self, size: int) -> float:
        return self.latency + size / self.lane_bandwidth

    def batch_seconds(self, sizes: Sequence[int]) -> float:
        lanes = [0.0] * self.n_lanes
        for s in sorted(sizes, reverse=True):  # LPT assignment
            i = min(range(self.n_lanes), key=lanes.__getitem__)
            lanes[i] += self.request_seconds(s)
        return max(lanes) if lanes else 0.0

    def fetch(self, offset: int, size: int) -> bytes:
        data = self._read(offset, size)
        self.stats.add(FetchStats(1, len(data), self.request_seconds(size)))
        return data

    def fetch_batch(self, requests: Sequence[Tuple[int, int]]
                    ) -> Tuple[List[bytes], float]:
        out = [self._read(o, s) for o, s in requests]
        dt = self.batch_seconds([s for _, s in requests])
        self.stats.add(FetchStats(len(requests),
                                  sum(len(d) for d in out), dt))
        return out, dt

    def effective_bandwidth(self, size: int) -> float:
        """bw · s/(s + latency·bw): the Insight-2 efficiency curve."""
        return size / self.request_seconds(size)


Storage = object  # duck-typed: RealStorage | SimulatedStorage


def open_storage(path: str, backend: str = "real", n_lanes: int = 1,
                 lane_bandwidth: float = 7e9,
                 latency: float = 20e-6):
    if backend == "real":
        return RealStorage(path)
    if backend == "sim":
        return SimulatedStorage(path, n_lanes=n_lanes,
                                lane_bandwidth=lane_bandwidth,
                                latency=latency)
    raise ValueError(backend)
