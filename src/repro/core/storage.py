"""Storage backends: real file I/O + the calibrated N-lane SSD model.

The container has no NVMe array, but SSD count is the x-axis of the paper's
Figures 2-3.  ``SimulatedStorage`` reads real bytes from the local file but
*accounts* time against an N-lane model calibrated to the paper's GDS
observations:

    request_time(lane) = latency + size / lane_bandwidth

so a request's achieved bandwidth is  bw · s/(s + latency·bw)  — small
(~100 KB) requests reach less than half of a lane while MiB-scale requests
saturate it (Insight 2).  Requests stripe across lanes; a batch completes
when its slowest lane drains.  Every benchmark labels which numbers come
from this model vs. real measurement (DESIGN.md §2).

Request **coalescing** (Insight 2's configuration-level fix): adjacent or
near-adjacent byte ranges — e.g. the column chunks of one row group, which
the writer lays out back to back — merge into one large read when the gap
between them is at most ``coalesce_gap`` bytes.  The gap bytes are read and
discarded; with 20 µs request latency at 7 GB/s a request is worth ~140 KB,
so the default 64 KiB gap always pays on the modeled lanes (and costs one
page-cache copy on the real backend).

Both backends read with ``os.pread`` on a shared fd — positionless reads
need no seek lock, so the overlapped reader's I/O thread never serializes
against the decode thread's dictionary fetches.

Defaults: 7 GB/s per lane (PCIe4 NVMe, the paper's class of device), 20 µs
per-request latency on the accelerator DMA path.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections.abc import Sequence

from repro.core.compression import inflate_backend

DEFAULT_COALESCE_GAP = 64 * 1024


@dataclasses.dataclass
class FetchStats:
    requests: int = 0        # requests actually issued (post-coalescing)
    bytes: int = 0
    seconds: float = 0.0     # simulated (sim backend) or measured (real)
    batches: int = 0         # fetch_batch calls (one per row group in scans)
    last_batch_requests: int = 0
    # informational: which gzip-inflate backend decompresses the fetched
    # chunks downstream (isal / zlib-ng / zlib — core/compression.py)
    inflate_backend: str = inflate_backend()

    def add(self, other: "FetchStats") -> None:
        self.requests += other.requests
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.batches += other.batches
        if other.batches:
            self.last_batch_requests = other.last_batch_requests

    @property
    def requests_per_batch(self) -> float:
        return self.requests / max(1, self.batches)

    @property
    def bandwidth(self) -> float:
        return self.bytes / max(1e-12, self.seconds)


def coalesce_ranges(ranges: Sequence[tuple[int, int]], gap: int
                    ) -> tuple[list[tuple[int, int]],
                               list[tuple[int, int]]]:
    """Merge byte ranges whose gaps are ≤ ``gap`` into large requests.

    Returns ``(merged, index)`` where ``merged`` is the ascending list of
    requests to issue and ``index[i] = (merged_idx, rel_off)`` locates input
    range ``i`` inside its merged request.
    """
    n = len(ranges)
    order = sorted(range(n), key=lambda i: ranges[i][0])
    merged: list[tuple[int, int]] = []
    index: list[tuple[int, int]] = [(0, 0)] * n
    for i in order:
        off, size = ranges[i]
        if merged:
            mo, ms = merged[-1]
            if mo <= off <= mo + ms + gap:
                merged[-1] = (mo, max(ms, off + size - mo))
                index[i] = (len(merged) - 1, off - mo)
                continue
        merged.append((off, size))
        index[i] = (len(merged) - 1, 0)
    return merged, index


def _slice_back(views: list[memoryview], index, ranges
                ) -> list[memoryview]:
    return [views[mi][rel:rel + size]
            for (mi, rel), (_, size) in zip(index, ranges)]


def fetch_coalesced(storage, ranges: Sequence[tuple[int, int]],
                    gap: int = DEFAULT_COALESCE_GAP
                    ) -> tuple[list[memoryview], float]:
    """Fetch ``ranges`` through ``storage`` as coalesced requests.

    Returns per-input-range zero-copy views into the merged buffers plus the
    batch time.  ``gap <= 0`` disables merging (every range is its own
    request) — the pre-coalescing baseline for benchmarks.
    """
    if gap <= 0:
        datas, dt = storage.fetch_batch(list(ranges))
        return [memoryview(d) for d in datas], dt
    merged, index = coalesce_ranges(ranges, gap)
    bufs, dt = storage.fetch_batch(merged)
    return _slice_back([memoryview(b) for b in bufs], index, ranges), dt


def fetch_ranges(fetch, ranges: Sequence[tuple[int, int]],
                 gap: int = DEFAULT_COALESCE_GAP) -> list[memoryview]:
    """Coalesced reads through a plain ``fetch(offset, size)`` callable
    (the reader's storage-agnostic path; no batch timing)."""
    if gap <= 0:
        return [memoryview(fetch(o, s)) for o, s in ranges]
    merged, index = coalesce_ranges(ranges, gap)
    views = [memoryview(fetch(o, s)) for o, s in merged]
    return _slice_back(views, index, ranges)


class RealStorage:
    """Direct file reads with measured wall time.

    Reads use ``os.pread`` so concurrent fetches (the overlapped reader's
    I/O thread alongside the decode thread) don't serialize on a shared
    file-position lock.
    """

    kind = "real"

    def __init__(self, path: str):
        self.path = path
        self._fd = os.open(path, os.O_RDONLY)
        self.stats = FetchStats()
        # the pipeline executor's fetch thread and decode workers may issue
        # concurrent reads; stats mutation is the only shared state
        self._stats_lock = threading.Lock()

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def fetch(self, offset: int, size: int) -> bytes:
        t0 = time.perf_counter()
        data = os.pread(self._fd, size, offset)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.add(FetchStats(1, len(data), dt))
        return data

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        t0 = time.perf_counter()
        out = [os.pread(self._fd, s, o) for o, s in requests]
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.add(FetchStats(len(requests),
                                      sum(len(d) for d in out), dt,
                                      batches=1,
                                      last_batch_requests=len(requests)))
        return out, dt


class SimulatedStorage:
    """N-lane SSD model over a real backing file.

    ``batch_seconds`` is the modeled completion time of a batch of requests
    issued together (per-RG in the scan engine): requests go to the
    least-loaded lane; the batch drains when the slowest lane finishes.
    """

    kind = "sim"

    def __init__(self, path: str, n_lanes: int = 1,
                 lane_bandwidth: float = 7e9, latency: float = 20e-6):
        self.path = path
        self.n_lanes = n_lanes
        self.lane_bandwidth = lane_bandwidth
        self.latency = latency
        self._fd = os.open(path, os.O_RDONLY)
        self.stats = FetchStats()
        self._stats_lock = threading.Lock()

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def _read(self, offset: int, size: int) -> bytes:
        return os.pread(self._fd, size, offset)

    def request_seconds(self, size: int) -> float:
        return self.latency + size / self.lane_bandwidth

    def batch_seconds(self, sizes: Sequence[int]) -> float:
        lanes = [0.0] * self.n_lanes
        for s in sorted(sizes, reverse=True):  # LPT assignment
            i = min(range(self.n_lanes), key=lanes.__getitem__)
            lanes[i] += self.request_seconds(s)
        return max(lanes) if lanes else 0.0

    def fetch(self, offset: int, size: int) -> bytes:
        data = self._read(offset, size)
        with self._stats_lock:
            self.stats.add(FetchStats(1, len(data),
                                      self.request_seconds(size)))
        return data

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        out = [self._read(o, s) for o, s in requests]
        dt = self.batch_seconds([s for _, s in requests])
        with self._stats_lock:
            self.stats.add(FetchStats(len(requests),
                                      sum(len(d) for d in out), dt,
                                      batches=1,
                                      last_batch_requests=len(requests)))
        return out, dt

    def effective_bandwidth(self, size: int) -> float:
        """bw · s/(s + latency·bw): the Insight-2 efficiency curve."""
        return size / self.request_seconds(size)


Storage = object  # duck-typed: RealStorage | SimulatedStorage


# ---------------------------------------------------------------------------
# bounded retry with exponential backoff + jitter and per-request timeouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How storage reads recover from transient faults (DESIGN.md §6).

    ``attempts`` is the total try count (1 = no retry).  Backoff is
    exponential from ``base_delay`` capped at ``max_delay``, with
    *deterministic* jitter — a hash of (attempt, offset) — so fault-replay
    tests see identical schedules.  ``timeout`` is a per-request budget:
    ``os.pread`` cannot be interrupted mid-call, so the check is post-hoc
    (a request that came back over budget counts as a timeout and is
    retried/raised) — it bounds how long a latency spike's bytes are
    trusted, which is the recoverable failure this layer owns; whole-scan
    budgets are the scheduler's deadline (core/scheduler.py)."""

    attempts: int = 3
    base_delay: float = 0.001
    max_delay: float = 0.050
    jitter: float = 0.5
    timeout: float | None = None

    def delay(self, attempt: int, salt: int = 0) -> float:
        import zlib
        import struct as _struct
        base = min(self.max_delay, self.base_delay * (2 ** attempt))
        u = zlib.crc32(_struct.pack("<qq", attempt, salt)) / 2**32
        return base * (1.0 + self.jitter * u)


#: retries on by default: 3 tries heal any single-shot transient fault
DEFAULT_RETRY_POLICY = RetryPolicy()

NO_RETRY = RetryPolicy(attempts=1)


@dataclasses.dataclass
class RetryStats:
    retries: int = 0      # extra attempts actually spent
    timeouts: int = 0     # requests that exceeded the per-request budget
    short_reads: int = 0  # truncated reads detected (then retried)


class RetryingStorage:
    """Bounded-retry wrapper over any storage backend.

    ``fetch`` retries retryable failures (core/faults.py taxonomy) and
    validates length — a short read is retried like an I/O error, never
    returned.  ``fetch_batch`` tries the batch once; on any failure it
    degrades to per-request retried fetches, so one bad request costs one
    batch-shaped region its coalescing, not the scan its life.  Counters
    land in ``retry_stats`` (ScanMetrics picks them up); everything else
    delegates to the wrapped backend."""

    def __init__(self, inner, policy: RetryPolicy | None = None):
        self.inner = inner
        self.policy = policy if policy is not None else DEFAULT_RETRY_POLICY
        self.retry_stats = RetryStats()
        self._retry_lock = threading.Lock()

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def close(self) -> None:
        self.inner.close()

    def _note(self, **deltas) -> None:
        with self._retry_lock:
            for k, v in deltas.items():
                setattr(self.retry_stats, k,
                        getattr(self.retry_stats, k) + v)

    def _fetch_once(self, offset: int, size: int) -> bytes:
        from repro.core.faults import FetchTimeout, ShortReadError
        t0 = time.perf_counter()
        data = self.inner.fetch(offset, size)
        elapsed = time.perf_counter() - t0
        if (self.policy.timeout is not None
                and elapsed > self.policy.timeout):
            self._note(timeouts=1)
            raise FetchTimeout(offset, size, elapsed, self.policy.timeout)
        if len(data) < size:
            self._note(short_reads=1)
            raise ShortReadError(offset, size, len(data))
        return data

    def fetch(self, offset: int, size: int) -> bytes:
        from repro.core.faults import is_retryable
        last: BaseException | None = None
        for attempt in range(max(1, self.policy.attempts)):
            if attempt:
                self._note(retries=1)
                time.sleep(self.policy.delay(attempt - 1, offset))
            try:
                return self._fetch_once(offset, size)
            except BaseException as e:  # noqa: BLE001 — reclassified below
                if not is_retryable(e):
                    raise
                last = e
        raise last

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        from repro.core.faults import is_retryable
        try:
            datas, dt = self.inner.fetch_batch(list(requests))
            if all(len(d) == s for d, (_, s) in zip(datas, requests)):
                return datas, dt
            self._note(short_reads=1)
        except BaseException as e:  # noqa: BLE001 — reclassified below
            if not is_retryable(e):
                raise
        # degraded path: per-request retried fetches (wall-measured — the
        # modeled batch time does not apply to a fault-recovery replay).
        # The replay is itself one retry of the batch-shaped region, even
        # when every per-request fetch then succeeds first try.
        self._note(retries=1)
        t0 = time.perf_counter()
        out = [self.fetch(o, s) for o, s in requests]
        return out, time.perf_counter() - t0


def open_storage(path: str, backend: str = "real", n_lanes: int = 1,
                 lane_bandwidth: float = 7e9,
                 latency: float = 20e-6):
    if backend == "real":
        return RealStorage(path)
    if backend == "sim":
        return SimulatedStorage(path, n_lanes=n_lanes,
                                lane_bandwidth=lane_bandwidth,
                                latency=latency)
    raise ValueError(backend)
