"""Storage backends: real file I/O + the calibrated N-lane SSD model.

The container has no NVMe array, but SSD count is the x-axis of the paper's
Figures 2-3.  ``SimulatedStorage`` reads real bytes from the local file but
*accounts* time against an N-lane model calibrated to the paper's GDS
observations:

    request_time(lane) = latency + size / lane_bandwidth

so a request's achieved bandwidth is  bw · s/(s + latency·bw)  — small
(~100 KB) requests reach less than half of a lane while MiB-scale requests
saturate it (Insight 2).  Requests stripe across lanes; a batch completes
when its slowest lane drains.  Every benchmark labels which numbers come
from this model vs. real measurement (DESIGN.md §2).

Request **coalescing** (Insight 2's configuration-level fix): adjacent or
near-adjacent byte ranges — e.g. the column chunks of one row group, which
the writer lays out back to back — merge into one large read when the gap
between them is at most ``coalesce_gap`` bytes.  The gap bytes are read and
discarded; with 20 µs request latency at 7 GB/s a request is worth ~140 KB,
so the default 64 KiB gap always pays on the modeled lanes (and costs one
page-cache copy on the real backend).

Both backends read with ``os.pread`` on a shared fd — positionless reads
need no seek lock, so the overlapped reader's I/O thread never serializes
against the decode thread's dictionary fetches.

Defaults: 7 GB/s per lane (PCIe4 NVMe, the paper's class of device), 20 µs
per-request latency on the accelerator DMA path.

**Object store** (``ObjectStoreStorage``): the remote profile next to the
NVMe model — per-request latency in the milliseconds (first-byte on an
S3-class store), a few parallel connections at ~GB/s each, and a much
larger default coalesce gap (at 8 ms × 1.2 GB/s a request is worth
~10 MB, so multi-MiB gap bytes are cheaper than a second round trip).
Unlike the NVMe model it *sleeps* its modeled request time by default:
remote latency is real wall time in production, so overlapping it
(fetch_threads > 1, prefetch, multi-device sharding) must show up in
measured wall, not only in the modeled schedule.

**Prefetch** (``PrefetchingStorage``): wraps a modeled backend with a
small background pool.  ``prefetch(ranges)`` issues reads ahead of
demand; a later ``fetch``/``fetch_batch`` for the same (offset, size)
consumes the buffered bytes and pays only the *residual* wait — the
portion of the modeled request time not yet elapsed — so remote latency
hides behind decode.  Hit/miss/hidden/stall counters land in
``prefetch_stats``; consumed prefetches account into the inner backend's
FetchStats at consumption time, so request counts stay deterministic for
the CI gate regardless of background-thread timing.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections.abc import Sequence

from repro.core import trace
from repro.core.compression import inflate_backend

DEFAULT_COALESCE_GAP = 64 * 1024

# object-store profile defaults: ms-scale first-byte latency, a few
# parallel connections, multi-MiB coalescing (see module docstring)
DEFAULT_OBJECT_LATENCY = 8e-3
DEFAULT_OBJECT_BANDWIDTH = 1.2e9
DEFAULT_OBJECT_CONNECTIONS = 4
DEFAULT_OBJECT_COALESCE_GAP = 4 * 1024 * 1024

#: per-request latency samples kept per FetchStats (bounded so a long
#: scan's observability never grows without bound)
LATENCY_SAMPLE_CAP = 4096


@dataclasses.dataclass
class FetchStats:
    requests: int = 0        # requests actually issued (post-coalescing)
    bytes: int = 0
    seconds: float = 0.0     # simulated (sim backend) or measured (real)
    batches: int = 0         # fetch_batch calls (one per row group in scans)
    last_batch_requests: int = 0
    # informational: which gzip-inflate backend decompresses the fetched
    # chunks downstream (isal / zlib-ng / zlib — core/compression.py)
    inflate_backend: str = inflate_backend()
    # per-request latency samples (modeled on sim/object, measured on
    # real) — the p50/p95 observability columns; bounded reservoir
    latencies: list = dataclasses.field(default_factory=list)

    def add(self, other: "FetchStats") -> None:
        self.requests += other.requests
        self.bytes += other.bytes
        self.seconds += other.seconds
        self.batches += other.batches
        if other.batches:
            self.last_batch_requests = other.last_batch_requests
        if other.latencies:
            room = LATENCY_SAMPLE_CAP - len(self.latencies)
            if room > 0:
                self.latencies.extend(other.latencies[:room])

    @property
    def requests_per_batch(self) -> float:
        return self.requests / max(1, self.batches)

    @property
    def bandwidth(self) -> float:
        return self.bytes / max(1e-12, self.seconds)

    def latency_us(self, q: float) -> float:
        """Per-request latency percentile in microseconds (0 when no
        samples were recorded)."""
        if not self.latencies:
            return 0.0
        import numpy as _np
        return float(_np.percentile(self.latencies, q)) * 1e6


def coalesce_ranges(ranges: Sequence[tuple[int, int]], gap: int
                    ) -> tuple[list[tuple[int, int]],
                               list[tuple[int, int]]]:
    """Merge byte ranges whose gaps are ≤ ``gap`` into large requests.

    Returns ``(merged, index)`` where ``merged`` is the ascending list of
    requests to issue and ``index[i] = (merged_idx, rel_off)`` locates input
    range ``i`` inside its merged request.
    """
    n = len(ranges)
    order = sorted(range(n), key=lambda i: ranges[i][0])
    merged: list[tuple[int, int]] = []
    index: list[tuple[int, int]] = [(0, 0)] * n
    for i in order:
        off, size = ranges[i]
        if merged:
            mo, ms = merged[-1]
            if mo <= off <= mo + ms + gap:
                merged[-1] = (mo, max(ms, off + size - mo))
                index[i] = (len(merged) - 1, off - mo)
                continue
        merged.append((off, size))
        index[i] = (len(merged) - 1, 0)
    return merged, index


def _slice_back(views: list[memoryview], index, ranges
                ) -> list[memoryview]:
    return [views[mi][rel:rel + size]
            for (mi, rel), (_, size) in zip(index, ranges)]


def fetch_coalesced(storage, ranges: Sequence[tuple[int, int]],
                    gap: int = DEFAULT_COALESCE_GAP
                    ) -> tuple[list[memoryview], float]:
    """Fetch ``ranges`` through ``storage`` as coalesced requests.

    Returns per-input-range zero-copy views into the merged buffers plus the
    batch time.  ``gap <= 0`` disables merging (every range is its own
    request) — the pre-coalescing baseline for benchmarks.
    """
    if gap <= 0:
        datas, dt = storage.fetch_batch(list(ranges))
        return [memoryview(d) for d in datas], dt
    merged, index = coalesce_ranges(ranges, gap)
    bufs, dt = storage.fetch_batch(merged)
    return _slice_back([memoryview(b) for b in bufs], index, ranges), dt


def fetch_ranges(fetch, ranges: Sequence[tuple[int, int]],
                 gap: int = DEFAULT_COALESCE_GAP) -> list[memoryview]:
    """Coalesced reads through a plain ``fetch(offset, size)`` callable
    (the reader's storage-agnostic path; no batch timing)."""
    if gap <= 0:
        return [memoryview(fetch(o, s)) for o, s in ranges]
    merged, index = coalesce_ranges(ranges, gap)
    views = [memoryview(fetch(o, s)) for o, s in merged]
    return _slice_back(views, index, ranges)


class RealStorage:
    """Direct file reads with measured wall time.

    Reads use ``os.pread`` so concurrent fetches (the overlapped reader's
    I/O thread alongside the decode thread) don't serialize on a shared
    file-position lock.
    """

    kind = "real"

    def __init__(self, path: str):
        self.path = path
        self._fd = os.open(path, os.O_RDONLY)
        self.stats = FetchStats()
        # the pipeline executor's fetch thread and decode workers may issue
        # concurrent reads; stats mutation is the only shared state
        self._stats_lock = threading.Lock()

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def _read(self, offset: int, size: int) -> bytes:
        return os.pread(self._fd, size, offset)

    def fetch(self, offset: int, size: int) -> bytes:
        t0 = time.perf_counter()
        data = os.pread(self._fd, size, offset)
        t1 = time.perf_counter()
        dt = t1 - t0
        with self._stats_lock:
            self.stats.add(FetchStats(1, len(data), dt, latencies=[dt]))
        tr = trace.active()
        if tr is not None:
            tr.complete("storage_read", "io", t0, t1, backend=self.kind,
                        offset=offset, bytes=len(data), n=1)
        return data

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        t0 = time.perf_counter()
        out = []
        lats = []
        for o, s in requests:
            t_r = time.perf_counter()
            out.append(os.pread(self._fd, s, o))
            lats.append(time.perf_counter() - t_r)
        t1 = time.perf_counter()
        dt = t1 - t0
        with self._stats_lock:
            self.stats.add(FetchStats(len(requests),
                                      sum(len(d) for d in out), dt,
                                      batches=1,
                                      last_batch_requests=len(requests),
                                      latencies=lats))
        tr = trace.active()
        if tr is not None:
            tr.complete("storage_read", "io", t0, t1, backend=self.kind,
                        bytes=sum(len(d) for d in out), n=len(requests))
        return out, dt


class SimulatedStorage:
    """N-lane SSD model over a real backing file.

    ``batch_seconds`` is the modeled completion time of a batch of requests
    issued together (per-RG in the scan engine): requests go to the
    least-loaded lane; the batch drains when the slowest lane finishes.
    """

    kind = "sim"

    def __init__(self, path: str, n_lanes: int = 1,
                 lane_bandwidth: float = 7e9, latency: float = 20e-6):
        self.path = path
        self.n_lanes = n_lanes
        self.lane_bandwidth = lane_bandwidth
        self.latency = latency
        self._fd = os.open(path, os.O_RDONLY)
        self.stats = FetchStats()
        self._stats_lock = threading.Lock()

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def _read(self, offset: int, size: int) -> bytes:
        return os.pread(self._fd, size, offset)

    def request_seconds(self, size: int) -> float:
        return self.latency + size / self.lane_bandwidth

    def batch_seconds(self, sizes: Sequence[int]) -> float:
        lanes = [0.0] * self.n_lanes
        for s in sorted(sizes, reverse=True):  # LPT assignment
            i = min(range(self.n_lanes), key=lanes.__getitem__)
            lanes[i] += self.request_seconds(s)
        return max(lanes) if lanes else 0.0

    def fetch(self, offset: int, size: int) -> bytes:
        tr = trace.active()
        t0 = time.perf_counter() if tr is not None else 0.0
        data = self._read(offset, size)
        dt = self.request_seconds(size)
        self._account(dt)
        with self._stats_lock:
            self.stats.add(FetchStats(1, len(data), dt, latencies=[dt]))
        if tr is not None:
            tr.complete("storage_read", "io", t0, time.perf_counter(),
                        backend=self.kind, offset=offset,
                        bytes=len(data), n=1, modeled_dt=dt)
        return data

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        tr = trace.active()
        t0 = time.perf_counter() if tr is not None else 0.0
        out = [self._read(o, s) for o, s in requests]
        dt = self.batch_seconds([s for _, s in requests])
        self._account(dt)
        with self._stats_lock:
            self.stats.add(FetchStats(
                len(requests), sum(len(d) for d in out), dt,
                batches=1, last_batch_requests=len(requests),
                latencies=[self.request_seconds(s) for _, s in requests]))
        if tr is not None:
            tr.complete("storage_read", "io", t0, time.perf_counter(),
                        backend=self.kind, bytes=sum(len(d) for d in out),
                        n=len(requests), modeled_dt=dt)
        return out, dt

    def _account(self, modeled_seconds: float) -> None:
        """Hook: the NVMe model only *accounts* modeled time (wall stays
        real); the object-store profile overrides this to sleep it."""

    def effective_bandwidth(self, size: int) -> float:
        """bw · s/(s + latency·bw): the Insight-2 efficiency curve."""
        return size / self.request_seconds(size)


class ObjectStoreStorage(SimulatedStorage):
    """High-latency object-store profile (S3-class remote reads).

    Same N-lane accounting as ``SimulatedStorage`` — ``connections``
    parallel HTTP-range streams at ``connection_bandwidth`` each, with
    millisecond first-byte ``latency`` — but by default the modeled
    request time is also *slept*, so hiding remote latency (prefetch,
    fetch_threads > 1, multi-device sharding) shows up in measured wall
    time, not only in the modeled schedule.  Pair with the much larger
    ``DEFAULT_OBJECT_COALESCE_GAP``: at 8 ms × 1.2 GB/s a request is
    worth ~10 MB, so multi-MiB gap bytes beat a second round trip.
    """

    kind = "object"

    def __init__(self, path: str,
                 connections: int = DEFAULT_OBJECT_CONNECTIONS,
                 connection_bandwidth: float = DEFAULT_OBJECT_BANDWIDTH,
                 latency: float = DEFAULT_OBJECT_LATENCY,
                 sleep: bool = True):
        super().__init__(path, n_lanes=connections,
                         lane_bandwidth=connection_bandwidth,
                         latency=latency)
        self.sleep = sleep

    @property
    def connections(self) -> int:
        return self.n_lanes

    def _account(self, modeled_seconds: float) -> None:
        if self.sleep and modeled_seconds > 0:
            time.sleep(modeled_seconds)


Storage = object  # duck-typed: RealStorage | SimulatedStorage


# ---------------------------------------------------------------------------
# background prefetch: hide remote latency behind decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PrefetchStats:
    hits: int = 0             # demand requests served from the buffer
    misses: int = 0           # demand requests that went to the backend
    hidden_seconds: float = 0.0  # modeled request time already elapsed at hit
    stall_seconds: float = 0.0   # residual wait actually paid at hit


class _PrefetchEntry:
    __slots__ = ("offset", "size", "event", "data", "issue_t",
                 "modeled_dt", "error")

    def __init__(self, offset: int, size: int):
        self.offset = offset
        self.size = size
        self.event = threading.Event()
        self.data: bytes | None = None
        self.issue_t = 0.0
        self.modeled_dt = 0.0
        self.error: BaseException | None = None


class PrefetchingStorage:
    """Background-prefetch wrapper over any storage backend.

    ``prefetch(ranges)`` issues reads ahead of demand on a small daemon
    pool; a later ``fetch``/``fetch_batch`` for the *same* (offset, size)
    consumes the buffered bytes and pays only the residual of the modeled
    request time — the part not yet elapsed since issue — so remote
    latency overlaps with whatever the caller did in between (decode).

    Determinism: background reads go through the raw ``_read`` path and
    account **nothing**; the inner backend's FetchStats are charged at
    consumption time with the same request counts and modeled seconds the
    un-prefetched demand path would have charged.  The CI-gated
    ``io_requests`` counter is therefore independent of background-thread
    timing.  Entries are single-use and keyed by exact (offset, size) —
    the scan path always re-derives the same coalesced ranges, so
    lookahead issued with the same gap always hits.
    """

    def __init__(self, inner, threads: int = 2,
                 max_buffer_bytes: int = 256 * 1024 * 1024):
        self.inner = inner
        self.threads = max(1, threads)
        self.max_buffer_bytes = max_buffer_bytes
        self.prefetch_stats = PrefetchStats()
        self._buf: dict[tuple[int, int], _PrefetchEntry] = {}
        self._buf_bytes = 0
        self._lock = threading.Lock()
        self._queue: list[_PrefetchEntry] = []
        self._queue_cv = threading.Condition(self._lock)
        self._pool: list[threading.Thread] = []
        self._closed = False
        self._sleeps = bool(getattr(inner, "sleep", False))

    # -- wrapper plumbing ---------------------------------------------------

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            for e in self._queue:
                e.error = RuntimeError("storage closed")
                e.event.set()
            self._queue.clear()
            self._queue_cv.notify_all()
        self.inner.close()

    # -- background pool ----------------------------------------------------

    def _ensure_pool_locked(self) -> None:
        while len(self._pool) < self.threads:
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"prefetch-{len(self._pool)}")
            self._pool.append(t)
            t.start()

    def _worker_loop(self) -> None:
        while True:
            with self._queue_cv:
                while not self._queue and not self._closed:
                    self._queue_cv.wait()
                if self._closed:
                    return
                entry = self._queue.pop(0)
            entry.issue_t = time.perf_counter()
            try:
                data = self.inner._read(entry.offset, entry.size)
                rs = getattr(self.inner, "request_seconds", None)
                entry.modeled_dt = (rs(entry.size) if rs is not None
                                    else time.perf_counter() - entry.issue_t)
                entry.data = data
            except BaseException as e:  # noqa: BLE001 — surfaced at consume
                entry.error = e
            entry.event.set()

    # -- issue --------------------------------------------------------------

    def prefetch(self, requests: Sequence[tuple[int, int]]) -> int:
        """Queue background reads for ``requests``; returns how many were
        accepted (duplicates and over-budget ranges are skipped)."""
        accepted = 0
        with self._queue_cv:
            if self._closed:
                return 0
            for off, size in requests:
                key = (off, size)
                if key in self._buf:
                    continue
                if self._buf_bytes + size > self.max_buffer_bytes:
                    continue
                entry = _PrefetchEntry(off, size)
                self._buf[key] = entry
                self._buf_bytes += size
                self._queue.append(entry)
                accepted += 1
            if accepted:
                self._ensure_pool_locked()
                self._queue_cv.notify_all()
        if accepted:
            tr = trace.active()
            if tr is not None:
                tr.instant("prefetch_issue", "io", n=accepted)
            trace.registry().counter_inc("storage.prefetch_issued",
                                         accepted)
        return accepted

    # -- consume ------------------------------------------------------------

    def _take(self, key: tuple[int, int]) -> _PrefetchEntry | None:
        with self._lock:
            entry = self._buf.pop(key, None)
            if entry is not None:
                self._buf_bytes -= entry.size
            return entry

    def _residual(self, entry: _PrefetchEntry) -> float:
        """Wait for the background read, then return the unexpired part of
        its modeled request time (0 when decode fully hid it)."""
        entry.event.wait()
        if entry.error is not None:
            return -1.0
        return max(0.0, entry.issue_t + entry.modeled_dt
                   - time.perf_counter())

    def _note(self, **deltas) -> None:
        with self._lock:
            for k, v in deltas.items():
                setattr(self.prefetch_stats, k,
                        getattr(self.prefetch_stats, k) + v)

    def fetch(self, offset: int, size: int) -> bytes:
        entry = self._take((offset, size))
        if entry is not None:
            residual = self._residual(entry)
            if residual >= 0.0:
                if self._sleeps and residual > 0:
                    time.sleep(residual)
                self._note(hits=1,
                           hidden_seconds=entry.modeled_dt - residual,
                           stall_seconds=residual)
                with self.inner._stats_lock:
                    self.inner.stats.add(FetchStats(
                        1, len(entry.data), entry.modeled_dt,
                        latencies=[entry.modeled_dt]))
                tr = trace.active()
                if tr is not None:
                    tr.instant("prefetch_hit", "io", offset=offset,
                               hidden=entry.modeled_dt - residual,
                               stall=residual)
                return entry.data
        self._note(misses=1)
        tr = trace.active()
        if tr is not None:
            tr.instant("prefetch_miss", "io", offset=offset)
        return self.inner.fetch(offset, size)

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        requests = list(requests)
        t0 = time.perf_counter()
        out: list[bytes | None] = [None] * len(requests)
        hit_entries: list[_PrefetchEntry] = []
        miss_idx: list[int] = []
        max_residual = 0.0
        for i, (off, size) in enumerate(requests):
            entry = self._take((off, size))
            residual = -1.0 if entry is None else self._residual(entry)
            if residual < 0.0:
                miss_idx.append(i)
                continue
            out[i] = entry.data
            hit_entries.append(entry)
            max_residual = max(max_residual, residual)
            self._note(hits=1,
                       hidden_seconds=entry.modeled_dt - residual,
                       stall_seconds=residual)
        if miss_idx:
            self._note(misses=len(miss_idx))
            datas, _ = self.inner.fetch_batch(
                [requests[i] for i in miss_idx])
            for i, d in zip(miss_idx, datas):
                out[i] = d
        if hit_entries:
            # hit requests ran concurrently in the background → one
            # residual wait covers them all (minus wall already spent on
            # the demand-path misses above)
            if self._sleeps:
                remaining = max_residual - (time.perf_counter() - t0)
                if remaining > 0:
                    time.sleep(remaining)
            bs = getattr(self.inner, "batch_seconds", None)
            sizes = [e.size for e in hit_entries]
            dt_hit = (bs(sizes) if bs is not None
                      else sum(e.modeled_dt for e in hit_entries))
            with self.inner._stats_lock:
                self.inner.stats.add(FetchStats(
                    len(hit_entries), sum(len(e.data) for e in hit_entries),
                    dt_hit,
                    batches=0 if miss_idx else 1,
                    last_batch_requests=0 if miss_idx else len(requests),
                    latencies=[e.modeled_dt for e in hit_entries]))
        tr = trace.active()
        if tr is not None and (hit_entries or miss_idx):
            tr.instant("prefetch_hit" if hit_entries else "prefetch_miss",
                       "io", hits=len(hit_entries), misses=len(miss_idx),
                       stall=max_residual)
        return out, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# bounded retry with exponential backoff + jitter and per-request timeouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How storage reads recover from transient faults (DESIGN.md §6).

    ``attempts`` is the total try count (1 = no retry).  Backoff is
    exponential from ``base_delay`` capped at ``max_delay``, with
    *deterministic* jitter — a hash of (attempt, offset) — so fault-replay
    tests see identical schedules.  ``timeout`` is a per-request budget:
    ``os.pread`` cannot be interrupted mid-call, so the check is post-hoc
    (a request that came back over budget counts as a timeout and is
    retried/raised) — it bounds how long a latency spike's bytes are
    trusted, which is the recoverable failure this layer owns; whole-scan
    budgets are the scheduler's deadline (core/scheduler.py).

    ``name`` identifies the policy in traces and ScanMetrics
    (``retry_policy`` column) — "nvme" for the local default, "object"
    for the remote profile (``backend_retry_policy``)."""

    attempts: int = 3
    base_delay: float = 0.001
    max_delay: float = 0.050
    jitter: float = 0.5
    timeout: float | None = None
    name: str = "nvme"

    def delay(self, attempt: int, salt: int = 0) -> float:
        import zlib
        import struct as _struct
        base = min(self.max_delay, self.base_delay * (2 ** attempt))
        u = zlib.crc32(_struct.pack("<qq", attempt, salt)) / 2**32
        return base * (1.0 + self.jitter * u)


#: retries on by default: 3 tries heal any single-shot transient fault
DEFAULT_RETRY_POLICY = RetryPolicy()

#: remote profile (PR 8 carried follow-up): an object store's transient
#: window is seconds, not microseconds — more attempts, backoff starting
#: above the 8 ms first-byte latency (a faster retry just queues behind
#: the same congested connection), and a per-request deadline generous
#: enough for a slept multi-MiB coalesced read at 1.2 GB/s + spikes
OBJECT_RETRY_POLICY = RetryPolicy(attempts=5, base_delay=0.025,
                                  max_delay=1.0, timeout=10.0,
                                  name="object")

NO_RETRY = RetryPolicy(attempts=1, name="none")


def backend_retry_policy(backend: str) -> RetryPolicy:
    """Per-backend default RetryPolicy, the recovery sibling of
    ``backend_io_defaults``: the NVMe policy for real/sim, the
    longer-backoff/deadline remote policy for object."""
    if backend == "object":
        return OBJECT_RETRY_POLICY
    return DEFAULT_RETRY_POLICY


@dataclasses.dataclass
class RetryStats:
    retries: int = 0      # extra attempts actually spent
    timeouts: int = 0     # requests that exceeded the per-request budget
    short_reads: int = 0  # truncated reads detected (then retried)


class RetryingStorage:
    """Bounded-retry wrapper over any storage backend.

    ``fetch`` retries retryable failures (core/faults.py taxonomy) and
    validates length — a short read is retried like an I/O error, never
    returned.  ``fetch_batch`` tries the batch once; on any failure it
    degrades to per-request retried fetches, so one bad request costs one
    batch-shaped region its coalescing, not the scan its life.  Counters
    land in ``retry_stats`` (ScanMetrics picks them up); everything else
    delegates to the wrapped backend."""

    def __init__(self, inner, policy: RetryPolicy | None = None):
        self.inner = inner
        self.policy = policy if policy is not None else DEFAULT_RETRY_POLICY
        self.retry_stats = RetryStats()
        self._retry_lock = threading.Lock()

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def close(self) -> None:
        self.inner.close()

    def _note(self, **deltas) -> None:
        with self._retry_lock:
            for k, v in deltas.items():
                setattr(self.retry_stats, k,
                        getattr(self.retry_stats, k) + v)

    def _fetch_once(self, offset: int, size: int) -> bytes:
        from repro.core.faults import FetchTimeout, ShortReadError
        t0 = time.perf_counter()
        data = self.inner.fetch(offset, size)
        elapsed = time.perf_counter() - t0
        if (self.policy.timeout is not None
                and elapsed > self.policy.timeout):
            self._note(timeouts=1)
            tr = trace.active()
            if tr is not None:
                tr.instant("fetch_timeout", "fault", offset=offset,
                           elapsed=elapsed, budget=self.policy.timeout)
            raise FetchTimeout(offset, size, elapsed, self.policy.timeout)
        if len(data) < size:
            self._note(short_reads=1)
            tr = trace.active()
            if tr is not None:
                tr.instant("short_read", "fault", offset=offset,
                           want=size, got=len(data))
            raise ShortReadError(offset, size, len(data))
        return data

    def fetch(self, offset: int, size: int) -> bytes:
        from repro.core.faults import is_retryable
        last: BaseException | None = None
        for attempt in range(max(1, self.policy.attempts)):
            if attempt:
                self._note(retries=1)
                tr = trace.active()
                if tr is not None:
                    tr.instant("retry_attempt", "fault", offset=offset,
                               attempt=attempt, policy=self.policy.name,
                               error=type(last).__name__)
                trace.registry().counter_inc("storage.retries")
                time.sleep(self.policy.delay(attempt - 1, offset))
            try:
                return self._fetch_once(offset, size)
            except BaseException as e:  # noqa: BLE001 — reclassified below
                if not is_retryable(e):
                    raise
                last = e
        raise last

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        from repro.core.faults import is_retryable
        try:
            datas, dt = self.inner.fetch_batch(list(requests))
            if all(len(d) == s for d, (_, s) in zip(datas, requests)):
                return datas, dt
            self._note(short_reads=1)
        except BaseException as e:  # noqa: BLE001 — reclassified below
            if not is_retryable(e):
                raise
        # degraded path: per-request retried fetches (wall-measured — the
        # modeled batch time does not apply to a fault-recovery replay).
        # The replay is itself one retry of the batch-shaped region, even
        # when every per-request fetch then succeeds first try.
        self._note(retries=1)
        tr = trace.active()
        if tr is not None:
            tr.instant("retry_attempt", "fault", n=len(requests),
                       policy=self.policy.name, batch=True)
        trace.registry().counter_inc("storage.retries")
        t0 = time.perf_counter()
        out = [self.fetch(o, s) for o, s in requests]
        return out, time.perf_counter() - t0


def backend_io_defaults(backend: str) -> tuple[float, float, int]:
    """Per-backend ``(lane_bandwidth, latency, coalesce_gap)`` defaults:
    the NVMe profile for real/sim, the remote profile for object."""
    if backend == "object":
        return (DEFAULT_OBJECT_BANDWIDTH, DEFAULT_OBJECT_LATENCY,
                DEFAULT_OBJECT_COALESCE_GAP)
    return 7e9, 20e-6, DEFAULT_COALESCE_GAP


def open_storage(path: str, backend: str = "real", n_lanes: int = 1,
                 lane_bandwidth: float | None = None,
                 latency: float | None = None):
    default_bw, default_lat, _ = backend_io_defaults(backend)
    if lane_bandwidth is None:
        lane_bandwidth = default_bw
    if latency is None:
        latency = default_lat
    if backend == "real":
        return RealStorage(path)
    if backend == "sim":
        return SimulatedStorage(path, n_lanes=n_lanes,
                                lane_bandwidth=lane_bandwidth,
                                latency=latency)
    if backend == "object":
        # n_lanes=1 is the NVMe-profile default, not a deliberate "one
        # connection" ask — the remote profile parallelizes by default
        connections = n_lanes if n_lanes > 1 else DEFAULT_OBJECT_CONNECTIONS
        return ObjectStoreStorage(path, connections=connections,
                                  connection_bandwidth=lane_bandwidth,
                                  latency=latency)
    raise ValueError(backend)
