"""Device scan engine: storage → (decompress → decode) → device columns.

Effective bandwidth (the paper's headline metric) = logical raw bytes after
decode/decompress ÷ scan time.  The scanner accounts all three byte flows:

  stored_bytes   what moved from storage        (denominator of Insight 2/3)
  logical_bytes  what the query sees            (numerator of effective bw)
  decode work    measured wall time on this host

Decode backends:
  'pallas'  the TPU kernels (interpret mode on CPU) — correctness path
  'host'    vectorized numpy decoders — the *measured* throughput path on
            this CPU-only container (labeled in all benchmark output)

Both backends decode through the row-group DecodePlan by default
(core/decode_plan.py): pages are batched *across columns* per
(encoding, codec, width class), so a multi-column row group costs
O(encoding groups) kernel launches instead of O(columns × stride groups);
``use_plan=False`` selects the per-chunk reference path.  Fetches are
coalesced (core/storage.py): adjacent chunk byte ranges merge into large
reads, which the N-lane model rewards per Insight 2.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections.abc import Iterator, Sequence

import numpy as np

from repro.core.compression import ChecksumError, inflate_backend
from repro.core.decode_plan import planner_for
from repro.core.faults import (FaultPlan, InjectedDecodeError, is_retryable,
                               wrap_storage)
from repro.core.metadata import ChunkMeta
from repro.core.reader import TabFileReader, read_footer
from repro.core import trace
from repro.core.storage import (DEFAULT_COALESCE_GAP, PrefetchingStorage,
                                RealStorage, RetryingStorage, RetryPolicy,
                                backend_io_defaults, backend_retry_policy,
                                coalesce_ranges, fetch_coalesced,
                                open_storage)
from repro.kernels import ops
from repro.kernels.common import kernel_launch_count


@dataclasses.dataclass
class ScanMetrics:
    backend: str = "real"
    stored_bytes: int = 0
    logical_bytes: int = 0
    io_seconds: float = 0.0
    decode_seconds: float = 0.0
    n_row_groups: int = 0
    n_pages: int = 0
    io_per_rg: list[float] = dataclasses.field(default_factory=list)
    decode_per_rg: list[float] = dataclasses.field(default_factory=list)
    n_kernel_launches: int = 0   # pallas dispatches during this scan
    n_io_requests: int = 0       # storage requests issued (post-coalescing)
    shared_rgs: int = 0          # RGs delivered from another scan's
                                 # in-flight job (cooperative scans)
    plan_seconds: float = 0.0    # decode-plan build time (0 on cache hits)
    # per-stage wall spans of a pipelined run (overlap.py): elapsed time
    # between each stage's first start and last end — distinct from the
    # summed per-RG stage times above, which ignore thread overlap.
    fetch_wall_seconds: float = 0.0
    decode_wall_seconds: float = 0.0
    consume_seconds: float = 0.0
    # per-chunk decode item times per row group (ScanService dispatch):
    # decode_chunks_per_rg[k] lists RG k's independently scheduled item
    # walls in completion order — open, phase-1 (decompress) items, the
    # phase transition, phase-2 (decode) items, finalize; empty on
    # monolithic decode.  sum(decode_chunks_per_rg[k]) ≈ decode_per_rg[k].
    # decode_p2_start_per_rg[k] indexes RG k's first phase-2 item — the
    # barrier the modeled schedule honors (phase 2 starts only after
    # every phase-1 item drained).
    decode_chunks_per_rg: list[list[float]] = dataclasses.field(
        default_factory=list)
    decode_p2_start_per_rg: list[int] = dataclasses.field(
        default_factory=list)
    # fault-recovery accounting (DESIGN.md §6): extra attempts spent at
    # any layer (storage refetch, decode requeue), CRC failures observed
    # (whether healed by refetch or propagated), and per-request timeouts.
    retries: int = 0
    checksum_failures: int = 0
    timeouts: int = 0
    # informational: the gzip-inflate backend active for this process
    # (isal / zlib-ng / zlib — core/compression.py)
    inflate_backend: str = inflate_backend()
    # per-backend observability (DESIGN.md §8): prefetch economics when a
    # PrefetchingStorage wraps the backend, per-request latency
    # percentiles (modeled on sim/object, measured on real), and the
    # decode-worker pinning in effect (REPRO_DECODE_AFFINITY)
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_hidden_seconds: float = 0.0
    prefetch_stall_seconds: float = 0.0
    io_p50_us: float = 0.0
    io_p95_us: float = 0.0
    decode_affinity: str = "off"
    # observability (DESIGN.md §10; informational, never gated): which
    # RetryPolicy recovered this scan's reads (nvme/object/custom), how
    # many flight-recorder events the run recorded (0 when tracing off),
    # and the process metrics-registry snapshot at scan end
    retry_policy: str = ""
    trace_events: int = 0
    registry_snapshot: dict = dataclasses.field(default_factory=dict)

    @property
    def blocking_seconds(self) -> float:
        return self.io_seconds + self.decode_seconds

    @property
    def overlapped_seconds(self) -> float:
        """Two-stage pipeline schedule: storage is the serial resource; the
        compute stage for RG i starts at max(io done(i), compute done(i-1))."""
        io_done = 0.0
        compute_done = 0.0
        for io, dec in zip(self.io_per_rg, self.decode_per_rg):
            io_done += io
            compute_done = max(io_done, compute_done) + dec
        return compute_done

    def effective_bandwidth(self, overlapped: bool = True) -> float:
        t = self.overlapped_seconds if overlapped else self.blocking_seconds
        return self.logical_bytes / max(1e-12, t)

    @property
    def storage_bandwidth(self) -> float:
        return self.stored_bytes / max(1e-12, self.io_seconds)

    @property
    def compression_ratio(self) -> float:
        return self.logical_bytes / max(1, self.stored_bytes)


class DecodeJob:
    """Protocol for a schedulable row-group decode (see Scanner.decode_job).

    Run every callable from ``phase1_tasks()`` (concurrently is fine), then
    — only after phase 1 fully drains — every callable from
    ``phase2_tasks()``, then ``finalize()`` (the join barrier), which
    returns the decoded columns dict.  Serial callers may simply iterate;
    the ScanService fans the items out across its shared decode pool so one
    slow chunk no longer holds its whole row group.
    """

    def phase1_tasks(self) -> list:
        return []

    def phase2_tasks(self) -> list:
        return []

    def phase3_tasks(self) -> list:
        """Late-materialization items (fused stage-B, core/fused.py) —
        valid once phase 2 fully drains; empty on unfused scans."""
        return []

    def finalize(self) -> dict[str, ops.DecodeResult]:
        raise NotImplementedError


class _PlannedDecodeJob(DecodeJob):
    """Staged DecodePlanner execution (the default path)."""

    def __init__(self, scanner: "Scanner", rg_index: int, raws):
        self.planner = scanner.planner
        self.ctx = self.planner.begin_execute(rg_index, raws)

    def phase1_tasks(self):
        return self.planner.decompress_tasks(self.ctx)

    def phase2_tasks(self):
        return self.planner.decode_tasks(self.ctx)

    def phase3_tasks(self):
        return self.planner.fused_tasks(self.ctx)

    def finalize(self):
        out = self.planner.finish_execute(self.ctx)
        for res in out.values():
            if res.on_device:
                res.array.block_until_ready()
        return out


class _PerChunkDecodeJob(DecodeJob):
    """use_plan=False reference path: one item per column chunk."""

    def __init__(self, scanner: "Scanner", rg_index: int, raws):
        self.scanner = scanner
        self.rg_index = rg_index
        self.raws = raws
        self.out: dict[str, ops.DecodeResult] = {}

    def _decode_column(self, name: str) -> None:
        sc = self.scanner
        rg = sc.meta.row_groups[self.rg_index]
        chunk = rg.column(name)
        field = sc.meta.schema.field(name)
        self.out[name] = ops.decode_chunk(
            chunk, field, self.raws[name],
            use_kernels=(sc.decode_backend == "pallas"))

    def phase2_tasks(self):
        return [functools.partial(self._decode_column, name)
                for name in self.scanner.columns]

    def finalize(self):
        for res in self.out.values():
            if res.on_device:
                res.array.block_until_ready()
        return {name: self.out[name] for name in self.scanner.columns}


class Scanner:
    def __init__(self, path: str, columns: list[str] | None = None,
                 storage=None, decode_backend: str = "pallas",
                 use_plan: bool = True,
                 coalesce_gap: int = DEFAULT_COALESCE_GAP,
                 retry: RetryPolicy | None = None,
                 fault_plan: FaultPlan | None = None,
                 fused_spec=None):
        self.path = path
        self.meta = read_footer(path)
        self.columns = columns if columns is not None \
            else self.meta.schema.names
        storage = storage if storage is not None else RealStorage(path)
        # fault-recovery sandwich (DESIGN.md §6): the FaultPlan injects
        # *under* the retry wrapper, so retries heal transient injections
        # exactly as they would heal real storage faults.  Retries are on
        # by default with the storage backend's profile policy — the NVMe
        # policy locally, longer backoff/deadlines on the object store
        # (backend_retry_policy); attempts=1 disables.
        self.fault_plan = fault_plan
        storage = wrap_storage(storage, fault_plan)
        self.retry = retry if retry is not None else backend_retry_policy(
            getattr(storage, "kind", "real"))
        if self.retry.attempts > 1 or self.retry.timeout is not None:
            storage = RetryingStorage(storage, self.retry)
        self.storage = storage
        assert decode_backend in ("pallas", "host")
        self.decode_backend = decode_backend
        self.coalesce_gap = coalesce_gap
        if fused_spec is not None and not use_plan:
            raise ValueError("fused scans require use_plan=True")
        self.fused_spec = fused_spec
        self.planner = planner_for(path, self.meta, self.columns,
                                   decode_backend,
                                   fused_spec=fused_spec) \
            if use_plan else None
        self._reader = TabFileReader(path, fetch=self.storage.fetch)
        # decode-layer fault accounting; storage-layer counts live in the
        # RetryingStorage.  Lock-protected: the ScanService's decode
        # workers increment concurrently.
        self._fault_lock = threading.Lock()
        self._decode_retries = 0
        self._checksum_failures = 0
        self._timeouts = 0

    def enable_fused(self, spec) -> None:
        """Attach a FusedSpec to an already-open scanner (rebinds the
        planner — fused and unfused scans never share stage-A plans)."""
        if self.planner is None:
            raise ValueError("fused scans require use_plan=True")
        self.fused_spec = spec
        self.planner = planner_for(self.path, self.meta, self.columns,
                                   self.decode_backend, fused_spec=spec)

    # -- fault accounting ----------------------------------------------------

    def count_fault(self, *, retries: int = 0, checksum_failures: int = 0,
                    timeouts: int = 0) -> None:
        """Record decode-layer recovery events (scheduler requeues, CRC
        failures, deadline-adjacent timeouts) against this scanner."""
        with self._fault_lock:
            self._decode_retries += retries
            self._checksum_failures += checksum_failures
            self._timeouts += timeouts

    def fault_counters(self) -> dict[str, int]:
        """Merged recovery counters: decode layer + storage retry layer."""
        rs = getattr(self.storage, "retry_stats", None)
        with self._fault_lock:
            return {
                "retries": self._decode_retries
                + (rs.retries if rs else 0),
                "checksum_failures": self._checksum_failures,
                "timeouts": self._timeouts + (rs.timeouts if rs else 0),
            }

    # -- planning -------------------------------------------------------------

    def plan(self, predicate_stats=None,
             row_groups: Sequence[int] | None = None) -> list[int]:
        return self._reader.plan_row_groups(predicate_stats, row_groups)

    def prepare_plans(self, row_groups: Sequence[int] | None = None,
                      predicate_stats=None) -> int:
        """Build (and cache) decode plans for the scan's row groups ahead of
        time — the serving/query loop pattern where planning cost must not
        land on the first request.  Returns the number of groups planned."""
        if self.planner is None:
            return 0
        return sum(self.planner.plan_rg(i).n_groups
                   for i in self.plan(predicate_stats, row_groups))

    def rg_requests(self, rg_index: int) -> list[tuple[str, ChunkMeta,
                                                       tuple[int, int]]]:
        rg = self.meta.row_groups[rg_index]
        out = []
        for name in self.columns:
            chunk = rg.column(name)
            out.append((name, chunk, chunk.byte_range))
        return out

    def prefetch_rgs(self, rg_indices: Sequence[int]) -> int:
        """Issue background reads for the given row groups' coalesced
        ranges (no-op unless the storage stack has a PrefetchingStorage).
        The merged ranges are derived with the scanner's own coalesce gap,
        so the later demand ``fetch_rg`` asks for byte-identical requests
        and always hits the prefetch buffer."""
        pf = getattr(self.storage, "prefetch", None)
        if pf is None:
            return 0
        merged_all: list[tuple[int, int]] = []
        for i in rg_indices:
            ranges = [r for _, _, r in self.rg_requests(i)]
            if self.coalesce_gap <= 0:
                merged_all.extend(ranges)
            else:
                merged, _ = coalesce_ranges(ranges, self.coalesce_gap)
                merged_all.extend(merged)
        return pf(merged_all)

    # -- stages ----------------------------------------------------------------

    def fetch_rg(self, rg_index: int) -> tuple[dict[str, bytes], float]:
        """Fetch every selected chunk of one row group with coalesced
        requests: adjacent/near-adjacent column byte ranges merge into one
        large read (Insight 2); per-column zero-copy views come back."""
        reqs = self.rg_requests(rg_index)
        datas, dt = fetch_coalesced(self.storage, [r for _, _, r in reqs],
                                    self.coalesce_gap)
        return {name: d for (name, _, _), d in zip(reqs, datas)}, dt

    def decode_job(self, rg_index: int, raws: dict[str, bytes]
                   ) -> "DecodeJob":
        """Schedulable decode of one row group (ScanService per-chunk
        dispatch, core/scheduler.py): phase-1 items (decompress), phase-2
        items (one per DecodePlan group / fallback column), then a join
        ``finalize``.  Bit-identical to ``decode_rg`` — both drive the same
        staged planner execution.  An *instance-patched* ``decode_rg``
        (tests, instrumentation) stays authoritative: the job degrades to
        one opaque item that calls it."""
        if "decode_rg" in self.__dict__:
            from repro.core.scheduler import OpaqueDecodeJob
            return OpaqueDecodeJob(self, rg_index, raws)
        if self.fault_plan is not None:
            self.fault_plan.maybe_decode_error(rg_index)
        if self.planner is not None:
            return _PlannedDecodeJob(self, rg_index, raws)
        return _PerChunkDecodeJob(self, rg_index, raws)

    def _decode_rg_once(self, rg_index: int, raws: dict[str, bytes]
                        ) -> dict[str, ops.DecodeResult]:
        if self.fault_plan is not None:
            self.fault_plan.maybe_decode_error(rg_index)
        if self.planner is not None:
            return self.planner.execute(rg_index, raws)
        out = {}
        rg = self.meta.row_groups[rg_index]
        for name in self.columns:
            chunk = rg.column(name)
            field = self.meta.schema.field(name)
            out[name] = ops.decode_chunk(chunk, field, raws[name],
                                         use_kernels=(self.decode_backend
                                                      == "pallas"))
        return out

    def retry_decode(self, rg_index: int, e: BaseException) -> bool:
        """Prepare a decode retry after failure ``e``: count it, evict
        anything the failed attempt may have pushed into the shared
        caches, and say whether the retry budget allows another try
        (callers then refetch the raw bytes and decode again).  Shared by
        the blocking path below and the ScanService requeue path."""
        if isinstance(e, ChecksumError):
            self.count_fault(checksum_failures=1)
            tr = trace.active()
            if tr is not None:
                tr.instant("checksum_failure", "fault", scan=self.path,
                           rg=rg_index)
        if isinstance(e, TimeoutError):
            self.count_fault(timeouts=1)
        if not is_retryable(e):
            return False
        if self.planner is not None:
            self.planner.evict_rg(rg_index)
        return True

    def decode_rg(self, rg_index: int, raws: dict[str, bytes]
                  ) -> tuple[dict[str, ops.DecodeResult], float]:
        t0 = time.perf_counter()
        out = None
        for attempt in range(max(1, self.retry.attempts)):
            try:
                out = self._decode_rg_once(rg_index, raws)
                break
            except (ChecksumError, InjectedDecodeError) as e:
                # a CRC failure here may be transit corruption (torn DMA,
                # injected flip): evict, refetch clean bytes, try again —
                # but never more times than the storage retry budget
                if (not self.retry_decode(rg_index, e)
                        or attempt + 1 >= max(1, self.retry.attempts)):
                    raise
                self.count_fault(retries=1)
                raws, _ = self.fetch_rg(rg_index)
        # flush async dispatch so decode time is honest
        for res in out.values():
            if res.on_device:
                res.array.block_until_ready()
        return out, time.perf_counter() - t0

    # -- full scans --------------------------------------------------------------

    def scan(self, row_groups: Sequence[int] | None = None,
             predicate_stats=None
             ) -> Iterator[tuple[int, dict[str, ops.DecodeResult]]]:
        for i in self.plan(predicate_stats, row_groups):
            raws, _ = self.fetch_rg(i)
            cols, _ = self.decode_rg(i, raws)
            yield i, cols

    def scan_with_metrics(self, row_groups: Sequence[int] | None = None,
                          predicate_stats=None, consume=None
                          ) -> tuple[object | None, ScanMetrics]:
        m = ScanMetrics(backend=getattr(self.storage, "kind", "real"))
        launches0 = kernel_launch_count()
        requests0 = self.storage.stats.requests
        faults0 = self.fault_counters()
        plan_s0 = self.planner.plan_seconds if self.planner else 0.0
        acc = None
        for i in self.plan(predicate_stats, row_groups):
            raws, io_dt = self.fetch_rg(i)
            cols, dec_dt = self.decode_rg(i, raws)
            rg = self.meta.row_groups[i]
            for name in self.columns:
                chunk = rg.column(name)
                m.stored_bytes += chunk.stored_bytes
                m.n_pages += len(chunk.pages)
            m.logical_bytes += sum(r.logical_bytes for r in cols.values())
            m.io_seconds += io_dt
            m.decode_seconds += dec_dt
            m.io_per_rg.append(io_dt)
            m.decode_per_rg.append(dec_dt)
            m.n_row_groups += 1
            if consume is not None:
                acc = consume(acc, i, cols)
        m.n_kernel_launches = kernel_launch_count() - launches0
        m.n_io_requests = self.storage.stats.requests - requests0
        faults = self.fault_counters()
        m.retries = faults["retries"] - faults0["retries"]
        m.checksum_failures = (faults["checksum_failures"]
                               - faults0["checksum_failures"])
        m.timeouts = faults["timeouts"] - faults0["timeouts"]
        if self.planner is not None:
            m.plan_seconds = self.planner.plan_seconds - plan_s0
        m.retry_policy = self.retry.name
        tr = trace.active()
        if tr is not None:
            m.trace_events = tr.event_count()
            m.registry_snapshot = trace.registry().snapshot()
        return acc, m


def open_scanner(path: str, columns=None, backend: str = "real",
                 n_lanes: int = 1, decode_backend: str = "pallas",
                 lane_bandwidth: float | None = None,
                 latency: float | None = None,
                 use_plan: bool = True,
                 coalesce_gap: int | None = None,
                 retry: RetryPolicy | None = None,
                 fault_plan: FaultPlan | None = None,
                 fused_spec=None, prefetch: bool = False,
                 prefetch_threads: int = 2) -> Scanner:
    # None means "the backend's profile default": NVMe numbers and 64 KiB
    # gaps for real/sim, the remote profile (ms latency, multi-MiB gap)
    # for object — callers that pass explicit values still win
    if coalesce_gap is None:
        coalesce_gap = backend_io_defaults(backend)[2]
    storage = open_storage(path, backend, n_lanes, lane_bandwidth, latency)
    if prefetch:
        storage = PrefetchingStorage(storage, threads=prefetch_threads)
    return Scanner(path, columns, storage, decode_backend,
                   use_plan=use_plan, coalesce_gap=coalesce_gap,
                   retry=retry, fault_plan=fault_plan,
                   fused_spec=fused_spec)
