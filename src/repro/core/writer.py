"""TabFile writer — applies the four insights at write time.

Supports both one-shot writes (``write_table``) and streaming row-group
writes (``begin`` / ``write_row_group`` / ``finish``), which the rewriter
uses to re-shape arbitrarily large files at bounded memory.
"""

from __future__ import annotations

import concurrent.futures as cf
import struct

import numpy as np

from repro.core.compression import Codec, maybe_compress_chunk, page_crc
from repro.core.config import FileConfig
from repro.core.encodings import ChunkEncoding, select_chunk_encoding
from repro.core.metadata import (MAGIC, ChunkMeta, FileMeta, PageMeta,
                                 RowGroupMeta)
from repro.core.schema import PhysicalType, Schema
from repro.core.table import StringColumn, Table


def _page_slices(n_rows: int, rows_per_page: int) -> list[tuple[int, int]]:
    return [(s, min(s + rows_per_page, n_rows))
            for s in range(0, n_rows, rows_per_page)]


def _chunk_stats(values, physical: PhysicalType) -> dict | None:
    if isinstance(values, StringColumn) or values.shape[0] == 0:
        return None
    if physical == PhysicalType.BOOLEAN:
        return {"min": bool(values.min()), "max": bool(values.max())}
    lo, hi = values.min(), values.max()
    if physical in (PhysicalType.FLOAT, PhysicalType.DOUBLE):
        return {"min": float(lo), "max": float(hi)}
    return {"min": int(lo), "max": int(hi)}


def _page_stats(values, physical: PhysicalType,
                slices) -> "list[tuple] | None":
    """Per-page (vmin, vmax) zone maps for numeric columns — the fused
    scan path (core/fused.py) uses these to skip whole pages before any
    arena byte is materialized.  Strings/booleans carry none."""
    if isinstance(values, StringColumn) or values.shape[0] == 0:
        return None
    if physical == PhysicalType.BOOLEAN:
        return None
    as_float = physical in (PhysicalType.FLOAT, PhysicalType.DOUBLE)
    out = []
    for s, e in slices:
        v = values[s:e]
        if v.shape[0] == 0:
            out.append(None)
        elif as_float:
            out.append((float(v.min()), float(v.max())))
        else:
            out.append((int(v.min()), int(v.max())))
    return out


def _encode_one_chunk(args):
    """Worker: encode + codec-gate one column chunk (thread-pool friendly —
    numpy/zlib release the GIL on the heavy parts)."""
    values, field, slices, config = args
    ce: ChunkEncoding = select_chunk_encoding(values, field, slices, config)
    payloads = [p.payload for p in ce.pages]
    if ce.dict_page is not None:
        payloads = [ce.dict_page.payload] + payloads
    codec, stored, _, _ = maybe_compress_chunk(
        payloads, config.compression.codec, config.compression.min_gain,
        config.compression.level)
    return (ce, codec, stored, _chunk_stats(values, field.physical),
            _page_stats(values, field.physical, slices))


class TabFileWriter:
    def __init__(self, path: str, config: FileConfig, threads: int = 1):
        self.path = path
        self.config = config
        self.threads = max(1, threads)
        self._f = None
        self._offset = 0
        self._rg_metas: list[RowGroupMeta] = []
        self._schema: Schema | None = None
        self._num_rows = 0
        self._logical_nbytes = 0

    # -- streaming API -------------------------------------------------------

    def begin(self, schema: Schema) -> "TabFileWriter":
        self._f = open(self.path, "wb")
        self._f.write(MAGIC)
        self._offset = len(MAGIC)
        self._schema = schema
        return self

    def write_row_group(self, rg: Table) -> None:
        """Write exactly one row group from ``rg`` (caller sizes it)."""
        assert self._f is not None, "begin() first"
        config = self.config
        rows_per_page = config.rows_per_page(rg.num_rows)
        slices = _page_slices(rg.num_rows, rows_per_page)
        jobs = [(rg.columns[fld.name], fld, slices, config)
                for fld in self._schema.fields]
        if self.threads > 1 and len(jobs) > 1:
            with cf.ThreadPoolExecutor(self.threads) as pool:
                results = list(pool.map(_encode_one_chunk, jobs))
        else:
            results = [_encode_one_chunk(j) for j in jobs]
        chunk_metas: list[ChunkMeta] = []
        for fld, (ce, codec, stored, stats, pstats) in zip(
                self._schema.fields, results):
            uncomp_pages = list(ce.pages)
            n_dict = 0
            if ce.dict_page is not None:
                uncomp_pages = [ce.dict_page] + uncomp_pages
                n_dict = 1
            # per-page zone maps line up 1:1 with the row slices; encoders
            # that merge or split pages (none today) would break the zip,
            # so only stamp when the counts agree
            stamp_pages = (pstats is not None
                           and len(ce.pages) == len(pstats))
            page_metas: list[PageMeta] = []
            for page_i, (enc_page, stored_payload) in enumerate(
                    zip(uncomp_pages, stored)):
                self._f.write(stored_payload)
                # stamp a CRC32 of the *stored* bytes so the read path can
                # verify before decompressing / caching (compression.py)
                extra = dict(enc_page.extra,
                             crc32=page_crc(stored_payload))
                if stamp_pages and page_i >= n_dict:
                    ps = pstats[page_i - n_dict]
                    if ps is not None:
                        extra = dict(extra, vmin=ps[0], vmax=ps[1])
                if codec == Codec.CASCADE:
                    # stamp the cascade frame's packed-run widths into the
                    # footer so the DecodePlanner can group the device
                    # decompress stage's (vw, cw) classes at *plan* time
                    # (core/decode_plan.py) instead of re-reading every
                    # page header at execute time
                    vw, cw = np.frombuffer(stored_payload, dtype=np.int32,
                                           count=4)[2:4]
                    extra = dict(extra, cascade_vw=int(vw),
                                 cascade_cw=int(cw))
                page_metas.append(PageMeta(
                    offset=self._offset,
                    stored_size=len(stored_payload),
                    uncompressed_size=enc_page.nbytes,
                    n_values=enc_page.n_values,
                    extra=extra))
                self._offset += len(stored_payload)
            dict_meta = None
            if ce.dict_page is not None:
                dict_meta, page_metas = page_metas[0], page_metas[1:]
            chunk_metas.append(ChunkMeta(
                name=fld.name, encoding=int(ce.encoding), codec=int(codec),
                pages=page_metas, dict_page=dict_meta, stats=stats))
        self._rg_metas.append(RowGroupMeta(rg.num_rows, chunk_metas))
        self._num_rows += rg.num_rows
        self._logical_nbytes += rg.nbytes

    def finish(self) -> FileMeta:
        assert self._f is not None
        meta = FileMeta(
            schema=self._schema, num_rows=self._num_rows,
            row_groups=self._rg_metas, logical_nbytes=self._logical_nbytes,
            writer_config=self.config.fingerprint())
        footer_json = meta.to_json_bytes()
        # footer block = json + LE32 crc32(json); footer_len covers both,
        # so read_footer can verify the metadata before trusting any
        # page offset in it (reader.py handles crc-less legacy footers)
        footer = footer_json + struct.pack("<I", page_crc(footer_json))
        self._f.write(footer)
        self._f.write(struct.pack("<Q", len(footer)))
        self._f.write(MAGIC)
        self._f.close()
        self._f = None
        return meta

    # -- one-shot API ---------------------------------------------------------

    def write(self, table: Table) -> FileMeta:
        self.begin(table.schema)
        for rg_start in range(0, table.num_rows, self.config.rows_per_rg):
            self.write_row_group(
                table.slice(rg_start, rg_start + self.config.rows_per_rg))
        return self.finish()


def write_table(table: Table, path: str, config: FileConfig,
                threads: int = 1) -> FileMeta:
    return TabFileWriter(path, config, threads).write(table)
