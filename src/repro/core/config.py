"""File configuration — the paper's four insights as first-class knobs.

The paper's central claim is that Parquet *configuration*, not the format,
determines accelerator scan performance.  ``FileConfig`` captures every knob
the paper studies:

* Insight 1 — ``target_pages_per_chunk``: the decode kernel's grid size is
  the page count; ≥100 keeps the accelerator busy.
* Insight 2 — ``rows_per_rg``: million-row row groups make each column chunk
  a MiB-scale transfer so the storage path saturates.
* Insight 3 — ``encodings=EncodingPolicy.FLEX``: per-chunk smallest-wins
  selection over every spec-valid V1+V2 encoding.
* Insight 4 — ``compression.min_gain``: a codec is kept only when it shrinks
  the chunk by at least this fraction.
"""

from __future__ import annotations

import dataclasses
import enum
from collections.abc import Sequence


class EncodingPolicy(str, enum.Enum):
    """Which encodings the writer may consider for a column chunk."""

    PLAIN_ONLY = "plain_only"    # worst case: no lightweight compression at all
    V1_ONLY = "v1_only"          # DuckDB-style default: plain or dictionary
    FLEX = "flex"                # Insight 3: all V1+V2 candidates, smallest wins


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Block-codec policy for column chunks (Insight 4).

    ``codec``: "none" | "gzip" (host-side LZ77, kept for ecosystem parity)
               | "cascade" (TPU-native word-level RLE+bitpack; beyond-paper).
    ``min_gain``: fraction of the encoded size the codec must save for the
    chunk to be stored compressed.  ``0.0`` reproduces the "blind
    compression" baseline the paper criticises; the paper uses ``0.10``.
    """

    codec: str = "none"
    min_gain: float = 0.10
    level: int = 1  # gzip level; speed-oriented like the paper's Snappy usage

    def __post_init__(self) -> None:
        if self.codec not in ("none", "gzip", "cascade"):
            raise ValueError(f"unknown codec {self.codec!r}")
        if not 0.0 <= self.min_gain < 1.0:
            raise ValueError("min_gain must be in [0, 1)")


@dataclasses.dataclass(frozen=True)
class FileConfig:
    """Complete TabFile writer configuration."""

    rows_per_rg: int = 122_880            # DuckDB default row-group size
    target_pages_per_chunk: int = 1       # DuckDB default: one page per chunk
    encodings: EncodingPolicy = EncodingPolicy.V1_ONLY
    compression: CompressionSpec = dataclasses.field(
        default_factory=lambda: CompressionSpec(codec="gzip", min_gain=0.0))
    # Columns never dictionary-encoded (e.g. already-dense token streams).
    no_dict_columns: Sequence[str] = ()
    # Maximum dictionary cardinality before DICT is abandoned for a chunk.
    max_dict_fraction: float = 0.75

    def __post_init__(self) -> None:
        if self.rows_per_rg <= 0:
            raise ValueError("rows_per_rg must be positive")
        if self.target_pages_per_chunk <= 0:
            raise ValueError("target_pages_per_chunk must be positive")

    def rows_per_page(self, rg_rows: int) -> int:
        """Rows per page for a row group of ``rg_rows`` rows."""
        pages = min(self.target_pages_per_chunk, max(1, rg_rows))
        return -(-rg_rows // pages)  # ceil division

    def replace(self, **kw) -> "FileConfig":
        return dataclasses.replace(self, **kw)

    def fingerprint(self) -> dict:
        """The knob values a written file records in its footer
        (``FileMeta.writer_config``) and a dataset manifest records per
        fragment — the identity compaction compares against its target."""
        return {
            "rows_per_rg": self.rows_per_rg,
            "target_pages_per_chunk": self.target_pages_per_chunk,
            "encodings": self.encodings.value,
            "codec": self.compression.codec,
            "min_gain": self.compression.min_gain,
        }


# The two named configurations from the paper (Fig. 1): the CPU-era default
# baseline (DuckDB defaults) and the GPU/TPU-aware optimized configuration.
CPU_DEFAULT = FileConfig(
    rows_per_rg=122_880,
    target_pages_per_chunk=1,
    encodings=EncodingPolicy.V1_ONLY,
    compression=CompressionSpec(codec="gzip", min_gain=0.0),
)

ACCELERATOR_OPTIMIZED = FileConfig(
    rows_per_rg=10_000_000,
    target_pages_per_chunk=100,
    encodings=EncodingPolicy.FLEX,
    compression=CompressionSpec(codec="gzip", min_gain=0.10),
)

# Beyond-paper: identical policy but with the TPU-native cascade codec so the
# decompression stage itself is device-resident (see DESIGN.md §2).
TPU_CASCADE = ACCELERATOR_OPTIMIZED.replace(
    compression=CompressionSpec(codec="cascade", min_gain=0.10))


def intermediate_configs() -> dict:
    """The ablation ladder used throughout the paper's figures."""
    return {
        "baseline": CPU_DEFAULT,
        "+pages": CPU_DEFAULT.replace(target_pages_per_chunk=100),
        "+rg_size": CPU_DEFAULT.replace(
            target_pages_per_chunk=100, rows_per_rg=10_000_000),
        "+encoding_flex": CPU_DEFAULT.replace(
            target_pages_per_chunk=100, rows_per_rg=10_000_000,
            encodings=EncodingPolicy.FLEX),
        "optimized": ACCELERATOR_OPTIMIZED,
        "tpu_cascade": TPU_CASCADE,
    }
