"""File-configuration autotuner — the rewriter's practical front end.

The paper gives four insights but leaves "which exact numbers for *my*
table and *my* storage" to the operator.  The autotuner closes that loop:
it takes a sample of the table (or the source file), sweeps the knob
grid under the calibrated storage model + measured encode sizes, and
recommends a FileConfig:

  rows_per_rg      smallest RG whose mean compressed chunk reaches the
                   target I/O efficiency (Insight 2: e(s) ≥ eff_target)
  pages_per_chunk  decode-grid width (Insight 1: ≥ grid_lanes, capped so
                   pages stay ≥ min_page_rows)
  encodings        FLEX if it saves ≥ flex_min_gain vs V1 (Insight 3)
  compression      codec kept only where the measured chunk-level gain
                   clears the Insight-4 threshold
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.compression import compress
from repro.core.config import (CompressionSpec, EncodingPolicy, FileConfig)
from repro.core.encodings import select_chunk_encoding
from repro.core.table import Table


@dataclasses.dataclass
class TuneReport:
    config: FileConfig
    per_column: dict[str, dict]
    sampled_rows: int
    est_compressed_bytes_per_row: float
    notes: list


def _encoded_size_per_row(table: Table, policy: EncodingPolicy,
                          config: FileConfig) -> dict[str, float]:
    out = {}
    n = table.num_rows
    cfg = config.replace(encodings=policy)
    for field in table.schema.fields:
        ce = select_chunk_encoding(table[field.name], field, [(0, n)], cfg)
        out[field.name] = ce.total_bytes / max(1, n)
    return out


def autotune(table: Table, *, lane_bandwidth: float = 7e9,
             latency: float = 20e-6, grid_lanes: int = 128,
             eff_target: float = 0.9, flex_min_gain: float = 0.02,
             codec: str = "gzip", comp_threshold: float = 0.10,
             sample_rows: int = 100_000) -> TuneReport:
    """Recommend a FileConfig for ``table`` (a sample is representative)."""
    notes = []
    sample = table.slice(0, min(sample_rows, table.num_rows))
    n = sample.num_rows

    # Insight 3: FLEX vs V1 on the sample
    base = FileConfig()
    v1 = _encoded_size_per_row(sample, EncodingPolicy.V1_ONLY, base)
    flex = _encoded_size_per_row(sample, EncodingPolicy.FLEX, base)
    v1_row = sum(v1.values())
    flex_row = sum(flex.values())
    gain = 1.0 - flex_row / max(v1_row, 1e-9)
    use_flex = gain >= flex_min_gain
    notes.append(f"FLEX saves {gain*100:.1f}% vs V1 on the sample "
                 f"({'keep FLEX' if use_flex else 'V1 suffices'})")
    per_row = flex if use_flex else v1

    # Insight 4: measure actual codec gain on the encoded sample chunks
    comp_gains = {}
    cfg_enc = base.replace(encodings=EncodingPolicy.FLEX if use_flex
                           else EncodingPolicy.V1_ONLY)
    for field in sample.schema.fields:
        ce = select_chunk_encoding(sample[field.name], field, [(0, n)],
                                   cfg_enc)
        raw = b"".join(p.payload for p in ce.pages)
        comp_gains[field.name] = 1.0 - len(compress(raw, codec)) \
            / max(1, len(raw))
    kept = [c for c, g in comp_gains.items() if g >= comp_threshold]
    notes.append(f"codec {codec} clears the {comp_threshold:.0%} gate on "
                 f"{len(kept)}/{len(comp_gains)} columns")

    # Insight 2: rows_per_rg from the per-column byte rate — the smallest
    # (power-of-two-ish) RG whose *smallest* column chunk hits eff_target
    min_col_rate = min(per_row.values())        # bytes/row, worst column
    target_chunk = eff_target / (1 - eff_target) * latency * lane_bandwidth
    rows_needed = int(target_chunk / max(min_col_rate, 1e-9))
    rows_per_rg = 1 << int(np.ceil(np.log2(max(rows_needed, 4096))))
    rows_per_rg = min(rows_per_rg, 16_000_000)
    notes.append(
        f"worst column {min_col_rate:.2f} B/row → chunks reach "
        f"{eff_target:.0%} lane efficiency at {rows_needed:,} rows; "
        f"recommending rows_per_rg={rows_per_rg:,}")

    # Insight 1: pages ≥ grid lanes, but keep ≥ 1024 rows per page
    pages = min(grid_lanes, max(1, rows_per_rg // 1024))
    notes.append(f"pages_per_chunk={pages} (grid {grid_lanes} lanes, "
                 f"≥1024 rows/page)")

    config = FileConfig(
        rows_per_rg=rows_per_rg,
        target_pages_per_chunk=pages,
        encodings=EncodingPolicy.FLEX if use_flex
        else EncodingPolicy.V1_ONLY,
        compression=CompressionSpec(codec=codec, min_gain=comp_threshold))
    return TuneReport(
        config=config,
        per_column={c: {"bytes_per_row": per_row[c],
                        "codec_gain": comp_gains[c]}
                    for c in per_row},
        sampled_rows=n,
        est_compressed_bytes_per_row=float(
            sum(per_row[c] * (1 - max(0.0, comp_gains[c])
                              if comp_gains[c] >= comp_threshold else 1.0)
                for c in per_row)),
        notes=notes)
