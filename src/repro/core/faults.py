"""Deterministic fault injection + the scan path's failure taxonomy.

Production object stores and NVMe fleets exhibit a small, well-known
fault menu: transient I/O errors, short/torn reads, flipped bits, and
latency spikes.  This module makes every one of them *reproducible* so
the recovery layers (storage retry — core/storage.py; scan retry
budget/deadlines — core/scheduler.py; fragment quarantine —
dataset/executor.py) are testable with exact replay (DESIGN.md §6):

  FaultPlan      a seeded schedule.  Every decision is a pure hash of
                 ``(seed, kind, offset, size, attempt)`` — NOT a
                 sequential RNG draw — so concurrent readers observe the
                 same faults regardless of thread interleaving, and the
                 same seed replays the same failure sequence.
  FaultyStorage  wraps any storage backend (Real/Simulated) and injects
                 the plan's faults on ``fetch``/``fetch_batch``.

``transient=True`` (the default) fires each fault only on a byte range's
*first* attempt, so a bounded retry always heals it — the chaos-suite
contract (bit-identical results, ``retries > 0``).  ``transient=False``
makes faults permanent: every attempt fails, which must surface as a
typed error or a quarantined fragment, never a wrong answer.

The error taxonomy lives here so every layer classifies consistently:

  retryable      OSError (incl. injected I/O errors and short reads),
                 TimeoutError (incl. FetchTimeout), ChecksumError (a torn
                 read looks identical to at-rest corruption until
                 refetched — retry once through a fresh read),
                 InjectedDecodeError (a decode worker dying transiently)
  non-retryable  DeadlineExceeded (the budget itself), everything else
                 (logic errors must propagate, not burn retries)
"""

from __future__ import annotations

import dataclasses
import struct
import threading
import time
import zlib
from collections import Counter
from collections.abc import Sequence

from repro.core import trace
from repro.core.compression import ChecksumError


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------

class InjectedFault:
    """Marker mixin: this exception came from a FaultPlan, not the OS."""


class InjectedIOError(InjectedFault, OSError):
    """Transient-class I/O error (models EIO/dropped connection)."""


class InjectedDecodeError(InjectedFault, RuntimeError):
    """A decode worker failed transiently (models a crashed/evicted
    worker); the ScanService requeues the row group."""


class ShortReadError(OSError):
    """A read returned fewer bytes than requested (torn read / truncated
    object).  OSError subclass → retryable."""

    def __init__(self, offset: int, want: int, got: int):
        self.offset, self.want, self.got = offset, want, got
        super().__init__(f"short read @{offset}: wanted {want} bytes, "
                         f"got {got}")


class FetchTimeout(TimeoutError):
    """A storage request exceeded its per-request timeout budget."""

    def __init__(self, offset: int, size: int, elapsed: float,
                 budget: float):
        self.offset, self.size = offset, size
        self.elapsed, self.budget = elapsed, budget
        super().__init__(f"fetch @{offset} (+{size}) took {elapsed * 1e3:.1f}"
                         f"ms > {budget * 1e3:.1f}ms budget")


class DeadlineExceeded(TimeoutError):
    """A scan/request deadline expired.  NOT retryable — the deadline is
    the budget; retrying past it would defeat its purpose."""


def is_retryable(exc: BaseException) -> bool:
    """Classify per the module taxonomy (see module docstring)."""
    if isinstance(exc, DeadlineExceeded):
        return False
    return isinstance(exc, (OSError, TimeoutError, ChecksumError,
                            InjectedDecodeError))


# ---------------------------------------------------------------------------
# the seeded schedule
# ---------------------------------------------------------------------------

def _roll(seed: int, kind: str, *coords: int) -> float:
    """Uniform [0, 1) as a pure function of (seed, kind, coords)."""
    h = zlib.crc32(kind.encode(),
                   zlib.crc32(struct.pack("<q", seed)))
    for c in coords:
        h = zlib.crc32(struct.pack("<q", c), h)
    return h / 2**32


@dataclasses.dataclass
class FaultPlan:
    """Seeded, deterministic fault schedule (rates are per-request
    probabilities in [0, 1]).  Decisions depend only on
    ``(seed, kind, offset, size, attempt)``, so the plan is replayable
    and thread-interleaving-proof; per-range attempt numbers are the only
    mutable state (lock-protected)."""

    seed: int = 0
    io_error: float = 0.0      # raise InjectedIOError before the read
    short_read: float = 0.0    # truncate the returned bytes
    bit_flip: float = 0.0      # flip one byte of the returned bytes
    latency: float = 0.0       # sleep latency_seconds before the read
    decode_error: float = 0.0  # raise InjectedDecodeError in decode
    latency_seconds: float = 0.002
    transient: bool = True     # faults fire only on attempt 0 per target

    def __post_init__(self):
        self._lock = threading.Lock()
        self._attempts: dict[tuple, int] = {}
        self.injected: Counter = Counter()

    # -- replay helpers ----------------------------------------------------

    def clone(self) -> "FaultPlan":
        """A fresh plan with the same schedule (seed/rates) and zeroed
        attempt state — replaying it reproduces the exact sequence."""
        return FaultPlan(seed=self.seed, io_error=self.io_error,
                         short_read=self.short_read, bit_flip=self.bit_flip,
                         latency=self.latency,
                         decode_error=self.decode_error,
                         latency_seconds=self.latency_seconds,
                         transient=self.transient)

    def counters(self) -> dict[str, int]:
        with self._lock:
            return dict(self.injected)

    def total_injected(self) -> int:
        with self._lock:
            return sum(self.injected.values())

    # -- decision core -------------------------------------------------------

    def _next_attempt(self, key: tuple) -> int:
        with self._lock:
            n = self._attempts.get(key, 0)
            self._attempts[key] = n + 1
            return n

    def _fires(self, rate: float, kind: str, attempt: int,
               *coords: int) -> bool:
        if rate <= 0.0 or (self.transient and attempt > 0):
            return False
        if not _roll(self.seed, kind, *coords) < rate:
            return False
        with self._lock:
            self.injected[kind] += 1
        tr = trace.active()
        if tr is not None:
            tr.instant("fault_injected", "fault", kind=kind,
                       attempt=attempt, coords=list(coords))
        trace.registry().counter_inc(f"faults.injected.{kind}")
        return True

    # -- storage hooks (FaultyStorage calls these) ---------------------------

    def read_attempt(self, offset: int, size: int) -> int:
        return self._next_attempt(("r", offset, size))

    def before_read(self, offset: int, size: int, attempt: int) -> None:
        """Latency spike and/or I/O error for one request."""
        if self._fires(self.latency, "latency", attempt, offset, size):
            time.sleep(self.latency_seconds)
        if self._fires(self.io_error, "io_error", attempt, offset, size):
            raise InjectedIOError(5, f"injected EIO @{offset} (+{size})")

    def corrupt(self, data: bytes, offset: int, size: int,
                attempt: int) -> bytes:
        """Short read and/or bit flip applied to one request's bytes."""
        if len(data) and self._fires(self.short_read, "short_read",
                                     attempt, offset, size):
            keep = max(0, len(data) - 1
                       - int(_roll(self.seed, "short_len", offset, size)
                             * (len(data) // 2)))
            data = data[:keep]
        if len(data) and self._fires(self.bit_flip, "bit_flip",
                                     attempt, offset, size):
            pos = int(_roll(self.seed, "flip_pos", offset, size) * len(data))
            b = bytearray(data)
            b[pos] ^= 1 << int(_roll(self.seed, "flip_bit",
                                     offset, size) * 8)
            data = bytes(b)
        return data

    # -- decode hook (Scanner/ScanService call this) --------------------------

    def maybe_decode_error(self, token: int) -> None:
        """Deterministic transient decode failure for work unit ``token``
        (e.g. a row-group index)."""
        attempt = self._next_attempt(("d", token))
        if self._fires(self.decode_error, "decode_error", attempt, token):
            raise InjectedDecodeError(f"injected decode fault (rg {token}, "
                                      f"attempt {attempt})")


# ---------------------------------------------------------------------------
# the storage wrapper
# ---------------------------------------------------------------------------

class FaultyStorage:
    """Injects a FaultPlan's faults over any storage backend.  Everything
    not intercepted (``stats``, ``kind``, model parameters, …) delegates
    to the wrapped backend, so the wrapper is drop-in for Scanner/reader
    code that duck-types storage."""

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def close(self) -> None:
        self.inner.close()

    def fetch(self, offset: int, size: int) -> bytes:
        attempt = self.plan.read_attempt(offset, size)
        self.plan.before_read(offset, size, attempt)
        data = self.inner.fetch(offset, size)
        return self.plan.corrupt(data, offset, size, attempt)

    def fetch_batch(self, requests: Sequence[tuple[int, int]]
                    ) -> tuple[list[bytes], float]:
        attempts = [self.plan.read_attempt(o, s) for o, s in requests]
        for (o, s), a in zip(requests, attempts):
            self.plan.before_read(o, s, a)
        datas, dt = self.inner.fetch_batch(requests)
        return [self.plan.corrupt(d, o, s, a)
                for d, (o, s), a in zip(datas, requests, attempts)], dt


def wrap_storage(storage, plan: FaultPlan | None):
    """``storage`` under ``plan`` (identity when plan is None)."""
    return storage if plan is None else FaultyStorage(storage, plan)
