"""TabFile footer metadata (Parquet FileMetaData analogue).

File layout:
  [8B magic "TABF0001"] [page payloads ...] [footer json utf-8]
  [uint64 footer length] [8B magic]

Page payloads are pure data (no inline page headers): per-page metadata
lives in the footer, Parquet-ColumnIndex style, so chunks upload to the
device as contiguous byte ranges.
"""

from __future__ import annotations

import dataclasses
import json

from repro.core.compression import Codec
from repro.core.encodings import Encoding
from repro.core.schema import Schema

MAGIC = b"TABF0001"


@dataclasses.dataclass
class PageMeta:
    offset: int               # absolute file offset
    stored_size: int          # bytes on disk (maybe compressed)
    uncompressed_size: int    # encoded-but-uncompressed bytes
    n_values: int
    extra: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(o: dict) -> "PageMeta":
        return PageMeta(**o)


@dataclasses.dataclass
class ChunkMeta:
    name: str
    encoding: int             # Encoding enum value
    codec: int                # Codec enum value
    pages: list[PageMeta]
    dict_page: PageMeta | None = None
    stats: dict | None = None  # {"min":…, "max":…} for numerics

    @property
    def n_values(self) -> int:
        return sum(p.n_values for p in self.pages)

    @property
    def stored_bytes(self) -> int:
        n = sum(p.stored_size for p in self.pages)
        if self.dict_page:
            n += self.dict_page.stored_size
        return n

    @property
    def uncompressed_bytes(self) -> int:
        n = sum(p.uncompressed_size for p in self.pages)
        if self.dict_page:
            n += self.dict_page.uncompressed_size
        return n

    @property
    def byte_range(self):
        """(offset, size) covering dict page + all data pages."""
        first = self.dict_page or self.pages[0]
        last = self.pages[-1] if self.pages else first
        return first.offset, last.offset + last.stored_size - first.offset

    def to_json(self) -> dict:
        return {
            "name": self.name, "encoding": self.encoding, "codec": self.codec,
            "pages": [p.to_json() for p in self.pages],
            "dict_page": self.dict_page.to_json() if self.dict_page else None,
            "stats": self.stats,
        }

    @staticmethod
    def from_json(o: dict) -> "ChunkMeta":
        return ChunkMeta(
            name=o["name"], encoding=o["encoding"], codec=o["codec"],
            pages=[PageMeta.from_json(p) for p in o["pages"]],
            dict_page=(PageMeta.from_json(o["dict_page"])
                       if o.get("dict_page") else None),
            stats=o.get("stats"),
        )


@dataclasses.dataclass
class RowGroupMeta:
    n_rows: int
    columns: list[ChunkMeta]

    def column(self, name: str) -> ChunkMeta:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def to_json(self) -> dict:
        return {"n_rows": self.n_rows,
                "columns": [c.to_json() for c in self.columns]}

    @staticmethod
    def from_json(o: dict) -> "RowGroupMeta":
        return RowGroupMeta(o["n_rows"],
                            [ChunkMeta.from_json(c) for c in o["columns"]])


@dataclasses.dataclass
class FileMeta:
    schema: Schema
    num_rows: int
    row_groups: list[RowGroupMeta]
    logical_nbytes: int       # raw decoded size — effective-bw numerator
    writer_config: dict       # provenance: the FileConfig that produced this

    def to_json_bytes(self) -> bytes:
        return json.dumps({
            "schema": self.schema.to_json(),
            "num_rows": self.num_rows,
            "row_groups": [rg.to_json() for rg in self.row_groups],
            "logical_nbytes": self.logical_nbytes,
            "writer_config": self.writer_config,
        }).encode("utf-8")

    @staticmethod
    def from_json_bytes(b: bytes) -> "FileMeta":
        o = json.loads(b.decode("utf-8"))
        return FileMeta(
            schema=Schema.from_json(o["schema"]),
            num_rows=o["num_rows"],
            row_groups=[RowGroupMeta.from_json(rg) for rg in o["row_groups"]],
            logical_nbytes=o["logical_nbytes"],
            writer_config=o["writer_config"],
        )

    @property
    def stored_bytes(self) -> int:
        return sum(c.stored_bytes for rg in self.row_groups
                   for c in rg.columns)

    def describe(self) -> dict:
        """Summary used by benchmarks/EXPERIMENTS.md."""
        enc_hist: dict = {}
        codec_hist: dict = {}
        n_pages = 0
        for rg in self.row_groups:
            for c in rg.columns:
                enc_hist[Encoding(c.encoding).name] = (
                    enc_hist.get(Encoding(c.encoding).name, 0) + 1)
                codec_hist[Codec(c.codec).name] = (
                    codec_hist.get(Codec(c.codec).name, 0) + 1)
                n_pages += len(c.pages)
        return {
            "num_rows": self.num_rows,
            "n_row_groups": len(self.row_groups),
            "n_pages": n_pages,
            "stored_bytes": self.stored_bytes,
            "logical_nbytes": self.logical_nbytes,
            "compression_ratio": (self.logical_nbytes
                                  / max(1, self.stored_bytes)),
            "encodings": enc_hist,
            "codecs": codec_hist,
        }
