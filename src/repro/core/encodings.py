"""Parquet V1/V2 encodings: host encoders/decoders + smallest-wins selection.

Insight 3 of the paper: most writers pin one V1 encoding per column; letting
every column *chunk* pick the smallest among all spec-valid candidates (V1
and V2) shrinks the bytes the storage path must move, which is what effective
bandwidth is made of.  The candidate set per physical type is < 5, so the
paper (and we) simply try them all.

Encodings implemented (ids match parquet.thrift where they exist):
  PLAIN(0)                 all types
  RLE(3)                   bool + integer runs
  DELTA_BINARY_PACKED(5)   int32/int64 (V2)
  DELTA_LENGTH_BYTE_ARRAY(6) strings (V2)
  RLE_DICTIONARY(8)        all types (chunk-level dictionary page)
  BYTE_STREAM_SPLIT(9)     float/double (V2)

All payloads are 4-byte aligned, varint-free (DESIGN.md §2): tiny headers are
parsed on host into *page manifests*; the bulk bit-packed payload is what the
Pallas kernels consume.
"""

from __future__ import annotations

import dataclasses
import enum
from collections.abc import Sequence

import numpy as np

from repro.core import bitpack
from repro.core.config import EncodingPolicy, FileConfig
from repro.core.schema import Field, PhysicalType
from repro.core.table import StringColumn

BLOCK = 1024           # values per DELTA block
MINIBLOCKS = 4         # miniblocks per block
MB_VALUES = BLOCK // MINIBLOCKS  # 256 values per miniblock
MB_GROUPS = MB_VALUES // bitpack.GROUP  # 8 packing groups per miniblock


class Encoding(enum.IntEnum):
    PLAIN = 0
    RLE = 3
    DELTA_BINARY_PACKED = 5
    DELTA_LENGTH_BYTE_ARRAY = 6
    RLE_DICTIONARY = 8
    BYTE_STREAM_SPLIT = 9


@dataclasses.dataclass
class EncodedPage:
    payload: bytes          # 4-byte aligned
    n_values: int
    extra: dict             # JSON-safe metadata required for decode

    @property
    def nbytes(self) -> int:
        return len(self.payload)


@dataclasses.dataclass
class ChunkEncoding:
    encoding: Encoding
    pages: list[EncodedPage]
    dict_page: EncodedPage | None = None

    @property
    def total_bytes(self) -> int:
        n = sum(p.nbytes for p in self.pages)
        if self.dict_page is not None:
            n += self.dict_page.nbytes
        return n


Values = np.ndarray | StringColumn


def _pad4(b: bytes) -> bytes:
    pad = (-len(b)) % 4
    return b + b"\x00" * pad


def _slice(values: Values, s: int, e: int) -> Values:
    if isinstance(values, StringColumn):
        return values.slice(s, e)
    return values[s:e]


def _n(values: Values) -> int:
    return len(values) if isinstance(values, StringColumn) else values.shape[0]


# ---------------------------------------------------------------------------
# PLAIN
# ---------------------------------------------------------------------------

def encode_plain_page(values: Values, field: Field) -> EncodedPage:
    if field.physical == PhysicalType.BYTE_ARRAY:
        assert isinstance(values, StringColumn)
        offsets = values.offsets.astype(np.int32)
        body = offsets.tobytes() + values.payload.tobytes()
        return EncodedPage(_pad4(body), len(values),
                           {"payload_len": int(values.payload.shape[0])})
    arr = np.ascontiguousarray(values)
    if field.physical == PhysicalType.BOOLEAN:
        arr = arr.astype(np.uint8)
    return EncodedPage(_pad4(arr.tobytes()), arr.shape[0], {})


def decode_plain_page(payload: bytes, n: int, field: Field,
                      extra: dict) -> Values:
    if field.physical == PhysicalType.BYTE_ARRAY:
        offsets = np.frombuffer(payload, dtype=np.int32, count=n + 1)
        plen = extra["payload_len"]
        start = (n + 1) * 4
        data = np.frombuffer(payload, dtype=np.uint8,
                             count=plen, offset=start).copy()
        return StringColumn(offsets.astype(np.int64), data)
    if field.physical == PhysicalType.BOOLEAN:
        return np.frombuffer(payload, dtype=np.uint8, count=n).astype(np.bool_)
    dt = field.numpy_dtype
    return np.frombuffer(payload, dtype=dt, count=n).copy()


# ---------------------------------------------------------------------------
# DELTA_BINARY_PACKED (V2) — block 1024, 4 miniblocks, bit-transposed packing
# ---------------------------------------------------------------------------

def _bit_widths_of(maxv: np.ndarray) -> np.ndarray:
    """Vectorized bit_length (≥1) for a small uint64 array."""
    out = np.ones(maxv.shape, dtype=np.int64)
    v = maxv.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        big = v >= (np.uint64(1) << np.uint64(shift))
        out[big] += shift
        v = np.where(big, v >> np.uint64(shift), v)
    return out


def _delta_encode_ints(values: np.ndarray) -> tuple[bytes, dict]:
    """Vectorized across blocks: miniblocks grouped by bit-width so each
    distinct width packs in one numpy pass."""
    n = values.shape[0]
    first = int(values[0]) if n else 0
    work = values.astype(np.int64, copy=False)
    deltas = np.diff(work) if n > 1 else np.zeros(0, dtype=np.int64)
    n_deltas = deltas.shape[0]
    n_blocks = max(0, -(-n_deltas // BLOCK))
    if n_blocks == 0:
        return b"", {"first_value": first, "n_blocks": 0}
    padded = np.zeros(n_blocks * BLOCK, dtype=np.int64)
    padded[:n_deltas] = deltas
    blocks = padded.reshape(n_blocks, BLOCK)
    min_delta = blocks.min(axis=1)
    rel = (blocks - min_delta[:, None]).astype(np.uint64)
    mbs = rel.reshape(n_blocks * MINIBLOCKS, MB_VALUES)
    widths = _bit_widths_of(mbs.max(axis=1))            # (n_mb,)
    packed: dict = {}
    for w in np.unique(widths):
        sel = np.flatnonzero(widths == w)
        words = bitpack.pack(mbs[sel].reshape(-1), int(w))
        packed[int(w)] = dict(zip(
            sel.tolist(),
            words.reshape(sel.shape[0], MB_GROUPS * int(w))))
    out = bytearray()
    for b in range(n_blocks):
        out += np.int64(min_delta[b]).tobytes()         # 8 bytes
        ws = widths[b * MINIBLOCKS:(b + 1) * MINIBLOCKS]
        out += bytes(int(x) for x in ws)                # 4 bytes (u8 x 4)
        for m in range(MINIBLOCKS):
            i = b * MINIBLOCKS + m
            out += packed[int(widths[i])][i].tobytes()
    return bytes(_pad4(bytes(out))), {"first_value": first,
                                      "n_blocks": n_blocks}


def encode_delta_page(values: np.ndarray, field: Field) -> EncodedPage:
    if field.physical not in (PhysicalType.INT32, PhysicalType.INT64):
        raise TypeError("DELTA_BINARY_PACKED is for integers")
    payload, extra = _delta_encode_ints(np.ascontiguousarray(values))
    return EncodedPage(payload, values.shape[0], extra)


def build_delta_manifest(payload: bytes, n_values: int, extra: dict) -> dict:
    """Host header pass → flat manifest arrays for device decode.

    Returns dict with:
      mb_off   int32 (n_blocks*4,)  word offset of each miniblock's packed data
      mb_width int32 (n_blocks*4,)
      min_delta int64 (n_blocks,)
      first_value int
    """
    n_blocks = extra["n_blocks"]
    words = np.frombuffer(payload, dtype=np.uint32)
    mb_off = np.zeros(n_blocks * MINIBLOCKS, dtype=np.int32)
    mb_width = np.zeros(n_blocks * MINIBLOCKS, dtype=np.int32)
    min_delta = np.zeros(max(n_blocks, 1), dtype=np.int64)
    pos = 0  # in words
    for b in range(n_blocks):
        min_delta[b] = np.frombuffer(
            payload, dtype=np.int64, count=1, offset=pos * 4)[0]
        wbytes = np.frombuffer(
            payload, dtype=np.uint8, count=4, offset=pos * 4 + 8)
        pos += 3  # 8B min_delta + 4B widths
        for m in range(MINIBLOCKS):
            w = int(wbytes[m])
            mb_off[b * MINIBLOCKS + m] = pos
            mb_width[b * MINIBLOCKS + m] = w
            pos += MB_GROUPS * w
    return {"mb_off": mb_off, "mb_width": mb_width, "min_delta": min_delta,
            "first_value": int(extra["first_value"]), "words": words,
            "n_blocks": n_blocks, "n_values": n_values}


def decode_delta_page(payload: bytes, n: int, field: Field,
                      extra: dict) -> np.ndarray:
    man = build_delta_manifest(payload, n, extra)
    n_blocks = man["n_blocks"]
    words = man["words"]
    n_mb = n_blocks * MINIBLOCKS
    rel = np.zeros((max(n_mb, 1), MB_VALUES), dtype=np.uint64)
    widths = man["mb_width"]
    offs = man["mb_off"]
    for w in np.unique(widths[:n_mb]) if n_mb else []:
        w = int(w)
        sel = np.flatnonzero(widths[:n_mb] == w)
        idx = offs[sel][:, None] + np.arange(MB_GROUPS * w)[None, :]
        gathered = words[idx]                      # (k, 8w) fancy gather
        vals = bitpack.unpack(gathered.reshape(-1), w,
                              sel.shape[0] * MB_VALUES)
        rel[sel] = vals.reshape(sel.shape[0], MB_VALUES)
    deltas = rel.reshape(-1)[:n_blocks * BLOCK].astype(np.int64)
    deltas += np.repeat(man["min_delta"][:n_blocks], BLOCK)
    out = np.empty(n, dtype=np.int64)
    if n:
        out[0] = man["first_value"]
        if n > 1:
            np.cumsum(deltas[:n - 1], out=out[1:])
            out[1:] += man["first_value"]
    return out.astype(field.numpy_dtype)


# ---------------------------------------------------------------------------
# RLE — runs of identical values
# ---------------------------------------------------------------------------

def encode_rle_page(values: np.ndarray, field: Field) -> EncodedPage:
    arr = np.ascontiguousarray(values)
    if field.physical == PhysicalType.BOOLEAN:
        arr = arr.astype(np.int32)
    n = arr.shape[0]
    if n == 0:
        return EncodedPage(b"", 0, {"n_runs": 0})
    change = np.flatnonzero(arr[1:] != arr[:-1]) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    run_vals = arr[starts]
    run_counts = (ends - starts).astype(np.int32)
    vdt = np.int64 if field.physical == PhysicalType.INT64 else np.int32
    body = run_vals.astype(vdt).tobytes() + run_counts.tobytes()
    return EncodedPage(_pad4(body), n, {"n_runs": int(run_vals.shape[0])})


def decode_rle_page(payload: bytes, n: int, field: Field,
                    extra: dict) -> np.ndarray:
    r = extra["n_runs"]
    if r == 0:
        dt = (np.bool_ if field.physical == PhysicalType.BOOLEAN
              else field.numpy_dtype)
        return np.zeros(0, dtype=dt)
    vdt = np.int64 if field.physical == PhysicalType.INT64 else np.int32
    vals = np.frombuffer(payload, dtype=vdt, count=r)
    counts = np.frombuffer(payload, dtype=np.int32, count=r,
                           offset=r * np.dtype(vdt).itemsize)
    out = np.repeat(vals, counts)
    assert out.shape[0] == n, (out.shape, n)
    if field.physical == PhysicalType.BOOLEAN:
        return out.astype(np.bool_)
    return out.astype(field.numpy_dtype)


# ---------------------------------------------------------------------------
# BYTE_STREAM_SPLIT (V2) — float/double
# ---------------------------------------------------------------------------

def encode_bss_page(values: np.ndarray, field: Field) -> EncodedPage:
    arr = np.ascontiguousarray(values)
    k = arr.dtype.itemsize
    streams = arr.view(np.uint8).reshape(arr.shape[0], k)
    body = b"".join(_pad4(streams[:, s].tobytes()) for s in range(k))
    return EncodedPage(body, arr.shape[0], {"itemsize": k})


def decode_bss_page(payload: bytes, n: int, field: Field,
                    extra: dict) -> np.ndarray:
    k = extra["itemsize"]
    stride = n + ((-n) % 4)
    out = np.empty((n, k), dtype=np.uint8)
    for s in range(k):
        out[:, s] = np.frombuffer(payload, dtype=np.uint8, count=n,
                                  offset=s * stride)
    return out.reshape(-1).view(field.numpy_dtype)[:n].copy()


# ---------------------------------------------------------------------------
# DELTA_LENGTH_BYTE_ARRAY (V2) — strings
# ---------------------------------------------------------------------------

def encode_dlba_page(values: StringColumn, field: Field) -> EncodedPage:
    lengths = values.lengths().astype(np.int64)
    lp, lextra = _delta_encode_ints(lengths)
    body = lp + _pad4(values.payload.tobytes())
    return EncodedPage(body, len(values),
                       {"lengths_extra": lextra, "lengths_size": len(lp),
                        "payload_len": int(values.payload.shape[0])})


def decode_dlba_page(payload: bytes, n: int, field: Field,
                     extra: dict) -> StringColumn:
    lsize = extra["lengths_size"]
    lf = Field("_lengths", PhysicalType.INT64)
    lengths = decode_delta_page(payload[:lsize], n, lf,
                                extra["lengths_extra"])
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lengths, out=offsets[1:])
    data = np.frombuffer(payload, dtype=np.uint8, count=extra["payload_len"],
                         offset=lsize).copy()
    return StringColumn(offsets, data)


# ---------------------------------------------------------------------------
# RLE_DICTIONARY (chunk-level)
# ---------------------------------------------------------------------------

def _unique_with_codes(values: Values) -> tuple[Values, np.ndarray]:
    if isinstance(values, StringColumn):
        table: dict[bytes, int] = {}
        codes = np.empty(len(values), dtype=np.int64)
        order: list[bytes] = []
        for i, b in enumerate(values.to_pylist()):
            code = table.get(b)
            if code is None:
                code = len(order)
                table[b] = code
                order.append(b)
            codes[i] = code
        return StringColumn.from_pylist(order), codes
    uniq, codes = np.unique(np.ascontiguousarray(values),
                            return_inverse=True)
    return uniq, codes.astype(np.int64)


def encode_dict_chunk(values: Values, field: Field,
                      page_slices: Sequence[tuple[int, int]],
                      max_dict_fraction: float) -> ChunkEncoding | None:
    n = _n(values)
    uniq, codes = _unique_with_codes(values)
    n_dict = _n(uniq)
    if n == 0 or n_dict > max(1, int(max_dict_fraction * n)):
        return None
    dict_page = encode_plain_page(uniq, field)
    width = bitpack.bit_width(max(1, n_dict - 1))
    pages = []
    for (s, e) in page_slices:
        packed = bitpack.pack(codes[s:e].astype(np.uint64), width)
        pages.append(EncodedPage(packed.tobytes(), e - s,
                                 {"bitwidth": width, "n_dict": n_dict}))
    return ChunkEncoding(Encoding.RLE_DICTIONARY, pages, dict_page)


def decode_dict_page(payload: bytes, n: int, field: Field, extra: dict,
                     dictionary: Values) -> Values:
    width = extra["bitwidth"]
    words = np.frombuffer(payload, dtype=np.uint32)
    codes = bitpack.unpack(words, width, n, out_dtype=np.int64)
    if isinstance(dictionary, StringColumn):
        return dictionary.take(codes)
    return np.ascontiguousarray(dictionary)[codes]


# ---------------------------------------------------------------------------
# Candidate sets + chunk encode/decode entry points (Insight 3)
# ---------------------------------------------------------------------------

_INT_TYPES = (PhysicalType.INT32, PhysicalType.INT64)
_FLOAT_TYPES = (PhysicalType.FLOAT, PhysicalType.DOUBLE)


def candidate_encodings(field: Field, policy: EncodingPolicy,
                        allow_dict: bool = True) -> list[Encoding]:
    if policy == EncodingPolicy.PLAIN_ONLY:
        return [Encoding.PLAIN]
    if policy == EncodingPolicy.V1_ONLY:
        cands = [Encoding.PLAIN]
        if allow_dict:
            cands.append(Encoding.RLE_DICTIONARY)
        return cands
    # FLEX — every spec-valid candidate for the type (< 5 per the paper)
    if field.physical in _INT_TYPES:
        cands = [Encoding.PLAIN, Encoding.DELTA_BINARY_PACKED, Encoding.RLE]
        if allow_dict:
            cands.insert(1, Encoding.RLE_DICTIONARY)
        return cands
    if field.physical in _FLOAT_TYPES:
        cands = [Encoding.PLAIN, Encoding.BYTE_STREAM_SPLIT]
        if allow_dict:
            cands.insert(1, Encoding.RLE_DICTIONARY)
        return cands
    if field.physical == PhysicalType.BOOLEAN:
        return [Encoding.PLAIN, Encoding.RLE]
    if field.physical == PhysicalType.BYTE_ARRAY:
        cands = [Encoding.PLAIN, Encoding.DELTA_LENGTH_BYTE_ARRAY]
        if allow_dict:
            cands.insert(1, Encoding.RLE_DICTIONARY)
        return cands
    raise TypeError(field.physical)


_PAGE_ENCODERS = {
    Encoding.PLAIN: encode_plain_page,
    Encoding.DELTA_BINARY_PACKED: encode_delta_page,
    Encoding.RLE: encode_rle_page,
    Encoding.BYTE_STREAM_SPLIT: encode_bss_page,
    Encoding.DELTA_LENGTH_BYTE_ARRAY: encode_dlba_page,
}

_PAGE_DECODERS = {
    Encoding.PLAIN: decode_plain_page,
    Encoding.DELTA_BINARY_PACKED: decode_delta_page,
    Encoding.RLE: decode_rle_page,
    Encoding.BYTE_STREAM_SPLIT: decode_bss_page,
    Encoding.DELTA_LENGTH_BYTE_ARRAY: decode_dlba_page,
}


def encode_chunk_with(encoding: Encoding, values: Values, field: Field,
                      page_slices: Sequence[tuple[int, int]],
                      max_dict_fraction: float = 1.0
                      ) -> ChunkEncoding | None:
    """Encode one column chunk with a specific encoding (None if invalid)."""
    if encoding == Encoding.RLE_DICTIONARY:
        return encode_dict_chunk(values, field, page_slices,
                                 max_dict_fraction)
    enc = _PAGE_ENCODERS[encoding]
    try:
        pages = [enc(_slice(values, s, e), field) for (s, e) in page_slices]
    except TypeError:
        return None
    return ChunkEncoding(encoding, pages)


def select_chunk_encoding(values: Values, field: Field,
                          page_slices: Sequence[tuple[int, int]],
                          config: FileConfig) -> ChunkEncoding:
    """Insight 3: try every candidate, keep the smallest encoded size."""
    allow_dict = field.name not in set(config.no_dict_columns)
    cands = candidate_encodings(field, config.encodings, allow_dict)
    best: ChunkEncoding | None = None
    for c in cands:
        ce = encode_chunk_with(c, values, field, page_slices,
                               config.max_dict_fraction)
        if ce is None:
            continue
        if best is None or ce.total_bytes < best.total_bytes:
            best = ce
    assert best is not None, "PLAIN always succeeds"
    return best


def decode_page(encoding: Encoding, payload: bytes, n: int, field: Field,
                extra: dict, dictionary: Values | None = None) -> Values:
    if encoding == Encoding.RLE_DICTIONARY:
        assert dictionary is not None
        return decode_dict_page(payload, n, field, extra, dictionary)
    return _PAGE_DECODERS[encoding](payload, n, field, extra)
