"""Query operators over scans: TPC-H Q6 (filter+agg) and Q12 (join).

These are the paper's §4 query-level validation workloads.  Both consume
row groups streamed by the overlap executor, so file-level configuration
gains translate to query runtime exactly as in Fig. 5.

Both also accept a **Dataset** (repro.dataset) in place of a Scanner:
the scan is then planned over the manifest (partition + file-level
zone-map pruning with the same stats contract the row-group pruner
uses) and executed as sharded fragment scans through the shared
ScanService — the "data-lake" path where file pruning and cooperative
multi-scan scheduling compound with the paper's single-file config
gains.  Per-fragment partial results reduce in plan order, so pruned
and unpruned runs are bit-identical.

Dates are int32 days since 1992-01-01 (DATE logical type).
"""

from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fused import (FUSED_KEY, Compare, FusedSpec, Interval,
                              SumProduct)
from repro.core.overlap import RunReport, run_blocking, run_overlapped
from repro.core.scan import Scanner
from repro.kernels.filter_agg import TILE, filter_agg_q6

D_1994_01_01 = 731
D_1995_01_01 = 1096

Q6_COLUMNS = ["l_shipdate", "l_discount", "l_quantity", "l_extendedprice"]
Q12_LINEITEM_COLUMNS = ["l_orderkey", "l_shipmode", "l_shipdate",
                        "l_commitdate", "l_receiptdate"]
Q12_ORDERS_COLUMNS = ["o_orderkey", "o_orderpriority"]


def _dev(x):
    return jnp.asarray(np.asarray(x))


def _is_dataset(source) -> bool:
    """Duck-typed Dataset check (no repro.dataset import on the scan-only
    path): a manifest-backed source exposes fragments + partitioning."""
    return hasattr(source, "fragments") and hasattr(source, "partitioning")


def _resolve_fused(fused: "bool | str | None") -> "bool | str":
    """``fused=`` resolution shared by q6/q12: None defers to the
    ``REPRO_FUSED`` env (the CI matrix leg), "reference" selects the
    unfused bit-identity twin (full materialization, canonical reduce)."""
    if fused is None:
        return os.environ.get("REPRO_FUSED", "0") == "1"
    return fused


# ---------------------------------------------------------------------------
# Q6 — SELECT sum(l_extendedprice*l_discount) WHERE shipdate in FY1994
#       AND discount BETWEEN 0.05 AND 0.07 AND quantity < 24
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _q6_jnp(ship, disc, qty, price):
    mask = ((ship >= D_1994_01_01) & (ship < D_1995_01_01)
            & (disc >= jnp.float32(0.05)) & (disc <= jnp.float32(0.07))
            & (qty < jnp.float32(24.0)))
    return jnp.sum(jnp.where(mask, price * disc, jnp.float32(0)))


def q6_rg_stats_predicate(name: str, stats: dict) -> bool:
    """Zone-map pruning: skip row groups whose shipdate range misses FY94."""
    if name == "l_shipdate":
        return stats["min"] < D_1995_01_01 and stats["max"] >= D_1994_01_01
    return True


def q6_fused_spec(mode: str = "fused") -> FusedSpec:
    """Q6 as a FusedSpec: shipdate interval stays in stage A (DELTA-coded
    → not kernel-fusable), discount/quantity intervals and the
    price×discount aggregate fuse into one stage-B launch per row group
    (constants cast to float32 in-kernel — same bits as ``_q6_jnp``)."""
    return FusedSpec(
        predicates=(Interval("l_shipdate", lo=D_1994_01_01,
                             hi=D_1995_01_01),
                    Interval("l_discount", lo=0.05, hi=0.07, hi_incl=True),
                    Interval("l_quantity", hi=24.0)),
        agg=SumProduct("l_extendedprice", "l_discount"),
        mode=mode)


def _q6_consume_fused(use_kernel: bool):
    """Sums the canonical per-RG fused partials in plan order.  Falls back
    to the legacy consume when a row group arrives without a fused result
    (use_plan=False scanners, instance-patched decode paths)."""
    legacy = _q6_consume(use_kernel)

    def consume(acc, rg_index, cols):
        res = cols.get(FUSED_KEY)
        if res is None:
            return legacy(acc, rg_index, cols)
        return res.partial if acc is None else acc + res.partial

    return consume


def _q6_consume(use_kernel: bool):
    def consume(acc, rg_index, cols):
        ship = _dev(cols["l_shipdate"].array).astype(jnp.int32)
        disc = _dev(cols["l_discount"].array).astype(jnp.float32)
        qty = _dev(cols["l_quantity"].array).astype(jnp.float32)
        price = _dev(cols["l_extendedprice"].array).astype(jnp.float32)
        if use_kernel:
            n = ship.shape[0]
            pad = (-n) % TILE
            if pad:
                ship = jnp.pad(ship, (0, pad),
                               constant_values=np.iinfo(np.int32).max)
                disc = jnp.pad(disc, (0, pad))
                qty = jnp.pad(qty, (0, pad))
                price = jnp.pad(price, (0, pad))
            part = filter_agg_q6(ship, qty, disc, price,
                                 lo=D_1994_01_01, hi=D_1995_01_01,
                                 dlo=0.05, dhi=0.07, qmax=24.0)
        else:
            part = _q6_jnp(ship, disc, qty, price)
        part = float(part)
        return part if acc is None else acc + part

    return consume


def q6(scanner: Scanner, overlapped: bool = True, use_kernel: bool = False,
       prune: bool = True, prepare_plan: bool = False, depth: int = 2,
       decode_workers: int | None = None, service=None,
       window: int = 4, open_opts: dict | None = None,
       fused: "bool | str | None" = None, devices=None,
       trace=None, tenant: str | None = None,
       result_cache=None) -> tuple[float, RunReport]:
    """Run Q6 over the scanner's stream — or over a whole **Dataset**
    (file-level pruning + sharded fragment scans; returns a
    ``DatasetRunReport``).  ``prepare_plan`` pre-builds the row-group
    decode plans before timing starts (the serving-loop case — plans are
    cached per file footer + column selection, so repeated queries always
    hit).  ``depth``/``decode_workers`` shape the pipelined executor
    (overlap.py); ``service`` selects a specific ScanService instead of
    the shared one; all three are ignored for blocking runs.
    ``window``/``open_opts`` apply to dataset runs only (fragment
    concurrency bound; ``Dataset.open_fragment`` storage options);
    dataset runs are always sharded (``overlapped=False`` raises) and
    ``prepare_plan`` is a no-op for them (per-fragment decode plans are
    cached on first scan).  ``fused`` selects late materialization
    (``True``/``"reference"``; ``None`` defers to ``REPRO_FUSED``):
    the decode plan stages predicate columns first and runs the
    filter+aggregate inside the scan (core/fused.py).  ``devices``
    (dataset runs only) routes fragments through the multi-device
    executor (``run_distributed_scan``): None keeps the windowed
    single-service path; an int or device list shards fragments across
    devices with the deterministic tree reduce — bit-identical across
    device counts.  ``trace`` enables the flight recorder for this run
    (core/trace.py, DESIGN.md §10): True records, a path string records
    and exports Chrome trace JSON.  ``tenant`` attributes the scan(s) to
    a ScanService tenant (weighted fair scheduling + admission,
    DESIGN.md §11); ``result_cache`` (dataset runs only) is a
    FragmentResultCache — repeated identical Q6 runs answer unchanged
    fragments from cached partials, invalidated on manifest swap."""
    fused = _resolve_fused(fused)
    spec = q6_fused_spec("reference" if fused == "reference"
                         else "fused") if fused else None
    consume = (_q6_consume_fused(use_kernel) if spec is not None
               else _q6_consume(use_kernel))
    if _is_dataset(scanner):
        if not overlapped:
            raise ValueError("dataset runs are always sharded/overlapped; "
                             "open a fragment Scanner for a blocking run")
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        plan = plan_dataset_scan(
            scanner, columns=list(Q6_COLUMNS),
            predicate_stats=q6_rg_stats_predicate if prune else None)
        if spec is not None:
            open_opts = dict(open_opts or {}, fused_spec=spec)
        if devices is not None:
            from repro.dataset.executor import run_distributed_scan
            acc, report = run_distributed_scan(
                plan, consume, lambda a, b: a + b,
                devices=devices, depth=depth,
                decode_workers=decode_workers, open_opts=open_opts,
                trace=trace)
            return (acc or 0.0), report
        fp = (f"q6:{'fused' if spec is not None else 'unfused'}:"
              f"{'ref' if fused == 'reference' else 'opt'}:"
              f"k{int(use_kernel)}:p{int(prune)}")
        acc, report = run_dataset_scan(
            plan, consume, lambda a, b: a + b,
            window=window, depth=depth, decode_workers=decode_workers,
            service=service, open_opts=open_opts, trace=trace,
            tenant=tenant, result_cache=result_cache, fingerprint=fp)
        return (acc or 0.0), report
    if spec is not None and scanner.planner is not None \
            and scanner.fused_spec != spec:
        scanner.enable_fused(spec)
    if prepare_plan:
        scanner.prepare_plans(
            predicate_stats=q6_rg_stats_predicate if prune else None)
    if overlapped:
        runner = functools.partial(run_overlapped, depth=depth,
                                   decode_workers=decode_workers,
                                   service=service, tenant=tenant)
    else:
        runner = run_blocking
    acc, report = runner(scanner, consume,
                         predicate_stats=(q6_rg_stats_predicate
                                          if prune else None),
                         trace=trace)
    return (acc or 0.0), report


def q6_reference(tables: dict[str, np.ndarray]) -> float:
    """Numpy oracle over raw columns."""
    ship, disc = tables["l_shipdate"], tables["l_discount"]
    qty, price = tables["l_quantity"], tables["l_extendedprice"]
    m = ((ship >= D_1994_01_01) & (ship < D_1995_01_01)
         & (disc >= np.float32(0.05)) & (disc <= np.float32(0.07))
         & (qty < 24))
    return float(np.sum(price[m].astype(np.float64)
                        * disc[m].astype(np.float64)))


# ---------------------------------------------------------------------------
# Q12 — lineitem ⋈ orders on orderkey; counts per shipmode split by
#        order priority (urgent/high vs other); FY1994 receipt dates
# ---------------------------------------------------------------------------

SHIPMODE_MAIL = 2
SHIPMODE_SHIP = 4


@jax.jit
def _q12_probe(skeys, sprio, okey, mode, ship, commit, receipt):
    mask = (((mode == SHIPMODE_MAIL) | (mode == SHIPMODE_SHIP))
            & (commit < receipt) & (ship < commit)
            & (receipt >= D_1994_01_01) & (receipt < D_1995_01_01))
    pos = jnp.clip(jnp.searchsorted(skeys, okey), 0, skeys.shape[0] - 1)
    hit = skeys[pos] == okey
    prio = sprio[pos]
    urgent = (prio <= 1) & hit & mask        # 1-URGENT / 2-HIGH
    other = (prio > 1) & hit & mask
    out = []
    for m in (SHIPMODE_MAIL, SHIPMODE_SHIP):
        sel = mode == m
        out.append(jnp.sum((urgent & sel).astype(jnp.int32)))
        out.append(jnp.sum((other & sel).astype(jnp.int32)))
    return jnp.stack(out)


def q12_fused_spec(mode: str = "fused") -> FusedSpec:
    """Q12's probe side as a selection-mode FusedSpec: every predicate and
    compare column evaluates in stage A, and the emit-only ``l_orderkey``
    is materialized late — only for row groups where any row survives the
    receipt-window + shipmode + date-ordering filter."""
    return FusedSpec(
        predicates=(Interval("l_receiptdate", lo=D_1994_01_01,
                             hi=D_1995_01_01),
                    Interval("l_shipmode",
                             in_set=(SHIPMODE_MAIL, SHIPMODE_SHIP))),
        compares=(Compare("l_commitdate", "l_receiptdate"),
                  Compare("l_shipdate", "l_commitdate")),
        emit=("l_orderkey", "l_shipmode"),
        mode=mode)


@jax.jit
def _q12_probe_selected(skeys, sprio, okey, mode):
    """Join probe over pre-selected rows (the fused path's selection
    vector already applied).  Padding rows carry okey=-1 (no order key
    matches) and mode=0 (neither shipmode), so they count nothing."""
    pos = jnp.clip(jnp.searchsorted(skeys, okey), 0, skeys.shape[0] - 1)
    hit = skeys[pos] == okey
    prio = sprio[pos]
    urgent = (prio <= 1) & hit
    other = (prio > 1) & hit
    out = []
    for m in (SHIPMODE_MAIL, SHIPMODE_SHIP):
        sel = mode == m
        out.append(jnp.sum((urgent & sel).astype(jnp.int32)))
        out.append(jnp.sum((other & sel).astype(jnp.int32)))
    return jnp.stack(out)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _source_digest(src) -> "str | None":
    """Content identity of a q12 side for result-cache fingerprints: a
    dataset's (root, generation), a file scanner's planner cache token
    (path + size + mtime); None → unknown, never cache against it."""
    if _is_dataset(src):
        return f"ds:{src.root}:g{src.generation}"
    tok = getattr(getattr(src, "planner", None), "cache_token", None)
    return None if tok is None else f"file:{tok}"


def q12(lineitem_scanner: Scanner, orders_scanner: Scanner,
        overlapped: bool = True, prepare_plan: bool = False,
        depth: int = 2, decode_workers: int | None = None,
        service=None, window: int = 4, open_opts: dict | None = None,
        fused: "bool | str | None" = None, devices=None,
        trace=None, tenant: str | None = None,
        result_cache=None) -> tuple[dict[str, int], RunReport, RunReport]:
    """Q12 over scanners — or over Datasets (either side independently):
    the build side streams every orders fragment, the probe side shards
    lineitem fragments through the ScanService, and per-fragment counts
    reduce in plan order.  Dataset sides are always sharded
    (``overlapped=False`` raises) and skip ``prepare_plan``.  ``fused``
    (``True``/``"reference"``/``None``→``REPRO_FUSED``) runs the probe
    side with late materialization: ``l_orderkey`` only materializes for
    row groups with surviving rows (core/fused.py).  ``devices`` routes
    dataset sides through ``run_distributed_scan`` (multi-device
    sharding + deterministic tree reduce).  ``trace`` records both the
    build and probe scans in one flight-recorder session (DESIGN.md
    §10); a path string also exports Chrome trace JSON on return.
    ``tenant``/``result_cache`` are the serving hooks (DESIGN.md §11):
    tenant attribution on every scan, and fragment-partial caching on
    dataset sides — the probe side's fingerprint carries the orders
    side's content identity, so a build-table change invalidates it."""
    if trace:
        from repro.core import trace as trace_mod
        with trace_mod.request(trace):
            return q12(lineitem_scanner, orders_scanner,
                       overlapped=overlapped, prepare_plan=prepare_plan,
                       depth=depth, decode_workers=decode_workers,
                       service=service, window=window,
                       open_opts=open_opts, fused=fused, devices=devices,
                       tenant=tenant, result_cache=result_cache)
    if not overlapped and (_is_dataset(lineitem_scanner)
                           or _is_dataset(orders_scanner)):
        raise ValueError("dataset runs are always sharded/overlapped; "
                         "open fragment Scanners for a blocking run")
    fused = _resolve_fused(fused)
    lspec = q12_fused_spec("reference" if fused == "reference"
                           else "fused") if fused else None
    if lspec is not None and not _is_dataset(lineitem_scanner) \
            and lineitem_scanner.planner is not None \
            and lineitem_scanner.fused_spec != lspec:
        lineitem_scanner.enable_fused(lspec)
    if prepare_plan and not _is_dataset(lineitem_scanner):
        lineitem_scanner.prepare_plans()
    if prepare_plan and not _is_dataset(orders_scanner):
        orders_scanner.prepare_plans()
    # Build side: stream orders, then sort once on device.
    def build_consume(acc, rg_index, cols):
        k = _dev(cols["o_orderkey"].array).astype(jnp.int32)
        p = _dev(cols["o_orderpriority"].array).astype(jnp.int32)
        return (k, p) if acc is None else (jnp.concatenate([acc[0], k]),
                                           jnp.concatenate([acc[1], p]))

    if overlapped:
        runner = functools.partial(run_overlapped, depth=depth,
                                   decode_workers=decode_workers,
                                   service=service, tenant=tenant)
    else:
        runner = run_blocking

    if _is_dataset(orders_scanner):
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        oplan = plan_dataset_scan(orders_scanner,
                                  columns=list(Q12_ORDERS_COLUMNS))
        build_combine = (lambda a, b: (jnp.concatenate([a[0], b[0]]),
                                       jnp.concatenate([a[1], b[1]])))
        if devices is not None:
            # concatenation is exactly associative, so the tree pairing
            # yields the same build table the left fold would
            from repro.dataset.executor import run_distributed_scan
            (keys, prio), build_report = run_distributed_scan(
                oplan, build_consume, build_combine,
                devices=devices, depth=depth,
                decode_workers=decode_workers, open_opts=open_opts)
        else:
            (keys, prio), build_report = run_dataset_scan(
                oplan, build_consume, build_combine,
                window=window, depth=depth, decode_workers=decode_workers,
                service=service, open_opts=open_opts, tenant=tenant,
                result_cache=result_cache, fingerprint="q12:build")
    else:
        (keys, prio), build_report = runner(orders_scanner, build_consume)
    order = jnp.argsort(keys)
    skeys, sprio = keys[order], prio[order]

    def probe_consume(acc, rg_index, cols):
        fres = cols.get(FUSED_KEY) if lspec is not None else None
        if fres is not None:
            # fused path: the selection already applied every predicate —
            # probe only the surviving (okey, shipmode) pairs, padded to a
            # pow2 (okey=-1 / mode=0 rows count nothing)
            okey = fres.gathered["l_orderkey"]
            shipmode = fres.gathered["l_shipmode"]
            n = int(okey.shape[0])
            if n == 0:
                part = jnp.zeros(4, jnp.int32)
            else:
                cap = max(32, _next_pow2(n))
                ok = np.full(cap, -1, dtype=np.int64)
                ok[:n] = okey
                md = np.zeros(cap, dtype=np.int64)
                md[:n] = shipmode
                part = _q12_probe_selected(
                    skeys, sprio, jnp.asarray(ok.astype(np.int32)),
                    jnp.asarray(md.astype(np.int32)))
            return part if acc is None else acc + part
        part = _q12_probe(
            skeys, sprio,
            _dev(cols["l_orderkey"].array).astype(jnp.int32),
            _dev(cols["l_shipmode"].array).astype(jnp.int32),
            _dev(cols["l_shipdate"].array).astype(jnp.int32),
            _dev(cols["l_commitdate"].array).astype(jnp.int32),
            _dev(cols["l_receiptdate"].array).astype(jnp.int32))
        return part if acc is None else acc + part

    if _is_dataset(lineitem_scanner):
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        lplan = plan_dataset_scan(lineitem_scanner,
                                  columns=list(Q12_LINEITEM_COLUMNS))
        l_open_opts = open_opts
        if lspec is not None:
            l_open_opts = dict(open_opts or {}, fused_spec=lspec)
        if devices is not None:
            from repro.dataset.executor import run_distributed_scan
            counts, probe_report = run_distributed_scan(
                lplan, probe_consume, lambda a, b: a + b,
                devices=devices, depth=depth,
                decode_workers=decode_workers, open_opts=l_open_opts)
        else:
            # the probe partial depends on the build table, so its
            # fingerprint carries the orders side's content identity —
            # an orders change invalidates probe entries even when the
            # lineitem dataset is untouched
            odig = _source_digest(orders_scanner)
            lfp = (None if odig is None else
                   f"q12:probe:{'fused' if lspec else 'unfused'}:{odig}")
            counts, probe_report = run_dataset_scan(
                lplan, probe_consume, lambda a, b: a + b,
                window=window, depth=depth, decode_workers=decode_workers,
                service=service, open_opts=l_open_opts, tenant=tenant,
                result_cache=result_cache, fingerprint=lfp)
    else:
        counts, probe_report = runner(lineitem_scanner, probe_consume)
    counts = np.asarray(counts)
    result = {
        "MAIL_high": int(counts[0]), "MAIL_low": int(counts[1]),
        "SHIP_high": int(counts[2]), "SHIP_low": int(counts[3]),
    }
    return result, build_report, probe_report


def q12_reference(line: dict[str, np.ndarray],
                  orders: dict[str, np.ndarray]) -> dict[str, int]:
    ok = orders["o_orderkey"].astype(np.int64)
    op = orders["o_orderpriority"]
    pr = dict(zip(ok.tolist(), op.tolist()))
    mode = line["l_shipmode"]
    mask = (np.isin(mode, [SHIPMODE_MAIL, SHIPMODE_SHIP])
            & (line["l_commitdate"] < line["l_receiptdate"])
            & (line["l_shipdate"] < line["l_commitdate"])
            & (line["l_receiptdate"] >= D_1994_01_01)
            & (line["l_receiptdate"] < D_1995_01_01))
    out = {"MAIL_high": 0, "MAIL_low": 0, "SHIP_high": 0, "SHIP_low": 0}
    names = {SHIPMODE_MAIL: "MAIL", SHIPMODE_SHIP: "SHIP"}
    for i in np.flatnonzero(mask):
        p = pr[int(line["l_orderkey"][i])]
        key = names[int(mode[i])] + ("_high" if p <= 1 else "_low")
        out[key] += 1
    return out
