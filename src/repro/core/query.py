"""Query operators over scans: TPC-H Q6 (filter+agg) and Q12 (join).

These are the paper's §4 query-level validation workloads.  Both consume
row groups streamed by the overlap executor, so file-level configuration
gains translate to query runtime exactly as in Fig. 5.

Both also accept a **Dataset** (repro.dataset) in place of a Scanner:
the scan is then planned over the manifest (partition + file-level
zone-map pruning with the same stats contract the row-group pruner
uses) and executed as sharded fragment scans through the shared
ScanService — the "data-lake" path where file pruning and cooperative
multi-scan scheduling compound with the paper's single-file config
gains.  Per-fragment partial results reduce in plan order, so pruned
and unpruned runs are bit-identical.

Dates are int32 days since 1992-01-01 (DATE logical type).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlap import RunReport, run_blocking, run_overlapped
from repro.core.scan import Scanner
from repro.kernels.filter_agg import TILE, filter_agg_q6

D_1994_01_01 = 731
D_1995_01_01 = 1096

Q6_COLUMNS = ["l_shipdate", "l_discount", "l_quantity", "l_extendedprice"]
Q12_LINEITEM_COLUMNS = ["l_orderkey", "l_shipmode", "l_shipdate",
                        "l_commitdate", "l_receiptdate"]
Q12_ORDERS_COLUMNS = ["o_orderkey", "o_orderpriority"]


def _dev(x):
    return jnp.asarray(np.asarray(x))


def _is_dataset(source) -> bool:
    """Duck-typed Dataset check (no repro.dataset import on the scan-only
    path): a manifest-backed source exposes fragments + partitioning."""
    return hasattr(source, "fragments") and hasattr(source, "partitioning")


# ---------------------------------------------------------------------------
# Q6 — SELECT sum(l_extendedprice*l_discount) WHERE shipdate in FY1994
#       AND discount BETWEEN 0.05 AND 0.07 AND quantity < 24
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _q6_jnp(ship, disc, qty, price):
    mask = ((ship >= D_1994_01_01) & (ship < D_1995_01_01)
            & (disc >= jnp.float32(0.05)) & (disc <= jnp.float32(0.07))
            & (qty < jnp.float32(24.0)))
    return jnp.sum(jnp.where(mask, price * disc, jnp.float32(0)))


def q6_rg_stats_predicate(name: str, stats: dict) -> bool:
    """Zone-map pruning: skip row groups whose shipdate range misses FY94."""
    if name == "l_shipdate":
        return stats["min"] < D_1995_01_01 and stats["max"] >= D_1994_01_01
    return True


def _q6_consume(use_kernel: bool):
    def consume(acc, rg_index, cols):
        ship = _dev(cols["l_shipdate"].array).astype(jnp.int32)
        disc = _dev(cols["l_discount"].array).astype(jnp.float32)
        qty = _dev(cols["l_quantity"].array).astype(jnp.float32)
        price = _dev(cols["l_extendedprice"].array).astype(jnp.float32)
        if use_kernel:
            n = ship.shape[0]
            pad = (-n) % TILE
            if pad:
                ship = jnp.pad(ship, (0, pad),
                               constant_values=np.iinfo(np.int32).max)
                disc = jnp.pad(disc, (0, pad))
                qty = jnp.pad(qty, (0, pad))
                price = jnp.pad(price, (0, pad))
            part = filter_agg_q6(ship, qty, disc, price,
                                 lo=D_1994_01_01, hi=D_1995_01_01,
                                 dlo=0.05, dhi=0.07, qmax=24.0)
        else:
            part = _q6_jnp(ship, disc, qty, price)
        part = float(part)
        return part if acc is None else acc + part

    return consume


def q6(scanner: Scanner, overlapped: bool = True, use_kernel: bool = False,
       prune: bool = True, prepare_plan: bool = False, depth: int = 2,
       decode_workers: int | None = None, service=None,
       window: int = 4, open_opts: dict | None = None
       ) -> tuple[float, RunReport]:
    """Run Q6 over the scanner's stream — or over a whole **Dataset**
    (file-level pruning + sharded fragment scans; returns a
    ``DatasetRunReport``).  ``prepare_plan`` pre-builds the row-group
    decode plans before timing starts (the serving-loop case — plans are
    cached per file footer + column selection, so repeated queries always
    hit).  ``depth``/``decode_workers`` shape the pipelined executor
    (overlap.py); ``service`` selects a specific ScanService instead of
    the shared one; all three are ignored for blocking runs.
    ``window``/``open_opts`` apply to dataset runs only (fragment
    concurrency bound; ``Dataset.open_fragment`` storage options);
    dataset runs are always sharded (``overlapped=False`` raises) and
    ``prepare_plan`` is a no-op for them (per-fragment decode plans are
    cached on first scan)."""
    if _is_dataset(scanner):
        if not overlapped:
            raise ValueError("dataset runs are always sharded/overlapped; "
                             "open a fragment Scanner for a blocking run")
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        plan = plan_dataset_scan(
            scanner, columns=list(Q6_COLUMNS),
            predicate_stats=q6_rg_stats_predicate if prune else None)
        acc, report = run_dataset_scan(
            plan, _q6_consume(use_kernel), lambda a, b: a + b,
            window=window, depth=depth, decode_workers=decode_workers,
            service=service, open_opts=open_opts)
        return (acc or 0.0), report
    if prepare_plan:
        scanner.prepare_plans(
            predicate_stats=q6_rg_stats_predicate if prune else None)
    if overlapped:
        runner = functools.partial(run_overlapped, depth=depth,
                                   decode_workers=decode_workers,
                                   service=service)
    else:
        runner = run_blocking
    acc, report = runner(scanner, _q6_consume(use_kernel),
                         predicate_stats=(q6_rg_stats_predicate
                                          if prune else None))
    return (acc or 0.0), report


def q6_reference(tables: dict[str, np.ndarray]) -> float:
    """Numpy oracle over raw columns."""
    ship, disc = tables["l_shipdate"], tables["l_discount"]
    qty, price = tables["l_quantity"], tables["l_extendedprice"]
    m = ((ship >= D_1994_01_01) & (ship < D_1995_01_01)
         & (disc >= np.float32(0.05)) & (disc <= np.float32(0.07))
         & (qty < 24))
    return float(np.sum(price[m].astype(np.float64)
                        * disc[m].astype(np.float64)))


# ---------------------------------------------------------------------------
# Q12 — lineitem ⋈ orders on orderkey; counts per shipmode split by
#        order priority (urgent/high vs other); FY1994 receipt dates
# ---------------------------------------------------------------------------

SHIPMODE_MAIL = 2
SHIPMODE_SHIP = 4


@jax.jit
def _q12_probe(skeys, sprio, okey, mode, ship, commit, receipt):
    mask = (((mode == SHIPMODE_MAIL) | (mode == SHIPMODE_SHIP))
            & (commit < receipt) & (ship < commit)
            & (receipt >= D_1994_01_01) & (receipt < D_1995_01_01))
    pos = jnp.clip(jnp.searchsorted(skeys, okey), 0, skeys.shape[0] - 1)
    hit = skeys[pos] == okey
    prio = sprio[pos]
    urgent = (prio <= 1) & hit & mask        # 1-URGENT / 2-HIGH
    other = (prio > 1) & hit & mask
    out = []
    for m in (SHIPMODE_MAIL, SHIPMODE_SHIP):
        sel = mode == m
        out.append(jnp.sum((urgent & sel).astype(jnp.int32)))
        out.append(jnp.sum((other & sel).astype(jnp.int32)))
    return jnp.stack(out)


def q12(lineitem_scanner: Scanner, orders_scanner: Scanner,
        overlapped: bool = True, prepare_plan: bool = False,
        depth: int = 2, decode_workers: int | None = None,
        service=None, window: int = 4, open_opts: dict | None = None
        ) -> tuple[dict[str, int], RunReport, RunReport]:
    """Q12 over scanners — or over Datasets (either side independently):
    the build side streams every orders fragment, the probe side shards
    lineitem fragments through the ScanService, and per-fragment counts
    reduce in plan order.  Dataset sides are always sharded
    (``overlapped=False`` raises) and skip ``prepare_plan``."""
    if not overlapped and (_is_dataset(lineitem_scanner)
                           or _is_dataset(orders_scanner)):
        raise ValueError("dataset runs are always sharded/overlapped; "
                         "open fragment Scanners for a blocking run")
    if prepare_plan and not _is_dataset(lineitem_scanner):
        lineitem_scanner.prepare_plans()
    if prepare_plan and not _is_dataset(orders_scanner):
        orders_scanner.prepare_plans()
    # Build side: stream orders, then sort once on device.
    def build_consume(acc, rg_index, cols):
        k = _dev(cols["o_orderkey"].array).astype(jnp.int32)
        p = _dev(cols["o_orderpriority"].array).astype(jnp.int32)
        return (k, p) if acc is None else (jnp.concatenate([acc[0], k]),
                                           jnp.concatenate([acc[1], p]))

    if overlapped:
        runner = functools.partial(run_overlapped, depth=depth,
                                   decode_workers=decode_workers,
                                   service=service)
    else:
        runner = run_blocking

    if _is_dataset(orders_scanner):
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        oplan = plan_dataset_scan(orders_scanner,
                                  columns=list(Q12_ORDERS_COLUMNS))
        (keys, prio), build_report = run_dataset_scan(
            oplan, build_consume,
            lambda a, b: (jnp.concatenate([a[0], b[0]]),
                          jnp.concatenate([a[1], b[1]])),
            window=window, depth=depth, decode_workers=decode_workers,
            service=service, open_opts=open_opts)
    else:
        (keys, prio), build_report = runner(orders_scanner, build_consume)
    order = jnp.argsort(keys)
    skeys, sprio = keys[order], prio[order]

    def probe_consume(acc, rg_index, cols):
        part = _q12_probe(
            skeys, sprio,
            _dev(cols["l_orderkey"].array).astype(jnp.int32),
            _dev(cols["l_shipmode"].array).astype(jnp.int32),
            _dev(cols["l_shipdate"].array).astype(jnp.int32),
            _dev(cols["l_commitdate"].array).astype(jnp.int32),
            _dev(cols["l_receiptdate"].array).astype(jnp.int32))
        return part if acc is None else acc + part

    if _is_dataset(lineitem_scanner):
        from repro.dataset.executor import run_dataset_scan
        from repro.dataset.planner import plan_dataset_scan
        lplan = plan_dataset_scan(lineitem_scanner,
                                  columns=list(Q12_LINEITEM_COLUMNS))
        counts, probe_report = run_dataset_scan(
            lplan, probe_consume, lambda a, b: a + b,
            window=window, depth=depth, decode_workers=decode_workers,
            service=service, open_opts=open_opts)
    else:
        counts, probe_report = runner(lineitem_scanner, probe_consume)
    counts = np.asarray(counts)
    result = {
        "MAIL_high": int(counts[0]), "MAIL_low": int(counts[1]),
        "SHIP_high": int(counts[2]), "SHIP_low": int(counts[3]),
    }
    return result, build_report, probe_report


def q12_reference(line: dict[str, np.ndarray],
                  orders: dict[str, np.ndarray]) -> dict[str, int]:
    ok = orders["o_orderkey"].astype(np.int64)
    op = orders["o_orderpriority"]
    pr = dict(zip(ok.tolist(), op.tolist()))
    mode = line["l_shipmode"]
    mask = (np.isin(mode, [SHIPMODE_MAIL, SHIPMODE_SHIP])
            & (line["l_commitdate"] < line["l_receiptdate"])
            & (line["l_shipdate"] < line["l_commitdate"])
            & (line["l_receiptdate"] >= D_1994_01_01)
            & (line["l_receiptdate"] < D_1995_01_01))
    out = {"MAIL_high": 0, "MAIL_low": 0, "SHIP_high": 0, "SHIP_low": 0}
    names = {SHIPMODE_MAIL: "MAIL", SHIPMODE_SHIP: "SHIP"}
    for i in np.flatnonzero(mask):
        p = pr[int(line["l_orderkey"][i])]
        key = names[int(mode[i])] + ("_high" if p <= 1 else "_low")
        out[key] += 1
    return out
