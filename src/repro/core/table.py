"""Host-side column table: the in-memory object the writer/reader exchange."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schema import (Field, LogicalType, PhysicalType, Schema,
                               physical_of_numpy)


@dataclasses.dataclass
class StringColumn:
    """Arrow-style string column: int64 offsets (n+1) + utf-8 payload."""

    offsets: np.ndarray  # int64, shape (n+1,)
    payload: np.ndarray  # uint8, shape (offsets[-1],)

    def __post_init__(self) -> None:
        self.offsets = np.ascontiguousarray(self.offsets, dtype=np.int64)
        self.payload = np.ascontiguousarray(self.payload, dtype=np.uint8)
        if self.offsets.ndim != 1 or self.offsets[0] != 0:
            raise ValueError("offsets must be 1-D and start at 0")
        if int(self.offsets[-1]) != self.payload.shape[0]:
            raise ValueError("payload length mismatch with offsets")

    def __len__(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def nbytes(self) -> int:
        return int(self.offsets.nbytes + self.payload.nbytes)

    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int64)

    def to_pylist(self) -> list[bytes]:
        pay = self.payload.tobytes()
        off = self.offsets
        return [pay[off[i]:off[i + 1]] for i in range(len(self))]

    @staticmethod
    def from_pylist(values: list[str | bytes]) -> "StringColumn":
        bs = [v.encode("utf-8") if isinstance(v, str) else bytes(v)
              for v in values]
        lengths = np.fromiter((len(b) for b in bs), dtype=np.int64,
                              count=len(bs))
        offsets = np.zeros(len(bs) + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        payload = np.frombuffer(b"".join(bs), dtype=np.uint8).copy()
        return StringColumn(offsets, payload)

    def take(self, idx: np.ndarray) -> "StringColumn":
        lens = self.lengths()[idx]
        offsets = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lens, out=offsets[1:])
        out = np.empty(int(offsets[-1]), dtype=np.uint8)
        src_off = self.offsets
        pos = 0
        for j, i in enumerate(idx):
            a, b = int(src_off[i]), int(src_off[i + 1])
            out[pos:pos + (b - a)] = self.payload[a:b]
            pos += b - a
        return StringColumn(offsets, out)

    def slice(self, start: int, stop: int) -> "StringColumn":
        off = self.offsets[start:stop + 1]
        pay = self.payload[int(off[0]):int(off[-1])]
        return StringColumn(off - off[0], pay.copy())


ColumnData = np.ndarray | StringColumn


class Table:
    """An ordered mapping of column name -> data with a derived schema."""

    def __init__(self, columns: dict[str, ColumnData],
                 schema: Schema | None = None):
        if not columns:
            raise ValueError("empty table")
        self.columns: dict[str, ColumnData] = {}
        n = None
        for name, col in columns.items():
            if isinstance(col, StringColumn):
                self.columns[name] = col
                m = len(col)
            else:
                arr = np.ascontiguousarray(col)
                if arr.ndim != 1:
                    raise ValueError(f"column {name!r} must be 1-D")
                self.columns[name] = arr
                m = arr.shape[0]
            if n is None:
                n = m
            elif n != m:
                raise ValueError(
                    f"column {name!r} has {m} rows, expected {n}")
        self.num_rows = int(n)
        self.schema = schema if schema is not None else self._infer_schema()
        if set(self.schema.names) != set(self.columns):
            raise ValueError("schema names do not match columns")

    def _infer_schema(self) -> Schema:
        fields = []
        for name, col in self.columns.items():
            if isinstance(col, StringColumn):
                fields.append(Field(name, PhysicalType.BYTE_ARRAY,
                                    LogicalType.STRING))
            else:
                fields.append(Field(name, physical_of_numpy(col.dtype)))
        return Schema(fields)

    def __getitem__(self, name: str) -> ColumnData:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    @property
    def names(self) -> list[str]:
        return list(self.columns)

    @property
    def nbytes(self) -> int:
        """Logical raw size — the numerator of *effective bandwidth*."""
        return sum(int(c.nbytes) for c in self.columns.values())

    def select(self, names: list[str]) -> "Table":
        return Table({n: self.columns[n] for n in names},
                     Schema([self.schema.field(n) for n in names]))

    def slice(self, start: int, stop: int) -> "Table":
        stop = min(stop, self.num_rows)
        cols: dict[str, ColumnData] = {}
        for n, c in self.columns.items():
            cols[n] = (c.slice(start, stop) if isinstance(c, StringColumn)
                       else c[start:stop])
        return Table(cols, self.schema)

    def equals(self, other: "Table") -> bool:
        if self.names != other.names or self.num_rows != other.num_rows:
            return False
        for n in self.names:
            a, b = self.columns[n], other.columns[n]
            if isinstance(a, StringColumn) != isinstance(b, StringColumn):
                return False
            if isinstance(a, StringColumn):
                if not (np.array_equal(a.offsets, b.offsets)
                        and np.array_equal(a.payload, b.payload)):
                    return False
            else:
                if a.dtype != b.dtype or not np.array_equal(a, b):
                    return False
        return True

    @staticmethod
    def concat(tables: list["Table"]) -> "Table":
        if not tables:
            raise ValueError("nothing to concat")
        names = tables[0].names
        cols: dict[str, ColumnData] = {}
        for n in names:
            parts = [t.columns[n] for t in tables]
            if isinstance(parts[0], StringColumn):
                lens = np.concatenate([p.lengths() for p in parts])
                offsets = np.zeros(lens.shape[0] + 1, dtype=np.int64)
                np.cumsum(lens, out=offsets[1:])
                payload = np.concatenate([p.payload for p in parts])
                cols[n] = StringColumn(offsets, payload)
            else:
                cols[n] = np.concatenate(parts)
        return Table(cols, tables[0].schema)
