"""Logical/physical schema for TabFile — Parquet-faithful type system."""

from __future__ import annotations

import dataclasses
import enum

import numpy as np


class PhysicalType(enum.IntEnum):
    """Parquet physical types (enum values match parquet.thrift)."""

    BOOLEAN = 0
    INT32 = 1
    INT64 = 2
    FLOAT = 4
    DOUBLE = 5
    BYTE_ARRAY = 6


class LogicalType(str, enum.Enum):
    NONE = "none"          # raw physical
    DATE = "date"          # INT32 days since epoch
    DECIMAL = "decimal"    # INT64 scaled integer
    STRING = "string"      # BYTE_ARRAY utf-8


_NUMPY_OF_PHYSICAL = {
    PhysicalType.BOOLEAN: np.dtype(np.bool_),
    PhysicalType.INT32: np.dtype(np.int32),
    PhysicalType.INT64: np.dtype(np.int64),
    PhysicalType.FLOAT: np.dtype(np.float32),
    PhysicalType.DOUBLE: np.dtype(np.float64),
}

_PHYSICAL_OF_NUMPY = {
    np.dtype(np.bool_): PhysicalType.BOOLEAN,
    np.dtype(np.int8): PhysicalType.INT32,
    np.dtype(np.int16): PhysicalType.INT32,
    np.dtype(np.int32): PhysicalType.INT32,
    np.dtype(np.uint8): PhysicalType.INT32,
    np.dtype(np.uint16): PhysicalType.INT32,
    np.dtype(np.int64): PhysicalType.INT64,
    np.dtype(np.float32): PhysicalType.FLOAT,
    np.dtype(np.float64): PhysicalType.DOUBLE,
}


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    physical: PhysicalType
    logical: LogicalType = LogicalType.NONE
    decimal_scale: int = 0  # only for DECIMAL

    @property
    def numpy_dtype(self) -> np.dtype | None:
        return _NUMPY_OF_PHYSICAL.get(self.physical)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "physical": int(self.physical),
            "logical": self.logical.value,
            "decimal_scale": self.decimal_scale,
        }

    @staticmethod
    def from_json(obj: dict) -> "Field":
        return Field(
            name=obj["name"],
            physical=PhysicalType(obj["physical"]),
            logical=LogicalType(obj["logical"]),
            decimal_scale=obj.get("decimal_scale", 0),
        )


@dataclasses.dataclass(frozen=True)
class Schema:
    fields: list[Field]

    def __post_init__(self) -> None:
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError("duplicate column names in schema")

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)

    @property
    def names(self) -> list[str]:
        return [f.name for f in self.fields]

    def to_json(self) -> list:
        return [f.to_json() for f in self.fields]

    @staticmethod
    def from_json(obj: list) -> "Schema":
        return Schema([Field.from_json(f) for f in obj])


def physical_of_numpy(dtype: np.dtype) -> PhysicalType:
    try:
        return _PHYSICAL_OF_NUMPY[np.dtype(dtype)]
    except KeyError:
        raise TypeError(f"unsupported numpy dtype for TabFile: {dtype}")
