"""Byte-capped, thread-safe LRU shared by the scan path's cross-scan
caches: the chunk decompress memo (core/compression.py) and the decoded
dictionary cache (kernels/dict_decode.py).  One implementation of the
lock + ordered-dict + eviction + hit/miss accounting, parameterized only
by how an entry's size is computed."""

from __future__ import annotations

import threading
from collections import OrderedDict
from collections.abc import Callable


class ByteCappedLRU:
    def __init__(self, max_bytes: int, sizer: Callable[[object], int]):
        self.max_bytes = max_bytes
        self._sizer = sizer
        self._entries: "OrderedDict[object, object]" = OrderedDict()
        self._sizes: dict[object, int] = {}
        self._lock = threading.Lock()
        self.bytes = 0
        self.hits = 0
        self.misses = 0

    def get(self, key) -> object | None:
        with self._lock:
            value = self._entries.get(key)
            if value is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key, value) -> object:
        """Stores ``value`` (oversize values are returned uncached) and
        returns it, so call sites can build-and-insert in one expression."""
        size = self._sizer(value)
        if size > self.max_bytes:
            return value
        with self._lock:
            self.bytes -= self._sizes.pop(key, 0)
            self._entries[key] = value
            self._sizes[key] = size
            self.bytes += size
            self._entries.move_to_end(key)
            while self.bytes > self.max_bytes and self._entries:
                k, _ = self._entries.popitem(last=False)
                self.bytes -= self._sizes.pop(k)
        return value

    def pop(self, key) -> object | None:
        """Remove and return ``key``'s value (None when absent).  The
        fault-recovery path uses this to evict entries a failed or
        retried scan populated, so stale/poisoned bytes cannot be served
        to a later scan of the same file."""
        with self._lock:
            value = self._entries.pop(key, None)
            if value is not None:
                self.bytes -= self._sizes.pop(key, 0)
            return value

    def pop_matching(self, pred: Callable[[object], bool]) -> int:
        """Evict every entry whose key satisfies ``pred``; returns the
        eviction count.  Used to drop all entries keyed by a given file
        token / row group when a scan fails permanently."""
        with self._lock:
            doomed = [k for k in self._entries if pred(k)]
            for k in doomed:
                del self._entries[k]
                self.bytes -= self._sizes.pop(k, 0)
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._sizes.clear()
            self.bytes = 0
            self.hits = 0
            self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
