"""Scan executors (paper §4): blocking, inline-overlapped, and the
ScanService client.

The blocking reader fetches *all* I/O, then decodes, then runs the query —
the accelerator idles through the I/O phase.  The overlapped reader splits
a scan into three stages (DESIGN.md §2.5/§2.6):

  fetch    an I/O thread prefetches RG byte ranges (coalesced requests);
  decode   decode work items run *off the consume thread*;
  consume  the caller's thread executes query kernels strictly in plan
           order while later row groups decode behind it.

``run_overlapped`` is a thin client of the process-wide **ScanService**
(core/scheduler.py): one shared fetch thread and one shared decode pool
serve every concurrent scan, dispatching *per-chunk* work items (each
DecodePlan group / fallback column of a row group is independently
schedulable, with a join barrier before consume).  ``decode_workers``:

  None     the default — shared pool, adaptive sizing from observed
           per-stage wall ratios (REPRO_DECODE_WORKERS overrides);
  N >= 1   shared pool with the pool width floored at N while this scan
           is active (reported and modeled as N servers);
  0        the private PR-1 executor: one fetch thread, decode inline on
           the consume thread (file-layout benchmarks pin this so executor
           parallelism cannot contaminate layout comparisons).

Backpressure: at most ``depth`` row groups are in flight (fetched or
decoded but not yet consumed) per scan — fetch is gated by per-scan
credits that the consume stage releases, which bounds memory (the paper's
OOM point).

Two time accountings are produced:
  measured_wall  actual wall time of this process (real thread overlap)
  modeled        pipeline schedule combining per-RG stage times — required
                 when storage time is simulated (sim backend), since a
                 simulated fetch returns instantly on the host clock.  The
                 overlapped model schedules decode on ``decode_workers``
                 parallel servers feeding an in-order consume stage — at
                 *chunk* granularity when per-chunk item times were
                 recorded (``ScanMetrics.decode_chunks_per_rg``); with
                 ``decode_workers=0`` decode shares the consume thread and
                 the schedule reduces to the PR-1 two-stage model.

Per-stage wall spans (first-start → last-end per stage) are recorded in
``RunReport.stage_walls`` and mirrored into ``ScanMetrics`` so measured and
modeled walls can be cross-checked.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from collections.abc import Callable, Sequence

from repro.core import trace as trace_mod
from repro.core.scan import Scanner, ScanMetrics
from repro.kernels.common import kernel_launch_count

Consume = Callable[[object, int, dict], object]


def default_decode_workers() -> int | None:
    """Resolve ``decode_workers=None``: the REPRO_DECODE_WORKERS override
    when set (0 → inline decode), else None — the shared ScanService pool
    with adaptive sizing (core/scheduler.py).  Resolved at call time so
    setting the env var after import still takes effect."""
    env = os.environ.get("REPRO_DECODE_WORKERS")
    if env is not None:
        return max(0, int(env))
    return None


class _MetricsProbe:
    """Snapshots launch/request/plan/fault counters around one run so
    RunReports carry the DecodePlan launch economy and the recovery
    accounting (see ScanMetrics field docs)."""

    def __init__(self, scanner: Scanner):
        self.scanner = scanner
        self.launches0 = kernel_launch_count()
        self.requests0 = scanner.storage.stats.requests
        self.lat0 = len(scanner.storage.stats.latencies)
        self.plan_s0 = (scanner.planner.plan_seconds
                        if scanner.planner else 0.0)
        fc = getattr(scanner, "fault_counters", None)
        self.faults0 = fc() if fc is not None else None
        pf = getattr(scanner.storage, "prefetch_stats", None)
        self.pf0 = dataclasses.replace(pf) if pf is not None else None

    def finish(self, m: ScanMetrics) -> None:
        m.n_kernel_launches = kernel_launch_count() - self.launches0
        m.n_io_requests = (self.scanner.storage.stats.requests
                           - self.requests0)
        lats = self.scanner.storage.stats.latencies[self.lat0:]
        if lats:
            import numpy as _np
            m.io_p50_us = float(_np.percentile(lats, 50)) * 1e6
            m.io_p95_us = float(_np.percentile(lats, 95)) * 1e6
        if self.pf0 is not None:
            pf = self.scanner.storage.prefetch_stats
            m.prefetch_hits = pf.hits - self.pf0.hits
            m.prefetch_misses = pf.misses - self.pf0.misses
            m.prefetch_hidden_seconds = (pf.hidden_seconds
                                         - self.pf0.hidden_seconds)
            m.prefetch_stall_seconds = (pf.stall_seconds
                                        - self.pf0.stall_seconds)
        from repro.core.scheduler import decode_affinity_mode
        m.decode_affinity = decode_affinity_mode()
        if self.scanner.planner is not None:
            m.plan_seconds = (self.scanner.planner.plan_seconds
                              - self.plan_s0)
        if self.faults0 is not None:
            now = self.scanner.fault_counters()
            m.retries = now["retries"] - self.faults0["retries"]
            m.checksum_failures = (now["checksum_failures"]
                                   - self.faults0["checksum_failures"])
            m.timeouts = now["timeouts"] - self.faults0["timeouts"]
        pol = getattr(self.scanner, "retry", None)
        if pol is not None:
            m.retry_policy = getattr(pol, "name", "")
        tr = trace_mod.active()
        if tr is not None:
            m.trace_events = tr.event_count()
            m.registry_snapshot = trace_mod.registry().snapshot()


@dataclasses.dataclass
class RunReport:
    mode: str                   # "blocking" | "overlapped"
    measured_wall: float
    metrics: ScanMetrics
    consume_per_rg: list[float]
    decode_workers: int = 0     # 0 → decode ran inline on the consume thread
    depth: int = 2              # in-flight bound the executor ran with
    stage_walls: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def modeled_wall(self) -> float:
        """Pipeline schedule over the per-RG stage times.

        blocking            io_total + Σ(decode + consume)
        overlapped, W = 0   two stages: storage ∥ (decode + consume) serial
                            on the consume thread (the PR-1 executor)
        overlapped, W ≥ 1   three stages: storage → W parallel decode
                            servers → in-order consume.  When per-chunk
                            item times were recorded (the ScanService's
                            per-chunk dispatch,
                            ``metrics.decode_chunks_per_rg``), RG i's
                            items are scheduled individually on the W
                            servers honoring the executor's DAG: the
                            serialized "open" runs first, the phase-1
                            (decompress) items fan out, the phase
                            transition runs after they ALL drain (the
                            barrier, ``decode_p2_start_per_rg``), the
                            phase-2 (decode) items fan out, and the
                            finalize join runs last — so a wide row
                            group's chunks decode in parallel but the
                            model never beats the real DAG.  Without
                            chunk times the RG is one indivisible decode
                            of length ``decode_per_rg[i]``.  RG i's decode starts
                            at max(io_done(i), earliest-free server) and
                            its consume at max(decode_done(i),
                            consume_done(i-1)).

        Overlapped schedules honor the executor's ``depth`` backpressure:
        RG k's fetch cannot start before RG k-depth is consumed (the
        in-flight credit), so the model never reports a schedule the real
        executor could not achieve.
        """
        dec = self.metrics.decode_per_rg
        cons = self.consume_per_rg
        ios = self.metrics.io_per_rg
        if self.mode == "blocking":
            return (self.metrics.io_seconds + sum(dec) + sum(cons))
        depth = max(1, self.depth)
        done_hist: list[float] = []     # per-RG consume completion
        io_done = 0.0
        if self.decode_workers <= 0:
            compute_done = 0.0
            for k, (io, d, c) in enumerate(zip(ios, dec, cons)):
                gate = done_hist[k - depth] if k >= depth else 0.0
                io_done = max(io_done, gate) + io
                compute_done = max(io_done, compute_done) + d + c
                done_hist.append(compute_done)
            return compute_done
        chunks = self.metrics.decode_chunks_per_rg
        free = [0.0] * self.decode_workers
        consume_done = 0.0

        def run_on_server(ready: float, t: float) -> float:
            j = min(range(len(free)), key=free.__getitem__)
            free[j] = max(ready, free[j]) + t
            return free[j]

        splits = self.metrics.decode_p2_start_per_rg
        for k, (io, d, c) in enumerate(zip(ios, dec, cons)):
            gate = done_hist[k - depth] if k >= depth else 0.0
            io_done = max(io_done, gate) + io
            parts = (chunks[k] if k < len(chunks) and chunks[k] else [d])
            s = splits[k] if k < len(splits) else 0
            if len(parts) <= 2 or not 2 <= s <= len(parts) - 1:
                # open/finalize alone, an indivisible decode, or no
                # recorded barrier: serialize — never beat the real DAG
                decode_done = io_done
                for t in parts:
                    decode_done = run_on_server(decode_done, t)
            else:
                # layout: [open][phase-1 …][transition][phase-2 …][fin];
                # each wave fans out across the W servers, the
                # transition and finalize join behind their phase
                opened = run_on_server(io_done, parts[0])
                p1_join = opened
                for t in parts[1:s - 1]:
                    p1_join = max(p1_join, run_on_server(opened, t))
                trans = run_on_server(p1_join, parts[s - 1])
                p2_join = trans
                for t in parts[s:-1]:
                    p2_join = max(p2_join, run_on_server(trans, t))
                decode_done = run_on_server(p2_join, parts[-1])
            consume_done = max(consume_done, decode_done) + c
            done_hist.append(consume_done)
        return consume_done

    def effective_bandwidth(self) -> float:
        return self.metrics.logical_bytes / max(1e-12, self.modeled_wall)

    @property
    def launch_summary(self) -> str:
        """Kernel-launch / I/O-request economy of this run (DecodePlan),
        plus the fault-recovery counters (informational — check_regression
        displays but never gates them: a chaos run's retries are expected,
        not a regression)."""
        m = self.metrics
        return (f"launches={m.n_kernel_launches};"
                f"io_requests={m.n_io_requests};"
                f"plan_ms={m.plan_seconds * 1e3:.2f};"
                f"retries={m.retries};"
                f"checksum_failures={m.checksum_failures};"
                f"timeouts={m.timeouts}")

    @property
    def stage_summary(self) -> str:
        """Per-stage wall spans of this run (pipeline observability),
        plus the fault-recovery counters (informational — never gated)."""
        w = self.stage_walls
        return (f"fetch_ms={w.get('fetch', 0.0) * 1e3:.2f};"
                f"decode_ms={w.get('decode', 0.0) * 1e3:.2f};"
                f"consume_ms={w.get('consume', 0.0) * 1e3:.2f};"
                f"workers={self.decode_workers};"
                f"retries={self.metrics.retries};"
                f"checksum_failures={self.metrics.checksum_failures};"
                f"timeouts={self.metrics.timeouts}")


def _account_rg(scanner: Scanner, m: ScanMetrics, i: int, cols: dict,
                io_dt: float, dec_dt: float) -> None:
    m.io_seconds += io_dt
    m.io_per_rg.append(io_dt)
    m.decode_seconds += dec_dt
    m.decode_per_rg.append(dec_dt)
    rg = scanner.meta.row_groups[i]
    for name in scanner.columns:
        m.stored_bytes += rg.column(name).stored_bytes
        m.n_pages += len(rg.column(name).pages)
    m.logical_bytes += sum(r.logical_bytes for r in cols.values())
    m.n_row_groups += 1


def run_blocking(scanner: Scanner, consume: Consume | None = None,
                 row_groups: Sequence[int] | None = None,
                 predicate_stats=None, trace=None):
    """Fetch everything, then decode+consume everything (paper Fig. 4 top).

    ``trace`` enables the flight recorder for this run (DESIGN.md §10):
    True records, a path string records and exports Chrome JSON."""
    with trace_mod.request(trace):
        return _run_blocking(scanner, consume, row_groups, predicate_stats)


def _run_blocking(scanner: Scanner, consume: Consume | None,
                  row_groups, predicate_stats):
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    tr = trace_mod.active()
    label = getattr(scanner, "path", "scan")
    staged = []
    t_f0 = time.perf_counter()
    for i in plan:
        t_r = time.perf_counter()
        raws, io_dt = scanner.fetch_rg(i)
        if tr is not None:
            tr.complete("fetch", "io", t_r, time.perf_counter(),
                        scan=label, rg=i, io_dt=io_dt)
        staged.append((i, raws, io_dt))
    fetch_wall = time.perf_counter() - t_f0
    acc = None
    consume_times: list[float] = []
    decode_wall = 0.0
    for i, raws, io_dt in staged:
        t_d = time.perf_counter()
        cols, dec_dt = scanner.decode_rg(i, raws)
        t_d1 = time.perf_counter()
        decode_wall += t_d1 - t_d
        if tr is not None:
            tr.complete("decode_rg", "decode", t_d, t_d1,
                        scan=label, rg=i)
        _account_rg(scanner, m, i, cols, io_dt, dec_dt)
        t1 = time.perf_counter()
        if consume is not None:
            acc = consume(acc, i, cols)
        t2 = time.perf_counter()
        consume_times.append(t2 - t1)
        if tr is not None:
            tr.complete("consume", "consume", t1, t2, scan=label, rg=i)
    probe.finish(m)
    m.fetch_wall_seconds = fetch_wall
    m.decode_wall_seconds = decode_wall
    m.consume_seconds = sum(consume_times)
    walls = {"fetch": fetch_wall, "decode": decode_wall,
             "consume": sum(consume_times)}
    t_end = time.perf_counter()
    if tr is not None:
        tr.complete("scan", "scan", t0, t_end, scan=label,
                    mode="blocking", rgs=m.n_row_groups,
                    retry_policy=m.retry_policy)
        m.trace_events = tr.event_count()
    return acc, RunReport("blocking", t_end - t0, m,
                          consume_times, decode_workers=0, depth=0,
                          stage_walls=walls)


class _FetchState:
    """Cross-thread state of the inline (W=0) executor's fetch thread:
    first-error capture and the abort flag both sides poll so failures
    drain instead of deadlocking."""

    def __init__(self):
        self.errors: list[BaseException] = []
        self.abort = threading.Event()

    def fail(self, exc: BaseException) -> None:
        self.errors.append(exc)
        self.abort.set()


def run_overlapped(scanner: Scanner, consume: Consume | None = None,
                   row_groups: Sequence[int] | None = None,
                   predicate_stats=None, depth: int = 2,
                   decode_workers: int | None = None, service=None,
                   priority: int = 0, retries: int = 3,
                   deadline: float | None = None, trace=None,
                   tenant: str | None = None):
    """Overlapped scan: fetch ∥ decode ∥ in-order consume.

    ``depth`` bounds row groups in flight (fetched or decoded, not yet
    consumed).  ``decode_workers=0`` decodes inline on the consume thread
    (the PR-1 double-buffered executor, private fetch thread); any other
    value routes through the shared ScanService — ``None`` (the default)
    with adaptive pool sizing, ``N >= 1`` flooring the pool at N while
    this scan runs.  ``service`` overrides the process-wide singleton
    (tests / dedicated pools).  ``priority`` is the ScanService strict
    service class (lower first; the dataset executor biases the pool
    toward earliest fragments) — ignored on the inline path.

    ``retries`` is the scan's transient-failure budget (row groups
    requeued for a fresh fetch + decode across the whole scan, DESIGN.md
    §6); ``deadline`` is a whole-scan wall budget in seconds — once
    exceeded the scan raises ``DeadlineExceeded`` (never retried).

    ``trace`` enables the flight recorder for this run (DESIGN.md §10):
    True records, a path string records and exports Chrome JSON.

    ``tenant`` names the ScanService tenant this scan belongs to
    (weighted fair scheduling + admission control, DESIGN.md §11);
    ignored on the inline path, which shares no pool to be fair about.
    """
    if decode_workers is None:
        decode_workers = default_decode_workers()
    with trace_mod.request(trace):
        if decode_workers is not None and int(decode_workers) <= 0:
            return _run_overlapped_inline(scanner, consume, row_groups,
                                          predicate_stats, depth,
                                          deadline=deadline)
        return _run_overlapped_service(scanner, consume, row_groups,
                                       predicate_stats, depth,
                                       decode_workers, service, priority,
                                       retries=retries, deadline=deadline,
                                       tenant=tenant)


def _run_overlapped_service(scanner: Scanner, consume: Consume | None,
                            row_groups, predicate_stats, depth: int,
                            decode_workers: int | None, service,
                            priority: int = 0, retries: int = 3,
                            deadline: float | None = None,
                            tenant: str | None = None):
    """Shared-pool path: submit to the ScanService, consume in order."""
    from repro.core.scheduler import scan_service

    t0 = time.perf_counter()
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    svc = service if service is not None else scan_service()
    hint = int(decode_workers) if decode_workers else None
    handle = svc.submit(scanner, row_groups=row_groups,
                        predicate_stats=predicate_stats, depth=depth,
                        workers_hint=hint,
                        label=getattr(scanner, "path", "scan"),
                        priority=priority, retries=retries,
                        deadline=deadline, tenant=tenant)
    acc = None
    consume_times: list[float] = []
    tr = trace_mod.active()
    label = getattr(scanner, "path", "scan")
    try:
        for i, cols, io_dt, dec_dt, chunk_times, p2_start in handle:
            _account_rg(scanner, m, i, cols, io_dt, dec_dt)
            m.decode_chunks_per_rg.append(chunk_times)
            m.decode_p2_start_per_rg.append(p2_start)
            t1 = time.perf_counter()
            if consume is not None:
                acc = consume(acc, i, cols)
            t2 = time.perf_counter()
            consume_times.append(t2 - t1)
            if tr is not None:
                tr.complete("consume", "consume", t1, t2, scan=label,
                            rg=i, logical_bytes=sum(
                                r.logical_bytes for r in cols.values()))
    except BaseException:
        handle.cancel()             # no-op if the scan already finished
        raise
    probe.finish(m)
    m.shared_rgs = handle.shared_rgs
    workers = handle.workers
    walls = handle.stage_walls()
    walls["consume"] = sum(consume_times)
    m.fetch_wall_seconds = walls["fetch"]
    m.decode_wall_seconds = walls["decode"]
    m.consume_seconds = walls["consume"]
    t_end = time.perf_counter()
    if tr is not None:
        tr.complete("scan", "scan", t0, t_end, scan=label,
                    mode="overlapped", workers=workers,
                    rgs=m.n_row_groups, shared_rgs=m.shared_rgs,
                    retry_policy=m.retry_policy,
                    **({"tenant": tenant} if tenant is not None else {}))
        m.trace_events = tr.event_count()
    return acc, RunReport("overlapped", t_end - t0, m,
                          consume_times, decode_workers=workers,
                          depth=max(1, depth), stage_walls=walls)


def _run_overlapped_inline(scanner: Scanner, consume: Consume | None,
                           row_groups, predicate_stats, depth: int,
                           deadline: float | None = None):
    """The PR-1 executor: private fetch thread ∥ inline decode + consume.
    Kept behind ``decode_workers=0`` so file-layout comparisons can pin an
    executor without pool parallelism."""
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    state = _FetchState()
    inflight = threading.Semaphore(max(1, depth))
    fetched: "queue.Queue" = queue.Queue()
    fetch_wall = [0.0]
    tr = trace_mod.active()
    label = getattr(scanner, "path", "scan")

    def fetch_worker():
        t_start = time.perf_counter()
        try:
            for i in plan:
                while not state.abort.is_set():
                    if inflight.acquire(timeout=0.05):
                        break
                if state.abort.is_set():
                    break
                t_r = time.perf_counter()
                raws, io_dt = scanner.fetch_rg(i)
                if tr is not None:
                    tr.complete("fetch", "io", t_r, time.perf_counter(),
                                scan=label, rg=i, io_dt=io_dt)
                fetched.put((i, raws, io_dt))
        except BaseException as e:  # surfaced on the consume thread
            state.fail(e)
        finally:
            fetch_wall[0] = time.perf_counter() - t_start
            fetched.put(None)

    thread = threading.Thread(target=fetch_worker, daemon=True)
    thread.start()

    acc = None
    consume_times: list[float] = []
    decode_wall = 0.0
    try:
        for _ in range(len(plan)):
            if (deadline is not None
                    and time.perf_counter() - t0 > deadline):
                from repro.core.faults import DeadlineExceeded
                cf = getattr(scanner, "count_fault", None)
                if cf is not None:
                    cf(timeouts=1)
                raise DeadlineExceeded(
                    f"scan {getattr(scanner, 'path', '?')}: deadline "
                    "exceeded")
            item = fetched.get()
            if item is None:
                break               # fetch aborted
            i, raws, io_dt = item
            t_d = time.perf_counter()
            cols, dec_dt = scanner.decode_rg(i, raws)
            t_d1 = time.perf_counter()
            decode_wall += t_d1 - t_d
            if tr is not None:
                tr.complete("decode_rg", "decode", t_d, t_d1,
                            scan=label, rg=i)
            _account_rg(scanner, m, i, cols, io_dt, dec_dt)
            t1 = time.perf_counter()
            if consume is not None:
                acc = consume(acc, i, cols)
            t2 = time.perf_counter()
            consume_times.append(t2 - t1)
            if tr is not None:
                tr.complete("consume", "consume", t1, t2, scan=label,
                            rg=i)
            inflight.release()
    except BaseException:
        state.abort.set()
        raise
    finally:
        thread.join(timeout=5.0)
    if state.errors:
        raise state.errors[0]
    probe.finish(m)
    m.fetch_wall_seconds = fetch_wall[0]
    m.decode_wall_seconds = decode_wall
    m.consume_seconds = sum(consume_times)
    walls = {"fetch": fetch_wall[0], "decode": decode_wall,
             "consume": sum(consume_times)}
    t_end = time.perf_counter()
    if tr is not None:
        tr.complete("scan", "scan", t0, t_end, scan=label,
                    mode="overlapped-inline", workers=0,
                    rgs=m.n_row_groups, retry_policy=m.retry_policy)
        m.trace_events = tr.event_count()
    return acc, RunReport("overlapped", t_end - t0, m,
                          consume_times, decode_workers=0,
                          depth=max(1, depth), stage_walls=walls)
