"""Staged pipeline scan executor (paper §4): fetch ∥ decompress/decode ∥ consume.

The blocking reader fetches *all* I/O, then decodes, then runs the query —
the accelerator idles through the I/O phase.  The pipelined reader splits a
scan into three stages at row-group granularity (DESIGN.md §2.5):

  fetch    one I/O thread prefetches RG byte ranges (coalesced requests);
  decode   a pool of ``decode_workers`` threads (default: one fewer than
           the core count, capped at 2 — see default_decode_workers) runs
           decompress + decode (``Scanner.decode_rg``) *off the consume
           thread*, so host decode work no longer serializes kernel
           execution;
  consume  the caller's thread executes query kernels strictly in plan
           order while later row groups decode behind it.

Backpressure: at most ``depth`` row groups are in flight (fetched or decoded
but not yet consumed) — the fetch thread blocks on an in-flight semaphore
that the consume stage releases, which bounds memory (the paper's OOM
point).  ``decode_workers=0`` degenerates to the PR-1 executor: decode runs
inline on the consume thread.

Two time accountings are produced:
  measured_wall  actual wall time of this process (real thread overlap)
  modeled        pipeline schedule combining per-RG stage times — required
                 when storage time is simulated (sim backend), since a
                 simulated fetch returns instantly on the host clock.  The
                 overlapped model schedules decode on ``decode_workers``
                 parallel servers feeding an in-order consume stage; with
                 ``decode_workers=0`` decode shares the consume thread and
                 the schedule reduces to the PR-1 two-stage model.

Per-stage wall spans (first-start → last-end per stage) are recorded in
``RunReport.stage_walls`` and mirrored into ``ScanMetrics`` so measured and
modeled walls can be cross-checked.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.scan import Scanner, ScanMetrics
from repro.kernels.common import kernel_launch_count

Consume = Callable[[object, int, Dict], object]


def default_decode_workers() -> int:
    """Decode-pool width: leave one core for the consume stage.  On the
    2-core CI/container class one worker is already the full win (decode
    off the consume thread); wider pools only pay with spare cores.
    Override with REPRO_DECODE_WORKERS (0 → inline decode).  Resolved at
    call time — ``decode_workers=None`` in run_overlapped/q6/q12 — so
    setting the env var after import still takes effect."""
    env = os.environ.get("REPRO_DECODE_WORKERS")
    if env is not None:
        return max(0, int(env))
    return max(1, min(2, (os.cpu_count() or 2) - 1))


class _MetricsProbe:
    """Snapshots launch/request/plan counters around one run so RunReports
    carry the DecodePlan launch economy (see ScanMetrics field docs)."""

    def __init__(self, scanner: Scanner):
        self.scanner = scanner
        self.launches0 = kernel_launch_count()
        self.requests0 = scanner.storage.stats.requests
        self.plan_s0 = (scanner.planner.plan_seconds
                        if scanner.planner else 0.0)

    def finish(self, m: ScanMetrics) -> None:
        m.n_kernel_launches = kernel_launch_count() - self.launches0
        m.n_io_requests = (self.scanner.storage.stats.requests
                           - self.requests0)
        if self.scanner.planner is not None:
            m.plan_seconds = (self.scanner.planner.plan_seconds
                              - self.plan_s0)


@dataclasses.dataclass
class RunReport:
    mode: str                   # "blocking" | "overlapped"
    measured_wall: float
    metrics: ScanMetrics
    consume_per_rg: List[float]
    decode_workers: int = 0     # 0 → decode ran inline on the consume thread
    depth: int = 2              # in-flight bound the executor ran with
    stage_walls: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def modeled_wall(self) -> float:
        """Pipeline schedule over the per-RG stage times.

        blocking            io_total + Σ(decode + consume)
        overlapped, W = 0   two stages: storage ∥ (decode + consume) serial
                            on the consume thread (the PR-1 executor)
        overlapped, W ≥ 1   three stages: storage → W parallel decode
                            servers → in-order consume; RG i's decode starts
                            at max(io_done(i), earliest-free server) and its
                            consume at max(decode_done(i), consume_done(i-1))

        Overlapped schedules honor the executor's ``depth`` backpressure:
        RG k's fetch cannot start before RG k-depth is consumed (the
        in-flight semaphore), so the model never reports a schedule the
        real executor could not achieve.
        """
        dec = self.metrics.decode_per_rg
        cons = self.consume_per_rg
        ios = self.metrics.io_per_rg
        if self.mode == "blocking":
            return (self.metrics.io_seconds + sum(dec) + sum(cons))
        depth = max(1, self.depth)
        done_hist: List[float] = []     # per-RG consume completion
        io_done = 0.0
        if self.decode_workers <= 0:
            compute_done = 0.0
            for k, (io, d, c) in enumerate(zip(ios, dec, cons)):
                gate = done_hist[k - depth] if k >= depth else 0.0
                io_done = max(io_done, gate) + io
                compute_done = max(io_done, compute_done) + d + c
                done_hist.append(compute_done)
            return compute_done
        free = [0.0] * self.decode_workers
        consume_done = 0.0
        for k, (io, d, c) in enumerate(zip(ios, dec, cons)):
            gate = done_hist[k - depth] if k >= depth else 0.0
            io_done = max(io_done, gate) + io
            j = min(range(len(free)), key=free.__getitem__)
            decode_done = max(io_done, free[j]) + d
            free[j] = decode_done
            consume_done = max(consume_done, decode_done) + c
            done_hist.append(consume_done)
        return consume_done

    def effective_bandwidth(self) -> float:
        return self.metrics.logical_bytes / max(1e-12, self.modeled_wall)

    @property
    def launch_summary(self) -> str:
        """Kernel-launch / I/O-request economy of this run (DecodePlan)."""
        m = self.metrics
        return (f"launches={m.n_kernel_launches};"
                f"io_requests={m.n_io_requests};"
                f"plan_ms={m.plan_seconds * 1e3:.2f}")

    @property
    def stage_summary(self) -> str:
        """Per-stage wall spans of this run (pipeline observability)."""
        w = self.stage_walls
        return (f"fetch_ms={w.get('fetch', 0.0) * 1e3:.2f};"
                f"decode_ms={w.get('decode', 0.0) * 1e3:.2f};"
                f"consume_ms={w.get('consume', 0.0) * 1e3:.2f};"
                f"workers={self.decode_workers}")


def _account_rg(scanner: Scanner, m: ScanMetrics, i: int, cols: Dict,
                io_dt: float, dec_dt: float) -> None:
    m.io_seconds += io_dt
    m.io_per_rg.append(io_dt)
    m.decode_seconds += dec_dt
    m.decode_per_rg.append(dec_dt)
    rg = scanner.meta.row_groups[i]
    for name in scanner.columns:
        m.stored_bytes += rg.column(name).stored_bytes
        m.n_pages += len(rg.column(name).pages)
    m.logical_bytes += sum(r.logical_bytes for r in cols.values())
    m.n_row_groups += 1


def run_blocking(scanner: Scanner, consume: Optional[Consume] = None,
                 row_groups: Optional[Sequence[int]] = None,
                 predicate_stats=None):
    """Fetch everything, then decode+consume everything (paper Fig. 4 top)."""
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    staged = []
    t_f0 = time.perf_counter()
    for i in plan:
        raws, io_dt = scanner.fetch_rg(i)
        staged.append((i, raws, io_dt))
    fetch_wall = time.perf_counter() - t_f0
    acc = None
    consume_times: List[float] = []
    decode_wall = 0.0
    for i, raws, io_dt in staged:
        t_d = time.perf_counter()
        cols, dec_dt = scanner.decode_rg(i, raws)
        decode_wall += time.perf_counter() - t_d
        _account_rg(scanner, m, i, cols, io_dt, dec_dt)
        t1 = time.perf_counter()
        if consume is not None:
            acc = consume(acc, i, cols)
        consume_times.append(time.perf_counter() - t1)
    probe.finish(m)
    m.fetch_wall_seconds = fetch_wall
    m.decode_wall_seconds = decode_wall
    m.consume_seconds = sum(consume_times)
    walls = {"fetch": fetch_wall, "decode": decode_wall,
             "consume": sum(consume_times)}
    return acc, RunReport("blocking", time.perf_counter() - t0, m,
                          consume_times, decode_workers=0, depth=0,
                          stage_walls=walls)


class _PipelineState:
    """Cross-thread state for one pipelined run: completed decodes keyed by
    plan position (consume reorders), first-error capture, and the abort
    flag every stage polls so failures drain instead of deadlocking."""

    def __init__(self):
        self.cv = threading.Condition()
        self.done: Dict[int, tuple] = {}
        self.errors: List[BaseException] = []
        self.abort = threading.Event()
        self.decode_t0: Optional[float] = None
        self.decode_t1: float = 0.0

    def fail(self, exc: BaseException) -> None:
        with self.cv:
            self.errors.append(exc)
            self.abort.set()
            self.cv.notify_all()


def run_overlapped(scanner: Scanner, consume: Optional[Consume] = None,
                   row_groups: Optional[Sequence[int]] = None,
                   predicate_stats=None, depth: int = 2,
                   decode_workers: Optional[int] = None):
    """Staged pipeline: I/O thread ∥ decode pool ∥ in-order consume.

    ``depth`` bounds row groups in flight (fetched or decoded, not yet
    consumed).  ``decode_workers=0`` decodes inline on the consume thread —
    the PR-1 double-buffered executor; None → default_decode_workers().
    """
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    if decode_workers is None:
        decode_workers = default_decode_workers()
    workers = max(0, int(decode_workers))
    state = _PipelineState()
    inflight = threading.Semaphore(max(1, depth))
    fetched: "queue.Queue" = queue.Queue()
    fetch_wall = [0.0]

    def fetch_worker():
        t_start = time.perf_counter()
        try:
            for seq, i in enumerate(plan):
                while not state.abort.is_set():
                    if inflight.acquire(timeout=0.05):
                        break
                if state.abort.is_set():
                    break
                raws, io_dt = scanner.fetch_rg(i)
                fetched.put((seq, i, raws, io_dt))
        except BaseException as e:  # surfaced on the consume thread
            state.fail(e)
        finally:
            fetch_wall[0] = time.perf_counter() - t_start
            for _ in range(max(1, workers)):
                fetched.put(None)

    def decode_worker():
        while True:
            item = fetched.get()
            if item is None:
                break
            if state.abort.is_set():
                continue            # drain without decoding
            seq, i, raws, io_dt = item
            try:
                t_d = time.perf_counter()
                cols, dec_dt = scanner.decode_rg(i, raws)
                t_e = time.perf_counter()
            except BaseException as e:
                state.fail(e)
                continue
            with state.cv:
                if state.decode_t0 is None or t_d < state.decode_t0:
                    state.decode_t0 = t_d
                state.decode_t1 = max(state.decode_t1, t_e)
                state.done[seq] = (i, cols, io_dt, dec_dt)
                state.cv.notify_all()

    threads = [threading.Thread(target=fetch_worker, daemon=True)]
    threads += [threading.Thread(target=decode_worker, daemon=True)
                for _ in range(workers)]
    for t in threads:
        t.start()

    acc = None
    consume_times: List[float] = []
    decode_wall_inline = 0.0
    try:
        for seq in range(len(plan)):
            if workers:
                with state.cv:
                    while seq not in state.done and not state.abort.is_set():
                        state.cv.wait(timeout=0.05)
                    if seq not in state.done:
                        break       # aborted upstream
                    i, cols, io_dt, dec_dt = state.done.pop(seq)
            else:
                item = fetched.get()
                if item is None:
                    break           # fetch aborted
                _, i, raws, io_dt = item
                t_d = time.perf_counter()
                cols, dec_dt = scanner.decode_rg(i, raws)
                decode_wall_inline += time.perf_counter() - t_d
            _account_rg(scanner, m, i, cols, io_dt, dec_dt)
            t1 = time.perf_counter()
            if consume is not None:
                acc = consume(acc, i, cols)
            consume_times.append(time.perf_counter() - t1)
            inflight.release()
    except BaseException:
        state.abort.set()
        raise
    finally:
        if state.errors:
            state.abort.set()
        for t in threads:
            t.join(timeout=5.0)
    if state.errors:
        raise state.errors[0]
    probe.finish(m)
    if workers and state.decode_t0 is not None:
        decode_wall = state.decode_t1 - state.decode_t0
    else:
        decode_wall = decode_wall_inline
    m.fetch_wall_seconds = fetch_wall[0]
    m.decode_wall_seconds = decode_wall
    m.consume_seconds = sum(consume_times)
    walls = {"fetch": fetch_wall[0], "decode": decode_wall,
             "consume": sum(consume_times)}
    return acc, RunReport("overlapped", time.perf_counter() - t0, m,
                          consume_times, decode_workers=workers,
                          depth=max(1, depth), stage_walls=walls)
