"""Blocking vs overlapped execution (paper §4).

The blocking reader fetches *all* I/O, then decodes, then runs the query —
the accelerator idles through the I/O phase.  The overlapped reader
double-buffers at row-group granularity: a background thread prefetches RG
i+1..i+depth while RG i decodes and is consumed, which both hides I/O and
bounds memory (the paper's OOM point).

Two time accountings are produced:
  measured_wall  actual wall time of this process (real thread overlap)
  modeled        pipeline schedule combining per-RG stage times — required
                 when storage time is simulated (sim backend), since a
                 simulated fetch returns instantly on the host clock.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.scan import Scanner, ScanMetrics
from repro.kernels.common import kernel_launch_count

Consume = Callable[[object, int, Dict], object]


class _MetricsProbe:
    """Snapshots launch/request/plan counters around one run so RunReports
    carry the DecodePlan launch economy (see ScanMetrics field docs)."""

    def __init__(self, scanner: Scanner):
        self.scanner = scanner
        self.launches0 = kernel_launch_count()
        self.requests0 = scanner.storage.stats.requests
        self.plan_s0 = (scanner.planner.plan_seconds
                        if scanner.planner else 0.0)

    def finish(self, m: ScanMetrics) -> None:
        m.n_kernel_launches = kernel_launch_count() - self.launches0
        m.n_io_requests = (self.scanner.storage.stats.requests
                           - self.requests0)
        if self.scanner.planner is not None:
            m.plan_seconds = (self.scanner.planner.plan_seconds
                              - self.plan_s0)


@dataclasses.dataclass
class RunReport:
    mode: str                   # "blocking" | "overlapped"
    measured_wall: float
    metrics: ScanMetrics
    consume_per_rg: List[float]

    @property
    def modeled_wall(self) -> float:
        compute = [d + c for d, c in zip(self.metrics.decode_per_rg,
                                         self.consume_per_rg)]
        if self.mode == "blocking":
            return self.metrics.io_seconds + sum(compute)
        io_done, compute_done = 0.0, 0.0
        for io, comp in zip(self.metrics.io_per_rg, compute):
            io_done += io
            compute_done = max(io_done, compute_done) + comp
        return compute_done

    def effective_bandwidth(self) -> float:
        return self.metrics.logical_bytes / max(1e-12, self.modeled_wall)

    @property
    def launch_summary(self) -> str:
        """Kernel-launch / I/O-request economy of this run (DecodePlan)."""
        m = self.metrics
        return (f"launches={m.n_kernel_launches};"
                f"io_requests={m.n_io_requests};"
                f"plan_ms={m.plan_seconds * 1e3:.2f}")


def run_blocking(scanner: Scanner, consume: Optional[Consume] = None,
                 row_groups: Optional[Sequence[int]] = None,
                 predicate_stats=None):
    """Fetch everything, then decode+consume everything (paper Fig. 4 top)."""
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    staged = []
    for i in plan:
        raws, io_dt = scanner.fetch_rg(i)
        staged.append((i, raws))
        m.io_seconds += io_dt
        m.io_per_rg.append(io_dt)
    acc = None
    consume_times: List[float] = []
    for i, raws in staged:
        cols, dec_dt = scanner.decode_rg(i, raws)
        m.decode_seconds += dec_dt
        m.decode_per_rg.append(dec_dt)
        rg = scanner.meta.row_groups[i]
        for name in scanner.columns:
            m.stored_bytes += rg.column(name).stored_bytes
            m.n_pages += len(rg.column(name).pages)
        m.logical_bytes += sum(r.logical_bytes for r in cols.values())
        m.n_row_groups += 1
        t1 = time.perf_counter()
        if consume is not None:
            acc = consume(acc, i, cols)
        consume_times.append(time.perf_counter() - t1)
    probe.finish(m)
    return acc, RunReport("blocking", time.perf_counter() - t0, m,
                          consume_times)


def run_overlapped(scanner: Scanner, consume: Optional[Consume] = None,
                   row_groups: Optional[Sequence[int]] = None,
                   predicate_stats=None, depth: int = 2):
    """RG-granular pipeline: I/O thread ∥ decode+consume (paper Fig. 4)."""
    t0 = time.perf_counter()
    plan = scanner.plan(predicate_stats, row_groups)
    m = ScanMetrics(backend=getattr(scanner.storage, "kind", "real"))
    probe = _MetricsProbe(scanner)
    q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
    err: List[BaseException] = []

    def io_worker():
        try:
            for i in plan:
                raws, io_dt = scanner.fetch_rg(i)
                q.put((i, raws, io_dt))
        except BaseException as e:  # surfaced on the consumer side
            err.append(e)
        finally:
            q.put(None)

    t = threading.Thread(target=io_worker, daemon=True)
    t.start()
    acc = None
    consume_times: List[float] = []
    while True:
        item = q.get()
        if item is None:
            break
        i, raws, io_dt = item
        m.io_seconds += io_dt
        m.io_per_rg.append(io_dt)
        cols, dec_dt = scanner.decode_rg(i, raws)
        m.decode_seconds += dec_dt
        m.decode_per_rg.append(dec_dt)
        rg = scanner.meta.row_groups[i]
        for name in scanner.columns:
            m.stored_bytes += rg.column(name).stored_bytes
            m.n_pages += len(rg.column(name).pages)
        m.logical_bytes += sum(r.logical_bytes for r in cols.values())
        m.n_row_groups += 1
        t1 = time.perf_counter()
        if consume is not None:
            acc = consume(acc, i, cols)
        consume_times.append(time.perf_counter() - t1)
    t.join()
    if err:
        raise err[0]
    probe.finish(m)
    return acc, RunReport("overlapped", time.perf_counter() - t0, m,
                          consume_times)
