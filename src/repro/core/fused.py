"""Late materialization: fused scan→filter→aggregate plans (DESIGN.md §7).

The unfused path fully materializes every selected column of a row group
and then runs ``kernels/filter_agg.py`` as a separate launch — one extra
HBM round-trip of columns the predicate is about to throw away.  A
``FusedSpec`` attached to a Scanner/DecodePlanner splits the scan into:

  **stage A** (planner phases 1–2, unchanged machinery): decode the
  predicate/compare columns — plus any scanned column outside the spec —
  through the normal DecodePlan group path and evaluate their predicates
  host-side into a row mask;

  **stage B** (a new phase-3 work item): the *late* columns — aggregate
  operands and emit-only columns — are never materialized.  In aggregate
  mode their still-encoded page payloads ride into ONE
  ``kernels/fused_agg`` launch together with the stage-A mask (codes
  unpack, dictionary gather / PLAIN bitcast, residual predicates and the
  ``sum(left*right)`` reduce all happen in-kernel, one float32 partial
  per page).  Pages ruled out by the writer's per-page zone maps
  (``vmin``/``vmax`` in ``PageMeta.extra``) or by an all-false stage-A
  selection never enter the kernel arena at all — their canonical
  partial is exactly +0.0.  In selection mode the stage-A mask becomes a
  selection vector (ascending int64 row indices) and emit-only columns
  are materialized only when at least one row survived.

**Bit-identity contract.**  The canonical result of a predicated scan is
defined per page: the float32 partial of
``kernels/fused_agg.mask_and_reduce`` over the page's (1, P) padded
block, then ``float(np.sum(partials, dtype=np.float64))`` per row group,
then plan-order accumulation across row groups.  Reference execution
(``mode="reference"``, or any row group whose shape the fused plan
cannot take — cascade-coded operands, non-fusable aggregate inputs,
misaligned page layouts) materializes everything through the unfused
path and evaluates the SAME traced expression on the same page blocks,
so fused and unfused results diff exactly, which CI enforces
(tools/check_fused_identity.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.compression import Codec
from repro.core.encodings import Encoding
from repro.core.schema import PhysicalType

#: key under which a fused scan's per-row-group result is delivered in the
#: decoded-columns dict (late columns themselves are absent from it)
FUSED_KEY = "__fused__"

_NUMERIC_CAST = {
    PhysicalType.FLOAT: np.float32,
    PhysicalType.DOUBLE: np.float64,
    PhysicalType.INT32: np.int64,
    PhysicalType.INT64: np.int64,
}


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Interval:
    """Single-column predicate: optional lo/hi bounds and/or a value set.
    Bounds are compared in the column's decoded dtype (float32 columns
    compare against float32-cast constants — same bits as the unfused
    consumers)."""
    column: str
    lo: float | int | None = None
    hi: float | int | None = None
    lo_incl: bool = True
    hi_incl: bool = False
    in_set: tuple | None = None


@dataclasses.dataclass(frozen=True)
class Compare:
    """Cross-column predicate ``left < right`` (strict).  Both columns
    always decode in stage A."""
    left: str
    right: str


@dataclasses.dataclass(frozen=True)
class SumProduct:
    """Aggregate ``sum(left * right)`` over selected rows."""
    left: str
    right: str


@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """Predicate + aggregate/emit spec a Scanner executes fused.

    Exactly one of aggregate mode (``agg`` set, ``emit`` empty — the
    per-RG result is a float partial) or selection mode (``agg`` None —
    the result is a selection vector plus gathered emit columns).
    ``mode="reference"`` executes unfused but computes the identical
    canonical result — the bit-identity twin CI diffs against.
    """
    predicates: tuple = ()
    compares: tuple = ()
    agg: SumProduct | None = None
    emit: tuple = ()
    mode: str = "fused"            # "fused" | "reference"

    def __post_init__(self):
        if self.mode not in ("fused", "reference"):
            raise ValueError(f"unknown fused mode {self.mode!r}")
        if self.agg is not None and self.emit:
            raise ValueError("aggregate and emit modes are exclusive")
        if self.agg is None and not (self.predicates or self.compares):
            raise ValueError("selection mode needs at least one predicate")

    def columns(self) -> list[str]:
        """Spec columns in canonical order (predicates, compares, agg,
        emit), deduplicated."""
        seen: dict[str, None] = {}
        for iv in self.predicates:
            seen.setdefault(iv.column)
        for cmp in self.compares:
            seen.setdefault(cmp.left)
            seen.setdefault(cmp.right)
        if self.agg is not None:
            seen.setdefault(self.agg.left)
            seen.setdefault(self.agg.right)
        for name in self.emit:
            seen.setdefault(name)
        return list(seen)

    def with_mode(self, mode: str) -> "FusedSpec":
        return dataclasses.replace(self, mode=mode)


# ---------------------------------------------------------------------------
# per-row-group fused plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OperandInfo:
    """One stage-B kernel operand (see kernels/fused_agg.py cfg format)."""
    name: str
    kind: str          # 'dict' | 'plain'
    width: int         # dict code bit width (0 for plain)
    vdtype: str        # 'float32' | 'int32'
    cfg: tuple         # static kernel config tuple


@dataclasses.dataclass
class FusedRGPlan:
    """How one row group executes under a FusedSpec.  ``ok=False`` means
    the shape is unsupported and the row group runs reference execution
    (full materialization, canonical compute) — correctness never depends
    on fusability."""
    ok: bool
    why: str
    n_pages: int
    page_counts: list[int]
    P: int                         # padded page lanes (pow2, >= 32)
    late: list[str]                # columns excluded from stage A
    operands: list[OperandInfo]    # aggregate-mode kernel operands
    zone_skip: frozenset           # pages provably all-false by zone maps

    @property
    def cfg(self) -> tuple:
        return tuple(op.cfg for op in self.operands)


class FusedRGResult:
    """Per-row-group result of a fused (or reference) execution, delivered
    as ``cols[FUSED_KEY]``.  Duck-types the two DecodeResult attributes the
    accounting layer reads (``on_device``, ``logical_bytes``)."""

    on_device = False

    __slots__ = ("partial", "partials", "selection", "gathered", "n_rows",
                 "n_selected", "pages_total", "pages_skipped",
                 "logical_bytes", "reference")

    def __init__(self, *, partial, partials, selection, gathered, n_rows,
                 n_selected, pages_total, pages_skipped, logical_bytes,
                 reference):
        self.partial = partial          # float | None (aggregate mode)
        self.partials = partials        # (n_pages,) float32 canonical
        self.selection = selection      # int64 row indices | None
        self.gathered = gathered        # {emit column: canonical ndarray}
        self.n_rows = n_rows
        self.n_selected = n_selected
        self.pages_total = pages_total
        self.pages_skipped = pages_skipped
        self.logical_bytes = logical_bytes
        self.reference = reference      # ran the unfused twin


def _iv_cfg(iv: Interval, vdtype: str, role: str = "",
            kind: str = "host", width: int = 0) -> tuple:
    return (kind, width, vdtype,
            iv.lo if iv is not None else None,
            iv.hi if iv is not None else None,
            iv.lo_incl if iv is not None else True,
            iv.hi_incl if iv is not None else False,
            tuple(iv.in_set) if iv is not None and iv.in_set is not None
            else None,
            role)


def _value_dtype(field) -> str | None:
    if field.physical == PhysicalType.FLOAT:
        return "float32"
    if field.physical == PhysicalType.INT32:
        return "int32"
    return None


def _operand_info(meta, rg, name: str, ivs: list, role: str
                  ) -> OperandInfo | None:
    """Kernel-fusable check for one column; None → it stays in stage A."""
    if len(ivs) > 1:
        return None                   # cfg carries at most one interval
    chunk = rg.column(name)
    field = meta.schema.field(name)
    if Codec(chunk.codec) not in (Codec.NONE, Codec.GZIP):
        return None                   # cascade payloads need device inflate
    vdtype = _value_dtype(field)
    if vdtype is None:
        return None                   # int64/double/bool/strings: stage A
    enc = Encoding(chunk.encoding)
    if enc == Encoding.RLE_DICTIONARY:
        widths = {pm.extra.get("bitwidth") for pm in chunk.pages}
        if len(widths) != 1:
            return None               # kernel width is static per launch
        width = widths.pop()
        if not isinstance(width, int) or width < 1 or width > 32:
            return None
        kind = "dict"
    elif enc == Encoding.PLAIN:
        kind, width = "plain", 0
    else:
        return None
    iv = ivs[0] if ivs else None
    return OperandInfo(name=name, kind=kind, width=int(width), vdtype=vdtype,
                       cfg=_iv_cfg(iv, vdtype, role, kind, int(width)))


def _interval_excludes(iv: Interval, cast, vmin, vmax) -> bool:
    """True when the page's [vmin, vmax] zone map proves the predicate
    false for every value on the page (conservative — equality keeps)."""
    if iv.lo is not None:
        lo = float(cast(iv.lo))
        if (vmax < lo) if iv.lo_incl else (vmax <= lo):
            return True
    if iv.hi is not None:
        hi = float(cast(iv.hi))
        if (vmin > hi) if iv.hi_incl else (vmin >= hi):
            return True
    if iv.in_set is not None:
        if all(float(cast(s)) < vmin or float(cast(s)) > vmax
               for s in iv.in_set):
            return True
    return False


def build_fused_rg_plan(planner, rg_index: int) -> FusedRGPlan:
    """Classify one row group under the planner's FusedSpec: stage-A vs
    late columns, kernel operand configs, zone-map page skips."""
    spec = planner.fused_spec
    meta = planner.meta
    rg = meta.row_groups[rg_index]
    cols = spec.columns()

    def bail(why: str) -> FusedRGPlan:
        return FusedRGPlan(ok=False, why=why, n_pages=0, page_counts=[],
                           P=32, late=[], operands=[],
                           zone_skip=frozenset())

    for c in cols:
        if c not in planner.columns:
            return bail(f"spec column {c} not in the scan selection")
    counts = [pm.n_values for pm in rg.column(cols[0]).pages]
    if not counts:
        return bail("row group has no pages")
    for c in cols[1:]:
        if [pm.n_values for pm in rg.column(c).pages] != counts:
            # the writer slices every column by the same rows_per_page, so
            # this only triggers on foreign/hand-built files
            return bail(f"page layout of {c} not row-aligned")
    P = max(32, _next_pow2(max(counts)))
    preds_by_col: dict[str, list[Interval]] = {}
    for iv in spec.predicates:
        preds_by_col.setdefault(iv.column, []).append(iv)
    compare_cols = {c for cmp in spec.compares
                    for c in (cmp.left, cmp.right)}

    late: list[str] = []
    operands: list[OperandInfo] = []
    if spec.agg is not None:
        for name in cols:
            role = ""
            if name == spec.agg.left and name == spec.agg.right:
                role = "both"
            elif name == spec.agg.left:
                role = "left"
            elif name == spec.agg.right:
                role = "right"
            if name in compare_cols:
                if role:
                    return bail(f"aggregate operand {name} is also a "
                                "compare column")
                continue                       # stage A
            ivs = preds_by_col.get(name, [])
            if not role and not ivs:
                continue                       # untouched by this spec
            info = _operand_info(meta, rg, name, ivs, role)
            if info is None:
                if role:
                    return bail(f"aggregate operand {name} is not "
                                "kernel-fusable here")
                continue                       # predicate stays in stage A
            late.append(name)
            operands.append(info)
    else:
        # selection mode: every predicate/compare column evaluates in
        # stage A; emit-only columns are late (materialized on demand)
        for name in spec.emit:
            if name in preds_by_col or name in compare_cols:
                continue
            field = meta.schema.field(name)
            if field.physical == PhysicalType.BYTE_ARRAY:
                return bail(f"string emit column {name} unsupported")
            late.append(name)

    zone_skip = set()
    for name, ivs in preds_by_col.items():
        field = meta.schema.field(name)
        cast = _NUMERIC_CAST.get(field.physical)
        if cast is None:
            continue
        for i, pm in enumerate(rg.column(name).pages):
            if i in zone_skip or "vmin" not in pm.extra:
                continue
            vmin, vmax = float(pm.extra["vmin"]), float(pm.extra["vmax"])
            if any(_interval_excludes(iv, cast, vmin, vmax) for iv in ivs):
                zone_skip.add(i)
    return FusedRGPlan(ok=True, why="", n_pages=len(counts),
                       page_counts=counts, P=P, late=late,
                       operands=operands, zone_skip=frozenset(zone_skip))


# ---------------------------------------------------------------------------
# execution (the planner's phase-3 work item)
# ---------------------------------------------------------------------------

def _payload_bytes(payloads, name: str, page_index: int) -> bytes:
    p = payloads[(name, page_index)]
    if isinstance(p, tuple):
        raw, lo, size = p
        return raw[lo:lo + size]
    return p


def _materialize(planner, ctx, name: str):
    """Assembled DecodeResult for a stage-A column (phase 3 runs before
    finish_execute, so grouped columns assemble here on first use;
    fallback/demoted columns are already in ctx.out)."""
    res = ctx.out.get(name)
    if res is not None:
        return res
    chunk = ctx.rg.column(name)
    field = planner.meta.schema.field(name)
    res = planner._assemble_column(chunk, field, ctx.per_col_parts[name],
                                   ctx.payloads)
    ctx.out[name] = res
    return res


def _page_rows(arr: np.ndarray, counts: list[int], P: int,
               dtype=None) -> np.ndarray:
    """(n_rows,) → (n_pages, P) padded page matrix (pad lanes zero —
    always masked out by the validity lanes of the mask matrix)."""
    out = np.zeros((len(counts), P), dtype=dtype or arr.dtype)
    off = 0
    for i, c in enumerate(counts):
        out[i, :c] = arr[off:off + c]
        off += c
    return out


def _stage_a_mask(planner, ctx, spec, fplan, reference: bool) -> np.ndarray:
    """Row mask from every predicate evaluated host-side: all of them
    under reference/selection execution, the non-late ones under fused
    aggregate execution (late predicates fold into the kernel).  Numpy
    compares on the decoded values — exact, so the mask bits match what
    the kernel would compute."""
    from repro.kernels.fused_agg import apply_predicates
    late = set() if reference else set(fplan.late)
    n_rows = sum(fplan.page_counts)
    mask = np.ones(n_rows, dtype=bool)
    vals_cache: dict[str, np.ndarray] = {}

    def vals(name):
        v = vals_cache.get(name)
        if v is None:
            v = np.asarray(_materialize(planner, ctx, name).array)
            vals_cache[name] = v
        return v

    for iv in spec.predicates:
        if iv.column in late:
            continue
        field = planner.meta.schema.field(iv.column)
        vdtype = _value_dtype(field) or "float32"
        mask = apply_predicates(mask, vals(iv.column),
                                _iv_cfg(iv, vdtype))
    for cmp in spec.compares:
        mask = mask & (vals(cmp.left) < vals(cmp.right))
    return mask


def _reduce_cfg(left_dtype: str, right_dtype: str) -> tuple:
    """Reference-twin cfg: two predicate-free operands in left/right roles
    (the full mask is precomputed host-side)."""
    return (("host", 0, left_dtype, None, None, True, False, None, "left"),
            ("host", 0, right_dtype, None, None, True, False, None, "right"))


def _host_decode_operand_page(planner, ctx, op: OperandInfo, rg,
                              page_index: int, count: int) -> np.ndarray:
    """Numpy twin of the in-kernel operand decode: identical values, so
    the host backend's fused partials match the pallas kernel's bits."""
    from repro.core import bitpack
    data = _payload_bytes(ctx.payloads, op.name, page_index)
    if op.kind == "dict":
        words = np.frombuffer(data, dtype=np.uint32, count=len(data) // 4)
        codes = bitpack.unpack(words, op.width,
                               (words.shape[0] // op.width) * 32)[:count]
        dic = planner._device_dictionary(rg, op.name, ctx.payloads).host
        codes = np.clip(codes.astype(np.int64), 0, dic.shape[0] - 1)
        return dic[codes]
    dt = np.float32 if op.vdtype == "float32" else np.int32
    return np.frombuffer(data, dtype=dt, count=count)


def _canonical_gather(values: np.ndarray, selection: np.ndarray
                      ) -> np.ndarray:
    """Gathered emit values in canonical dtype: integer columns widen to
    int64 (the device path narrows int64→int32, the host path keeps
    int64 — gathering through int64 makes both routes bit-identical)."""
    out = np.asarray(values)[selection]
    if out.dtype.kind in "iu":
        return out.astype(np.int64)
    return np.ascontiguousarray(out)


def _emit_dtype(field) -> np.dtype:
    if field.physical == PhysicalType.FLOAT:
        return np.dtype(np.float32)
    if field.physical == PhysicalType.DOUBLE:
        return np.dtype(np.float64)
    if field.physical == PhysicalType.BOOLEAN:
        return np.dtype(np.bool_)
    return np.dtype(np.int64)


def run_fused(planner, ctx) -> FusedRGResult:
    """The phase-3 work item: stage-A mask → fused kernel / selection
    gather (or the reference twin), producing the canonical per-RG
    result."""
    from repro.kernels.fused_agg import (fused_page_agg,
                                         reference_page_reduce)
    spec = planner.fused_spec
    fplan = ctx.fused_plan
    rg = ctx.rg
    if not fplan.ok:
        # rebuild page geometry from any spec column that exists; a spec
        # column missing from the scan selection is a caller error
        for c in spec.columns():
            if c not in planner.columns:
                raise ValueError(fplan.why)
        counts = [pm.n_values for pm in rg.column(spec.columns()[0]).pages]
        fplan = dataclasses.replace(
            fplan, n_pages=len(counts), page_counts=counts,
            P=max(32, _next_pow2(max(counts or [1]))), late=[],
            operands=[], zone_skip=frozenset())
    reference = (spec.mode == "reference") or not ctx.fused_plan.ok
    counts, P, n_pages = fplan.page_counts, fplan.P, fplan.n_pages
    n_rows = sum(counts)
    mask = _stage_a_mask(planner, ctx, spec, fplan, reference)
    mask_rows = _page_rows(mask.astype(np.uint8), counts, P)
    page_any = mask_rows.any(axis=1)

    if spec.agg is not None:
        partials = np.zeros(n_pages, dtype=np.float32)
        if reference:
            lname, rname = spec.agg.left, spec.agg.right
            lvals = np.asarray(_materialize(planner, ctx, lname).array)
            rvals = (lvals if rname == lname
                     else np.asarray(_materialize(planner, ctx,
                                                  rname).array))
            lrows = _page_rows(lvals, counts, P)
            rrows = lrows if rname == lname else _page_rows(rvals, counts, P)
            ldt = _value_dtype(planner.meta.schema.field(lname)) or "float32"
            rdt = _value_dtype(planner.meta.schema.field(rname)) or "float32"
            cfg = _reduce_cfg(ldt, rdt)
            for i in range(n_pages):
                partials[i] = np.float32(reference_page_reduce(
                    mask_rows[i:i + 1], lrows[i:i + 1], rrows[i:i + 1],
                    cfg=cfg))
            skipped = 0
        else:
            surv = [i for i in range(n_pages)
                    if i not in fplan.zone_skip and page_any[i]]
            skipped = n_pages - len(surv)
            if surv:
                if ctx.use_kernels:
                    arrays = []
                    for op in fplan.operands:
                        if op.kind == "dict":
                            wrow = (P // 32) * op.width
                            words = np.zeros((len(surv), wrow), np.uint32)
                            for r, i in enumerate(surv):
                                data = _payload_bytes(ctx.payloads,
                                                      op.name, i)
                                w = np.frombuffer(data, dtype=np.uint32,
                                                  count=len(data) // 4)
                                words[r, :min(w.shape[0], wrow)] = w[:wrow]
                            arrays.append(words)
                            arrays.append(planner._device_dictionary(
                                rg, op.name, ctx.payloads).device)
                        else:
                            words = np.zeros((len(surv), P), np.uint32)
                            for r, i in enumerate(surv):
                                data = _payload_bytes(ctx.payloads,
                                                      op.name, i)
                                w = np.frombuffer(data, dtype=np.uint32,
                                                  count=len(data) // 4)
                                words[r, :counts[i]] = w[:counts[i]]
                            arrays.append(words)
                    out = np.asarray(fused_page_agg(
                        mask_rows[surv], arrays, cfg=fplan.cfg))
                    partials[surv] = out
                else:
                    cfg = fplan.cfg
                    for i in surv:
                        rows = [_page_rows(
                            _host_decode_operand_page(planner, ctx, op, rg,
                                                      i, counts[i]),
                            [counts[i]], P)
                            for op in fplan.operands]
                        partials[i] = np.float32(reference_page_reduce(
                            mask_rows[i:i + 1], *rows, cfg=cfg))
        total = float(np.sum(partials, dtype=np.float64))
        return FusedRGResult(
            partial=total, partials=partials, selection=None, gathered={},
            n_rows=n_rows, n_selected=-1, pages_total=n_pages,
            pages_skipped=skipped, logical_bytes=int(partials.nbytes),
            reference=reference)

    # -- selection mode ----------------------------------------------------
    selection = np.flatnonzero(mask).astype(np.int64)
    n_selected = int(selection.shape[0])
    skipped = 0 if reference else int(n_pages - np.count_nonzero(page_any))
    gathered: dict[str, np.ndarray] = {}
    for name in spec.emit:
        field = planner.meta.schema.field(name)
        if n_selected == 0:
            gathered[name] = np.zeros(0, dtype=_emit_dtype(field))
            continue
        if not reference and name in fplan.late:
            # materialized on demand, host route (no extra kernel launch);
            # values are bit-identical to the device decode for the
            # canonical dtypes (_canonical_gather)
            from repro.kernels import ops
            chunk = ctx.rg.column(name)
            res = ops.decode_chunk(
                chunk, field, ctx.raws[name], use_kernels=False,
                payloads=planner._fallback_payloads(chunk, name, ctx.raws))
            values = np.asarray(res.array)
        else:
            values = np.asarray(_materialize(planner, ctx, name).array)
        gathered[name] = _canonical_gather(values, selection)
    logical = int(selection.nbytes
                  + sum(a.nbytes for a in gathered.values()))
    return FusedRGResult(
        partial=None, partials=None, selection=selection, gathered=gathered,
        n_rows=n_rows, n_selected=n_selected, pages_total=n_pages,
        pages_skipped=skipped, logical_bytes=logical, reference=reference)
