"""Scan flight recorder + process metrics registry (DESIGN.md §10).

The paper's thesis makes "why is this scan slow" the central operational
question, but end-of-run aggregates (ScanMetrics counters, stage_walls)
cannot show pipeline bubbles, prefetch stalls, steal storms, or retry
bursts *inside* a run.  This module records a bounded, thread-safe event
timeline — typed spans with thread/scan/fragment/RG attribution — that
exports as Chrome/Perfetto trace-event JSON (``chrome://tracing``,
https://ui.perfetto.dev) and feeds ``tools/trace_report.py``'s
critical-path and stage-bucket attribution.

Design constraints, in order:

1. **Off by default, near-zero cost when off.**  Every instrumented site
   guards on ``trace.active()`` — one module-global load and a None
   check — and reuses the ``perf_counter`` timestamps the site already
   takes for ScanMetrics, so tracing-off adds no timing calls and
   tracing-on adds one lock + list append per event (the ≤5% CI budget,
   tools/trace_check.py).
2. **Bounded.**  The recorder is a flight recorder, not a log: a global
   event cap plus a per-scan cap (one chatty scan cannot evict the
   others' events).  Overflow increments drop counters that export in
   the trace metadata — silent truncation never reads as "nothing
   happened".
3. **Thread-safe.**  Fetch threads, decode workers, consume threads,
   fragment workers and device workers all record concurrently; events
   carry their recording thread id for per-track rendering.

Enablement: the ``REPRO_TRACE`` environment variable (``1``/``true`` →
record; any other non-empty non-zero value → record *and* export to that
path at process exit), or programmatically via ``trace.request(...)`` —
the refcounted context manager behind every ``trace=`` kwarg
(``run_overlapped``, ``run_dataset_scan``, …): ``True`` records for the
duration, a path string additionally exports on exit.

The **metrics registry** is the aggregate sibling: process-wide
counters / gauges / histograms (pool depth, queue wait, inflight
credits, steals, kernel launches) that cost one dict update at coarse
boundaries and snapshot into ``ScanMetrics.registry_snapshot`` /
``DatasetRunReport.registry_snapshot`` — informational columns only,
never a gated count.  Registry updates at per-item granularity are also
gated on ``active()`` so the tracing-off hot path stays untouched.

Event vocabulary (``tools/trace_report.py`` buckets on these):

  cat "io"        fetch (per-RG coalesced batch), storage_read,
                  prefetch_issue / prefetch_hit / prefetch_miss,
                  retry_attempt / fetch_timeout / short_read
  cat "decode"    open, decompress (phase 1), transition, decode
                  (phase 2), fused (phase 3), finalize, decode_rg
                  (monolithic inline/blocking decode)
  cat "consume"   consume (per-RG reducer on the caller's thread)
  cat "scan"      scan (whole-run span), dataset_scan, distributed_scan
  cat "fragment"  fragment (per-attempt), shard_assign, steal,
                  quarantine
  cat "fault"     fault_injected, requeue, checksum_failure, deadline
  cat "kernel"    kernel_launch (instant, counted n)

Multi-tenant attribution (DESIGN.md §11): fetch and decode-item spans
emitted by the scheduler carry an ``args.tenant`` tag when the scan was
submitted under a registered tenant; ``window_hit`` instants (cat
"io") mark row groups served from the delivered-result window instead
of storage, and ``result_cache_hit`` instants mark whole fragments
served from the fragment result cache.  ``tools/trace_report.py`` aggregates these into a
per-tenant wall-attribution breakdown; untagged spans are charged to
the shared ``-`` tenant, mirroring the scheduler's weight-1 virtual
tenant.  The registry's tenancy surface: counters
``scheduler.window_hits``, ``scheduler.admission_rejects``,
``scheduler.admission_queued``, ``scheduler.slo_boosts``,
``result_cache.{hits,misses,evictions,invalidated}``, and one
``scheduler.tenant_depth.<name>`` gauge per tenant (current active
scans — the per-tenant queue depth).
"""

from __future__ import annotations

import json
import os
import threading
import time

#: default global event cap (REPRO_TRACE_CAP overrides); at ~7 events
#: per row group a 64k buffer holds ~9k row groups of timeline
DEFAULT_CAP = 65_536
#: per-scan share of the buffer: one scan label may hold at most this
#: fraction of the global cap before its events start dropping
PER_SCAN_FRACTION = 0.5


class TraceEvent:
    """One recorded event.  ``ts``/``dur`` are perf_counter seconds
    relative to the tracer's epoch; ``ph`` is the Chrome phase ("X"
    complete span, "i" instant)."""

    __slots__ = ("name", "cat", "ph", "ts", "dur", "tid", "args")

    def __init__(self, name: str, cat: str, ph: str, ts: float,
                 dur: float, tid: int, args: dict):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.args = args

    def to_chrome(self, pid: int) -> dict:
        ev = {"name": self.name, "cat": self.cat, "ph": self.ph,
              "ts": self.ts * 1e6, "pid": pid, "tid": self.tid}
        if self.ph == "X":
            ev["dur"] = self.dur * 1e6
        elif self.ph == "i":
            ev["s"] = "t"
        if self.args:
            ev["args"] = self.args
        return ev


class MetricsRegistry:
    """Process-wide counters / gauges / histograms.

    Lock-protected plain dicts: ``counter_inc`` adds, ``gauge_set``
    overwrites, ``observe`` accumulates (count, sum, min, max) — cheap
    enough for coarse-grained call sites (per row group / per resize),
    with per-item sites additionally gated on ``trace.active()``.
    ``snapshot()`` returns a plain-dict copy safe to stash in reports.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, list[float]] = {}

    def counter_inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                self._hists[name] = [1, value, value, value]
            else:
                h[0] += 1
                h[1] += value
                h[2] = min(h[2], value)
                h[3] = max(h[3], value)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {
                    name: {"count": h[0], "sum": h[1], "min": h[2],
                           "max": h[3], "mean": h[1] / max(1, h[0])}
                    for name, h in self._hists.items()},
            }

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


class Tracer:
    """Bounded thread-safe event recorder (see module docstring)."""

    def __init__(self, cap: int | None = None):
        if cap is None:
            cap = int(os.environ.get("REPRO_TRACE_CAP", DEFAULT_CAP))
        self.cap = max(16, cap)
        self.scan_cap = max(8, int(self.cap * PER_SCAN_FRACTION))
        self.epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._events: list[TraceEvent] = []
        self._per_scan: dict[object, int] = {}
        self.dropped = 0
        self.dropped_by_scan: dict[object, int] = {}

    # -- recording ----------------------------------------------------------

    def _admit_locked(self, args: dict) -> bool:
        if len(self._events) >= self.cap:
            self.dropped += 1
            return False
        scan = args.get("scan")
        if scan is not None:
            n = self._per_scan.get(scan, 0)
            if n >= self.scan_cap:
                self.dropped += 1
                self.dropped_by_scan[scan] = \
                    self.dropped_by_scan.get(scan, 0) + 1
                return False
            self._per_scan[scan] = n + 1
        return True

    def complete(self, name: str, cat: str, t0: float, t1: float,
                 **args) -> None:
        """Record a complete span from two perf_counter stamps the call
        site already took (the zero-extra-timing contract)."""
        with self._lock:
            if not self._admit_locked(args):
                return
            self._events.append(TraceEvent(
                name, cat, "X", t0 - self.epoch, max(0.0, t1 - t0),
                threading.get_ident(), args))

    def instant(self, name: str, cat: str, **args) -> None:
        ts = time.perf_counter() - self.epoch
        with self._lock:
            if not self._admit_locked(args):
                return
            self._events.append(TraceEvent(
                name, cat, "i", ts, 0.0, threading.get_ident(), args))

    class _Span:
        __slots__ = ("tracer", "name", "cat", "args", "t0")

        def __init__(self, tracer, name, cat, args):
            self.tracer = tracer
            self.name = name
            self.cat = cat
            self.args = args

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self.tracer.complete(self.name, self.cat, self.t0,
                                 time.perf_counter(), **self.args)

    def span(self, name: str, cat: str, **args) -> "Tracer._Span":
        """Context-manager span for sites without existing timestamps."""
        return Tracer._Span(self, name, cat, args)

    # -- inspection / export ------------------------------------------------

    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._events)

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._per_scan.clear()
            self.dropped = 0
            self.dropped_by_scan.clear()
        self.epoch = time.perf_counter()

    def to_chrome(self) -> dict:
        """The Chrome/Perfetto trace-event document (``traceEvents`` +
        metadata: drop counters and the registry snapshot)."""
        pid = os.getpid()
        with self._lock:
            events = [e.to_chrome(pid) for e in self._events]
            dropped = self.dropped
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "dropped": dropped,
                "cap": self.cap,
                "registry": registry().snapshot(),
            },
        }

    def export(self, path: str) -> str:
        doc = self.to_chrome()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


# ---------------------------------------------------------------------------
# module-level enablement (env var + refcounted request())
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_tracer: Tracer | None = None
_env_checked = False
_requests = 0          # active trace.request() contexts
_env_on = False        # REPRO_TRACE kept the tracer on
_registry = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide metrics registry (always available; callers at
    per-item granularity should still gate on ``active()``)."""
    return _registry


def _resolve_env_locked() -> None:
    global _env_checked, _env_on, _tracer
    _env_checked = True
    val = os.environ.get("REPRO_TRACE", "").strip()
    if not val or val.lower() in ("0", "off", "false", "none"):
        return
    _env_on = True
    if _tracer is None:
        _tracer = Tracer()
    if val.lower() not in ("1", "true", "on", "yes"):
        # a path value: export the flight recorder at process exit
        import atexit
        tr = _tracer
        atexit.register(lambda: tr.export(val))


def active() -> Tracer | None:
    """The live tracer, or None when tracing is off — THE hot-path guard
    every instrumented site calls (module-global load + None check)."""
    tr = _tracer
    if tr is not None:
        return tr
    if _env_checked:
        return None
    with _lock:
        if not _env_checked:
            _resolve_env_locked()
        return _tracer


def enabled() -> bool:
    return active() is not None


def enable(cap: int | None = None) -> Tracer:
    """Turn the recorder on (idempotent); returns the tracer."""
    global _tracer, _env_checked
    with _lock:
        if not _env_checked:
            _resolve_env_locked()
        if _tracer is None:
            _tracer = Tracer(cap=cap)
        return _tracer


def disable() -> None:
    """Turn the recorder off.  The Tracer object itself stays valid for
    callers still holding a reference (events remain readable)."""
    global _tracer
    with _lock:
        _tracer = None


def reset() -> None:
    """Test hook: drop the tracer, forget the env resolution, zero the
    refcount, and clear the registry — the next ``active()`` re-reads
    REPRO_TRACE."""
    global _tracer, _env_checked, _requests, _env_on
    with _lock:
        _tracer = None
        _env_checked = False
        _requests = 0
        _env_on = False
    _registry.clear()


class _Request:
    """Refcounted enable: nested/concurrent ``trace=`` runs share one
    tracer; the recorder turns off only when the last request exits and
    REPRO_TRACE didn't independently keep it on."""

    def __init__(self, path: str | None):
        self.path = path
        self.tracer: Tracer | None = None

    def __enter__(self) -> Tracer:
        global _requests
        self.tracer = enable()
        with _lock:
            _requests += 1
        return self.tracer

    def __exit__(self, *exc) -> None:
        global _requests
        if self.path is not None:
            self.tracer.export(self.path)
        with _lock:
            _requests = max(0, _requests - 1)
            last = _requests == 0
        if last and not _env_on:
            disable()


class _NullRequest:
    def __enter__(self) -> Tracer | None:
        return active()

    def __exit__(self, *exc) -> None:
        pass


def request(arg: bool | str | None):
    """The context manager behind every ``trace=`` kwarg:

      None / False   no change (returns whatever is already active)
      True           record for the duration of the context
      "<path>"       record and export Chrome JSON to <path> on exit
    """
    if arg is None or arg is False:
        return _NullRequest()
    return _Request(arg if isinstance(arg, str) else None)
