"""Bit-transposed packing (host reference implementation).

Layout: values are packed in groups of 32.  A group with bit-width ``w``
occupies ``w`` uint32 words; word ``k`` holds bit ``k`` of all 32 values
(bit ``i`` of word ``k`` = bit ``k`` of value ``i``).

This is the FastLanes-style interleaved ("bit-transposed") order rather than
Parquet's sequential little-endian order: unpacking becomes ``w`` independent
shift/mask/or steps over full vector lanes, which maps directly onto the TPU
VPU (and is the layout the Pallas kernels consume).  The choice of bit order
inside an encoding is writer-private in our container (DESIGN.md §9.2).

Widths up to 64 are supported on the host path (int64 deltas); the device
kernels consume widths ≤ 32.
"""

from __future__ import annotations

import numpy as np

GROUP = 32  # values per packing group


def bit_width(max_value: int) -> int:
    """Minimum width to represent max_value (≥ 0); at least 1."""
    if max_value < 0:
        raise ValueError("bit_width of negative value")
    return max(1, int(max_value).bit_length())


def _as_groups(values: np.ndarray) -> np.ndarray:
    n = values.shape[0]
    n_groups = -(-n // GROUP)
    padded = np.zeros(n_groups * GROUP, dtype=np.uint64)
    padded[:n] = values.astype(np.uint64, copy=False)
    return padded.reshape(n_groups, GROUP)


def pack(values: np.ndarray, width: int) -> np.ndarray:
    """Pack non-negative ints into bit-transposed uint32 words.

    Returns an array of shape (n_groups * width,) — group-major, i.e. the
    ``width`` words of group 0 first.
    """
    if width < 1 or width > 64:
        raise ValueError(f"width {width} out of range")
    groups = _as_groups(values)  # (G, 32) uint64
    lane = np.arange(GROUP, dtype=np.uint64)
    words = np.empty((groups.shape[0], width), dtype=np.uint32)
    for k in range(width):
        bits = (groups >> np.uint64(k)) & np.uint64(1)
        words[:, k] = np.bitwise_or.reduce(
            (bits << lane), axis=1).astype(np.uint32)
    return words.reshape(-1)


def unpack(words: np.ndarray, width: int, n: int,
           out_dtype=np.uint64) -> np.ndarray:
    """Inverse of :func:`pack`; returns the first ``n`` values.

    Widths ≤ 32 run the shift/or loop in uint32 — half the memory traffic
    of the uint64 path, which matters because this loop dominates host
    decode time for dictionary-encoded scans.
    """
    if width < 1 or width > 64:
        raise ValueError(f"width {width} out of range")
    words = np.ascontiguousarray(words, dtype=np.uint32)
    n_groups = words.shape[0] // width
    if width <= 32:
        w = words.reshape(n_groups, width)
        lane = np.arange(GROUP, dtype=np.uint32)
        vals = np.zeros((n_groups, GROUP), dtype=np.uint32)
        for k in range(width):
            vals |= ((w[:, k, None] >> lane) & np.uint32(1)) << np.uint32(k)
        return vals.reshape(-1)[:n].astype(out_dtype)
    w = words.reshape(n_groups, width).astype(np.uint64)
    lane = np.arange(GROUP, dtype=np.uint64)
    vals = np.zeros((n_groups, GROUP), dtype=np.uint64)
    for k in range(width):
        vals |= ((w[:, k, None] >> lane) & np.uint64(1)) << np.uint64(k)
    return vals.reshape(-1)[:n].astype(out_dtype)


def packed_words(n_values: int, width: int) -> int:
    """Number of uint32 words pack() produces."""
    return (-(-n_values // GROUP)) * width
