# The paper's primary contribution: a Parquet-faithful columnar file layer
# ("TabFile") whose configuration knobs are the paper's four insights, plus
# the rewriter, device scan engine, overlap executor and query operators.

from repro.core.config import (ACCELERATOR_OPTIMIZED, CPU_DEFAULT,
                               TPU_CASCADE, CompressionSpec, EncodingPolicy,
                               FileConfig, intermediate_configs)
from repro.core.schema import Field, LogicalType, PhysicalType, Schema
from repro.core.table import StringColumn, Table
from repro.core.writer import TabFileWriter, write_table
from repro.core.reader import TabFileReader, read_footer

__all__ = [
    "ACCELERATOR_OPTIMIZED", "CPU_DEFAULT", "TPU_CASCADE", "CompressionSpec",
    "EncodingPolicy", "FileConfig", "intermediate_configs", "Field",
    "LogicalType", "PhysicalType", "Schema", "StringColumn", "Table",
    "TabFileWriter", "write_table", "TabFileReader", "read_footer",
]
