"""Row-group-level decode planning: cross-column batched decode (DESIGN.md §2.4).

The per-chunk decode path (kernels/ops.py::decode_chunk) issues one Pallas
call per column chunk — and per stride/width group inside it — so a
16-column row group pays ~16+ kernel launches.  Insight 1 of the paper says
GPU scan throughput comes from exposing *all* pages to the device at once;
this module takes that to its logical end at row-group granularity:

  1. a host-side **planning pass** walks every selected column chunk of a
     row group and groups all data pages — across columns — by
     ``(encoding, codec, bitwidth/stride class)``;
  2. each group's payloads are packed into one preallocated uint32 **arena**
     (contiguous page runs are copied with a single reshape copy, not one
     ``np.frombuffer`` per page);
  3. **one Pallas call per group** decodes pages from many columns at once
     (O(encoding groups) launches instead of O(columns × stride groups));
  4. decoded rows are scattered back into per-column ``DecodeResult``s that
     are bit-identical to the per-chunk reference path.

Plans depend only on the file footer + column selection, so they are cached
(module-level LRU) and repeated scans — the serving/query loop — skip
planning entirely.

The same plan also drives the *host* backend: group execution batches the
``bitpack.unpack`` / run-expansion work across every page of a group, which
collapses the per-page numpy call overhead that dominates host decode for
many-page files (see benchmarks/bench_scan_plan.py).

Class parameters (the padding buckets) are powers of two so ragged page
shapes across columns land in O(log size) groups; padded regions decode to
don't-care values past each page's true ``n_values`` and are sliced away.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import threading
import time
from collections import OrderedDict
from collections.abc import Callable, Sequence

import numpy as np

from repro.core import bitpack
from repro.core import fused as fused_mod
from repro.core.compression import (Codec, cascade_manifest,
                                    chunk_decompress_memo, decompress,
                                    verify_page)
from repro.core.encodings import (Encoding, build_delta_manifest,
                                  decode_plain_page)
from repro.core.metadata import ChunkMeta, FileMeta, PageMeta
from repro.core.schema import Field, PhysicalType
from repro.kernels import dict_decode, ops

_INT_TYPES = (PhysicalType.INT32, PhysicalType.INT64)

# A cross-column dictionary group ships one padded dictionary row per page
# (n_pages × d_max).  Beyond this arena size the duplication costs more
# than the saved launches, so the planner splits the group per column and
# each sub-group uses the shared-dictionary kernel instead.
_DICT_ARENA_CAP_BYTES = 16 * 1024 * 1024


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


_planner_token_counter = itertools.count()


# ---------------------------------------------------------------------------
# arena pool
# ---------------------------------------------------------------------------

class ArenaPool:
    """Reusable decode-arena buffers (DESIGN.md §2.4).

    ``take`` returns a ``(shape, dtype)`` ndarray view over a pooled byte
    buffer; ``give`` returns the buffer once the row group's kernels have
    consumed it, so consecutive row groups of the same file share arenas
    instead of paying a fresh ``np.zeros`` each (the PR-1 allocation).
    Reused buffers are **not** re-zeroed: arena words past each page's
    payload decode to don't-care values that the scatter stage slices away
    (``n_values``-exact), so zero-filling per row group is pure overhead.

    Thread-safe (the pipeline executor's decode workers share the planner);
    byte-capped — buffers beyond ``max_bytes`` are dropped on ``give``.
    """

    def __init__(self, max_bytes: int = 32 * 1024 * 1024):
        self.max_bytes = max_bytes
        self._free: dict[int, list[np.ndarray]] = {}
        self._lock = threading.Lock()
        self._pooled_bytes = 0
        self.allocs = 0
        self.reuses = 0

    def take(self, shape: tuple[int, ...], dtype
             ) -> tuple[np.ndarray, np.ndarray]:
        """Returns ``(view, buffer)``; pass ``buffer`` back to ``give``."""
        dt = np.dtype(dtype)
        need = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        cap = _next_pow2(need)
        buf = None
        with self._lock:
            stack = self._free.get(cap)
            if stack:
                buf = stack.pop()
                self._pooled_bytes -= cap
                self.reuses += 1
        if buf is None:
            buf = np.zeros(cap, dtype=np.uint8)
            self.allocs += 1
        return buf[:need].view(dt).reshape(shape), buf

    def give(self, buf: np.ndarray) -> None:
        cap = buf.shape[0]
        with self._lock:
            if self._pooled_bytes + cap <= self.max_bytes:
                self._free.setdefault(cap, []).append(buf)
                self._pooled_bytes += cap


# ---------------------------------------------------------------------------
# plan structures
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PageSlot:
    """One data page's place in a decode group (column + page index)."""
    column: str
    page_index: int
    n_values: int


@dataclasses.dataclass
class DecodeGroup:
    """Pages from any number of columns that decode in one batched call."""
    key: tuple                    # (encoding, codec, *class params)
    encoding: Encoding
    codec: Codec
    slots: list[PageSlot]

    @property
    def n_pages(self) -> int:
        return len(self.slots)


@dataclasses.dataclass
class CascadeGroup:
    """Device-cascade pages sharing one (value_width, count_width) class —
    one ``cascade_decode_pages`` launch.  Grouped at *plan* time from the
    widths the writer stamps into ``PageMeta.extra`` (``cascade_vw/cw``);
    ``key=None`` collects pages of older files without the stamp, which
    fall back to execute-time grouping by manifest widths."""
    key: tuple[int, int] | None
    slots: list[PageSlot]


@dataclasses.dataclass
class RowGroupPlan:
    rg_index: int
    groups: list[DecodeGroup]
    grouped_columns: list[str]    # decoded via the batched group path
    fallback_columns: list[str]   # decoded via the per-chunk reference path
    # decompress sub-plan: grouped columns whose pages inflate on the host
    # through the chunk memo vs. raw-view columns vs. device-cascade pages
    # (the latter pre-grouped by (vw, cw) — see CascadeGroup)
    memo_columns: list[str] = dataclasses.field(default_factory=list)
    raw_columns: list[str] = dataclasses.field(default_factory=list)
    cascade_groups: list[CascadeGroup] = dataclasses.field(
        default_factory=list)

    @property
    def n_groups(self) -> int:
        return len(self.groups)


# ---------------------------------------------------------------------------
# eligibility / group keys
#
# The key functions mirror the fallback conditions in ops.decode_chunk so
# the plan path takes the device (or batched-host) route exactly when the
# per-chunk reference path would — required for bit-identical results.
# ---------------------------------------------------------------------------

_DICT_DEVICE_DTYPE = {
    PhysicalType.INT32: "int32",
    PhysicalType.INT64: "int32",      # narrowed (stats-gated below)
    PhysicalType.FLOAT: "float32",
    PhysicalType.BOOLEAN: "uint8",
}


def _pallas_page_keys(chunk: ChunkMeta, field: Field) -> list[tuple] | None:
    """Per-page group keys for the device path, or None → per-chunk fallback."""
    enc = Encoding(chunk.encoding)
    codec = int(chunk.codec)
    if not chunk.pages:
        return None
    if enc == Encoding.RLE_DICTIONARY:
        dt = _DICT_DEVICE_DTYPE.get(field.physical)
        if dt is None:
            return None
        if (field.physical == PhysicalType.INT64
                and not ops._stats_fit_int32(chunk)):
            return None
        return [(int(enc), codec, pm.extra["bitwidth"], dt)
                for pm in chunk.pages]
    if enc == Encoding.DELTA_BINARY_PACKED:
        if not ops._stats_fit_int32(chunk):
            return None
        if max(pm.extra["n_blocks"] for pm in chunk.pages) == 0:
            return None
        return [(int(enc), codec, _next_pow2(max(pm.extra["n_blocks"], 1)))
                for pm in chunk.pages]
    if enc == Encoding.RLE:
        if (field.physical == PhysicalType.INT64
                and not ops._stats_fit_int32(chunk)):
            return None
        if any(pm.extra["n_runs"] > ops._RLE_MAX_RUNS for pm in chunk.pages):
            return None
        vdt = "int64" if field.physical == PhysicalType.INT64 else "int32"
        return [(int(enc), codec,
                 _next_pow2(-(-max(pm.n_values, 1) // 1024)) * 1024, vdt)
                for pm in chunk.pages]
    if enc == Encoding.BYTE_STREAM_SPLIT:
        if field.physical != PhysicalType.FLOAT:
            return None
        return [(int(enc), codec,
                 _next_pow2((pm.n_values + (-pm.n_values) % 4) // 4))
                for pm in chunk.pages]
    # PLAIN is a memcpy (no kernel launch to save); strings/float64 are
    # host-path encodings — the per-chunk reference handles all of them.
    return None


def _host_page_keys(chunk: ChunkMeta, field: Field) -> list[tuple] | None:
    """Group keys for the batched-host path (no padding classes needed —
    numpy handles ragged pages; keys only separate incompatible layouts)."""
    enc = Encoding(chunk.encoding)
    codec = int(chunk.codec)
    if not chunk.pages:
        return None
    if enc == Encoding.RLE_DICTIONARY:
        if field.physical == PhysicalType.BYTE_ARRAY:
            return None               # StringColumn dictionaries: reference
        return [(int(enc), codec, pm.extra["bitwidth"]) for pm in chunk.pages]
    if enc == Encoding.DELTA_BINARY_PACKED:
        if field.physical not in _INT_TYPES:
            return None
        return [(int(enc), codec) for pm in chunk.pages]
    if enc == Encoding.RLE:
        vdt = "int64" if field.physical == PhysicalType.INT64 else "int32"
        return [(int(enc), codec, vdt) for pm in chunk.pages]
    return None


# ---------------------------------------------------------------------------
# staged execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecContext:
    """Shared state of one row group's staged decode (per-chunk dispatch).

    Built by ``DecodePlanner.begin_execute``; mutated by the decompress /
    decode work items; consumed by ``finish_execute``.  Tasks of one
    context may run concurrently on the ScanService's decode pool — each
    writes disjoint keys, see the concurrency contract in DecodePlanner.
    """
    rg_index: int
    plan: RowGroupPlan
    rg: object                       # RowGroupMeta
    raws: dict[str, bytes]
    use_kernels: bool
    per_col_parts: dict[str, dict]
    payloads: dict = dataclasses.field(default_factory=dict)
    demoted: list[str] = dataclasses.field(default_factory=list)
    out: dict[str, "ops.DecodeResult"] = dataclasses.field(
        default_factory=dict)
    leases: list[np.ndarray] = dataclasses.field(default_factory=list)
    # late-materialization state (core/fused.py): the per-RG fused plan
    # and the phase-3 result delivered under FUSED_KEY
    fused_plan: object = None
    fused_result: object = None


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

class DecodePlanner:
    """Builds + caches RowGroupPlans for one (file, column selection).

    ``backend`` is 'pallas' (batched device groups) or 'host' (batched numpy
    groups); both scatter back into per-column results bit-identical to the
    per-chunk path of the same backend.
    """

    def __init__(self, meta: FileMeta, columns: Sequence[str],
                 backend: str = "pallas",
                 cache_token: tuple | None = None,
                 fused_spec: "fused_mod.FusedSpec | None" = None):
        assert backend in ("pallas", "host")
        self.meta = meta
        self.columns = list(columns)
        self.backend = backend
        self.fused_spec = fused_spec
        self._fused_plans: dict[int, "fused_mod.FusedRGPlan"] = {}
        self._plans: dict[int, RowGroupPlan] = {}
        self.plans_built = 0
        self.plan_seconds = 0.0
        # identifies the file *contents* this planner decodes; keys the
        # cross-row-group dictionary cache and decompress memo so a
        # same-path rewrite can never serve stale entries
        self.cache_token = (cache_token if cache_token is not None
                            else ("planner", next(_planner_token_counter)))
        self._plan_lock = threading.Lock()
        self._arena_pool = ArenaPool()

    # -- planning ----------------------------------------------------------

    def plan_rg(self, rg_index: int) -> RowGroupPlan:
        plan = self._plans.get(rg_index)
        if plan is not None:
            return plan
        with self._plan_lock:     # decode workers may plan concurrently
            plan = self._plans.get(rg_index)
            if plan is not None:
                return plan
            t0 = time.perf_counter()
            key_fn = (_pallas_page_keys if self.backend == "pallas"
                      else _host_page_keys)
            rg = self.meta.row_groups[rg_index]
            # late materialization: under a fused-mode spec the late
            # columns never enter the stage-A plan at all — their pages
            # decode (or are skipped) inside the phase-3 fused item
            late: frozenset = frozenset()
            if (self.fused_spec is not None
                    and self.fused_spec.mode == "fused"):
                fp = self._fused_plan_locked(rg_index)
                if fp.ok:
                    late = frozenset(fp.late)
            groups: "OrderedDict[tuple, DecodeGroup]" = OrderedDict()
            grouped, fallback = [], []
            for name in self.columns:
                if name in late:
                    continue
                chunk = rg.column(name)
                field = self.meta.schema.field(name)
                keys = key_fn(chunk, field)
                if keys is None:
                    fallback.append(name)
                    continue
                grouped.append(name)
                for pi, (pm, key) in enumerate(zip(chunk.pages, keys)):
                    g = groups.get(key)
                    if g is None:
                        g = DecodeGroup(key=key, encoding=Encoding(key[0]),
                                        codec=Codec(key[1]), slots=[])
                        groups[key] = g
                    g.slots.append(PageSlot(name, pi, pm.n_values))
            final: list[DecodeGroup] = []
            for g in groups.values():
                final.extend(self._split_oversize_dict_group(g, rg))
            plan = RowGroupPlan(rg_index, final, grouped, fallback)
            self._plan_decompress_stage(plan, rg)
            self._plans[rg_index] = plan
            self.plans_built += 1
            self.plan_seconds += time.perf_counter() - t0
            return plan

    def fused_plan_rg(self, rg_index: int) -> "fused_mod.FusedRGPlan":
        fp = self._fused_plans.get(rg_index)
        if fp is not None:
            return fp
        with self._plan_lock:
            return self._fused_plan_locked(rg_index)

    def _fused_plan_locked(self, rg_index: int) -> "fused_mod.FusedRGPlan":
        fp = self._fused_plans.get(rg_index)
        if fp is None:
            fp = fused_mod.build_fused_rg_plan(self, rg_index)
            self._fused_plans[rg_index] = fp
        return fp

    def _plan_decompress_stage(self, plan: RowGroupPlan, rg) -> None:
        """Classify grouped columns for the decompress stage and group
        device-cascade pages by their footer-stamped (vw, cw) class, so
        execute never re-reads page headers to discover the grouping."""
        cas: "OrderedDict[tuple[int, int] | None, CascadeGroup]" = \
            OrderedDict()
        for name in plan.grouped_columns:
            chunk = rg.column(name)
            codec = Codec(chunk.codec)
            if codec == Codec.GZIP or (codec == Codec.CASCADE
                                       and self.backend != "pallas"):
                plan.memo_columns.append(name)
                continue
            plan.raw_columns.append(name)
            if codec == Codec.CASCADE:      # pallas: device decompress
                for pi, pm in enumerate(chunk.pages):
                    key = None
                    if "cascade_vw" in pm.extra:
                        key = (int(pm.extra["cascade_vw"]),
                               int(pm.extra["cascade_cw"]))
                    g = cas.get(key)
                    if g is None:
                        g = cas[key] = CascadeGroup(key=key, slots=[])
                    g.slots.append(PageSlot(name, pi, pm.n_values))
        plan.cascade_groups = list(cas.values())

    def _split_oversize_dict_group(self, group: DecodeGroup, rg
                                   ) -> list[DecodeGroup]:
        """Bound the per-page dictionary duplication of multi-column dict
        groups (see _DICT_ARENA_CAP_BYTES): oversize groups split per
        column, which the executor decodes with the shared-dict kernel."""
        if (self.backend != "pallas"
                or group.encoding != Encoding.RLE_DICTIONARY):
            return [group]
        cols = {s.column for s in group.slots}
        if len(cols) == 1:
            return [group]
        d_max = max(rg.column(c).dict_page.n_values for c in cols)
        if len(group.slots) * d_max * 4 <= _DICT_ARENA_CAP_BYTES:
            return [group]
        by_col: "OrderedDict[str, list[PageSlot]]" = OrderedDict()
        for s in group.slots:
            by_col.setdefault(s.column, []).append(s)
        return [DecodeGroup(key=group.key + (name,), encoding=group.encoding,
                            codec=group.codec, slots=slots)
                for name, slots in by_col.items()]

    # -- execution ---------------------------------------------------------
    #
    # Execution is *staged* so the ScanService (core/scheduler.py) can
    # dispatch every DecodePlan group of a row group as an independently
    # schedulable work item (per-chunk dispatch): ``begin_execute`` builds
    # the shared context, ``decompress_tasks`` returns the phase-1 items
    # (host inflate per memoizable column, raw views, one device launch per
    # cascade (vw, cw) class), ``decode_tasks`` — valid once phase 1 has
    # drained — returns the phase-2 items (one per DecodeGroup plus one per
    # fallback column), and ``finish_execute`` is the join barrier that
    # assembles columns, flushes the device, and returns pooled arenas.
    # ``execute`` runs the same stages serially, so the scheduled path is
    # bit-identical to the inline path by construction
    # (tests/test_scheduler.py pins it against the reference decoder too).
    #
    # Concurrency contract for tasks of ONE context: distinct tasks write
    # distinct ``payloads`` / ``per_col_parts`` keys (single dict stores,
    # atomic under the GIL); appends to ``leases`` and ``out`` go through
    # the same atomic operations; the planner-level caches (arena pool,
    # dictionary cache, decompress memo) are themselves thread-safe.

    def execute(self, rg_index: int, raws: dict[str, bytes]
                ) -> dict[str, ops.DecodeResult]:
        ctx = self.begin_execute(rg_index, raws)
        for task in self.decompress_tasks(ctx):
            task()
        for task in self.decode_tasks(ctx):
            task()
        for task in self.fused_tasks(ctx):
            task()
        return self.finish_execute(ctx)

    def begin_execute(self, rg_index: int, raws: dict[str, bytes]
                      ) -> "ExecContext":
        plan = self.plan_rg(rg_index)
        ctx = ExecContext(
            rg_index=rg_index, plan=plan,
            rg=self.meta.row_groups[rg_index], raws=raws,
            use_kernels=(self.backend == "pallas"),
            per_col_parts={name: {} for name in plan.grouped_columns})
        if self.fused_spec is not None:
            ctx.fused_plan = self.fused_plan_rg(rg_index)
        return ctx

    def decompress_tasks(self, ctx: "ExecContext") -> list[Callable[[], None]]:
        """Phase-1 work items: decompressed page payloads for every grouped
        column.  Host-decompressed chunks (gzip on either backend, cascade
        on the host backend) go through the chunk-level decompress memo —
        a scan that revisits the chunk reuses the inflated payloads instead
        of re-running one zlib call per page.  Device-cascade pages launch
        one kernel per plan-time (vw, cw) group."""
        tasks: list[Callable[[], None]] = []
        for name in ctx.plan.memo_columns:
            tasks.append(functools.partial(self._inflate_column_task,
                                           ctx, name))
        if ctx.plan.raw_columns:
            tasks.append(functools.partial(self._raw_views_task, ctx))
        for group in ctx.plan.cascade_groups:
            tasks.append(functools.partial(self._cascade_group_task,
                                           ctx, group))
        if (ctx.fused_plan is not None and ctx.fused_plan.ok
                and self.fused_spec.mode == "fused"):
            # fused-mode aggregate operands: stage their still-encoded
            # page payloads now, CRC-verified — the ChecksumError-before-
            # kernel gate for the fused path (tools/chaos_check.py)
            for op in ctx.fused_plan.operands:
                tasks.append(functools.partial(self._fused_payload_task,
                                               ctx, op.name))
        return tasks

    def _fused_payload_task(self, ctx: "ExecContext", name: str) -> None:
        """Verified page payloads for one late fused operand (its column
        is outside the stage-A plan, so neither the memo nor the raw-view
        task covers it).  Operand eligibility restricts the codec to
        NONE/GZIP (core/fused.py)."""
        chunk = ctx.rg.column(name)
        codec = Codec(chunk.codec)
        if codec == Codec.GZIP:
            self._inflate_column_task(ctx, name)
            return
        raw = ctx.raws[name]
        off0, _ = chunk.byte_range
        if chunk.dict_page is not None:
            dp = chunk.dict_page
            data = raw[dp.offset - off0:dp.offset - off0 + dp.stored_size]
            verify_page(data, dp, where=f"{name} dict@{dp.offset}")
            ctx.payloads[(name, "dict")] = decompress(
                data, codec, dp.uncompressed_size)
        for pi, pm in enumerate(chunk.pages):
            lo = pm.offset - off0
            verify_page(raw[lo:lo + pm.stored_size], pm,
                        where=f"{name} page@{pm.offset}")
            ctx.payloads[(name, pi)] = (raw, lo, pm.stored_size)

    def _inflate_column_task(self, ctx: "ExecContext", name: str) -> None:
        chunk = ctx.rg.column(name)
        memo = chunk_decompress_memo()
        memo_key = self._memo_key(chunk, name)
        entry = memo.get(memo_key)
        if entry is None:
            entry = memo.put(memo_key,
                             self._inflate_chunk_entry(chunk, ctx.raws[name]))
        for k, v in entry.items():
            ctx.payloads[(name, k)] = v

    def _raw_views_task(self, ctx: "ExecContext") -> None:
        """Raw-view tuples for uncompressed pages (enables the single-copy
        arena fill) + host dict-page decompress for every non-memo column.
        Cheap — one item covers all such columns."""
        for name in ctx.plan.raw_columns:
            chunk = ctx.rg.column(name)
            raw = ctx.raws[name]
            off0, _ = chunk.byte_range
            codec = Codec(chunk.codec)
            if chunk.dict_page is not None:
                dp = chunk.dict_page
                data = raw[dp.offset - off0:dp.offset - off0
                           + dp.stored_size]
                verify_page(data, dp, where=f"{name} dict@{dp.offset}")
                ctx.payloads[(name, "dict")] = decompress(
                    data, codec, dp.uncompressed_size)
            if codec == Codec.NONE:
                for pi, pm in enumerate(chunk.pages):
                    lo = pm.offset - off0
                    verify_page(raw[lo:lo + pm.stored_size], pm,
                                where=f"{name} page@{pm.offset}")
                    ctx.payloads[(name, pi)] = (raw, lo, pm.stored_size)

    def _cascade_group_task(self, ctx: "ExecContext",
                            group: CascadeGroup) -> None:
        """One device decompress launch for one (vw, cw) class (or the
        execute-time-grouped leftovers of width-unstamped files)."""
        pages = []
        for s in group.slots:
            chunk = ctx.rg.column(s.column)
            pm = chunk.pages[s.page_index]
            off0, _ = chunk.byte_range
            lo = pm.offset - off0
            data = ctx.raws[s.column][lo:lo + pm.stored_size]
            verify_page(data, pm,
                        where=f"{s.column} page@{pm.offset}")
            pages.append((pm, data))
        if group.key is not None:
            datas = ops.cascade_decompress_pages_grouped(pages)
            for s, data in zip(group.slots, datas):
                ctx.payloads[(s.column, s.page_index)] = data
        else:
            dec = ops.cascade_decompress_device(pages)
            for s, (_, data) in zip(group.slots, dec):
                ctx.payloads[(s.column, s.page_index)] = data

    def decode_tasks(self, ctx: "ExecContext") -> list[Callable[[], None]]:
        """Phase-2 work items (valid once every decompress task drained):
        one per DecodeGroup plus one per fallback/demoted column.  The
        wide-delta demotion scan runs here, serially, so every group task
        sees the final demoted set (mirrors the chunk-granular reference
        fallback)."""
        plan = ctx.plan
        if ctx.use_kernels:
            for group in plan.groups:
                if group.encoding != Encoding.DELTA_BINARY_PACKED:
                    continue
                slots = [s for s in group.slots
                         if s.column not in ctx.demoted]
                _, newly = self._demote_wide_delta(ctx.rg, slots,
                                                   ctx.payloads)
                ctx.demoted.extend(newly)
        tasks: list[Callable[[], None]] = []
        for group in plan.groups:
            tasks.append(functools.partial(self._group_task, ctx, group))
        for name in list(plan.fallback_columns) + list(ctx.demoted):
            tasks.append(functools.partial(self._fallback_task, ctx, name))
        return tasks

    def _group_task(self, ctx: "ExecContext", group: DecodeGroup) -> None:
        slots = [s for s in group.slots if s.column not in ctx.demoted]
        if not slots:
            return
        exec_group = (self._execute_group_pallas if ctx.use_kernels
                      else self._execute_group_host)
        exec_group(group, slots, ctx.rg, ctx.payloads, ctx.per_col_parts,
                   ctx.leases)

    def _fallback_task(self, ctx: "ExecContext", name: str) -> None:
        chunk = ctx.rg.column(name)
        field = self.meta.schema.field(name)
        ctx.out[name] = ops.decode_chunk(
            chunk, field, ctx.raws[name], use_kernels=ctx.use_kernels,
            payloads=self._fallback_payloads(chunk, name, ctx.raws))

    def fused_tasks(self, ctx: "ExecContext") -> list[Callable[[], None]]:
        """Phase-3 work item (valid once every decode task drained): the
        fused stage-B of a predicated scan — stage-A mask, zone/selection
        page skips, ONE fused kernel launch (or the reference twin).
        Empty for planners without a FusedSpec, so the scheduler's phase
        accounting is untouched on the unfused path."""
        if ctx.fused_plan is None:
            return []
        return [functools.partial(self._fused_task, ctx)]

    def _fused_task(self, ctx: "ExecContext") -> None:
        ctx.fused_result = fused_mod.run_fused(self, ctx)

    def finish_execute(self, ctx: "ExecContext"
                       ) -> dict[str, ops.DecodeResult]:
        """Join barrier: scatter group outputs back into per-column results,
        flush the device, return pooled arenas."""
        for name in ctx.plan.grouped_columns:
            if name in ctx.demoted or name in ctx.out:
                continue      # phase 3 may have assembled stage-A columns
            chunk = ctx.rg.column(name)
            field = self.meta.schema.field(name)
            ctx.out[name] = self._assemble_column(
                chunk, field, ctx.per_col_parts[name], ctx.payloads)
        if ctx.leases:
            # flush before returning arenas: a pooled buffer may be aliased
            # by in-flight device computation until results materialize
            for res in ctx.out.values():
                if res.on_device:
                    res.array.block_until_ready()
            for buf in ctx.leases:
                self._arena_pool.give(buf)
        if ctx.fused_result is not None:
            # late columns were never materialized — deliver the stage-A
            # columns that exist plus the fused result under FUSED_KEY
            out = {name: ctx.out[name] for name in self.columns
                   if name in ctx.out}
            out[fused_mod.FUSED_KEY] = ctx.fused_result
            return out
        return {name: ctx.out[name] for name in self.columns}

    # -- fault recovery ------------------------------------------------------

    def evict_rg(self, rg_index: int) -> int:
        """Drop every shared-cache entry this planner could have populated
        for ``rg_index`` (decompress memo + dictionary cache); returns the
        eviction count.  The ScanService calls this before retrying a row
        group whose decode failed — and for every delivered row group of a
        permanently failed scan — so bytes derived from a bad read can
        never be served to a later scan (checksum verification makes
        poisoning impossible when ON; eviction keeps the invariant even
        with verification off or for non-checksum failures)."""
        rg = self.meta.row_groups[rg_index]
        n = 0
        memo = chunk_decompress_memo()
        for name in self.columns:
            chunk = rg.column(name)
            key = self._memo_key(chunk, name)
            if key is not None and memo.pop(key) is not None:
                n += 1
            if chunk.dict_page is not None:
                dp_off = chunk.dict_page.offset
                n += dict_decode.dict_cache_evict(
                    lambda k, o=dp_off, nm=name: (k[0] == self.cache_token
                                                  and k[1] == nm
                                                  and k[2] == o))
        return n

    def evict_file(self) -> int:
        """Drop every shared-cache entry keyed by this planner's file
        token (all row groups, all columns)."""
        token = self.cache_token
        memo = chunk_decompress_memo()
        n = memo.pop_matching(lambda k: k and k[0] == token)
        n += dict_decode.dict_cache_evict(lambda k: k and k[0] == token)
        return n

    # -- stages ------------------------------------------------------------

    def _memo_key(self, chunk, name: str) -> tuple | None:
        """Memo key for host-decompressed chunks (gzip on either backend,
        cascade on the host backend); None → not memoizable."""
        codec = Codec(chunk.codec)
        if codec == Codec.GZIP or (codec == Codec.CASCADE
                                   and self.backend != "pallas"):
            return (self.cache_token, name, chunk.byte_range)
        return None

    @staticmethod
    def _inflate_chunk_entry(chunk, raw) -> dict[object, object]:
        """Decompress every page of one chunk into the memo entry format:
        {page_index: payload, "dict": dictionary payload} — the shape both
        the grouped decompress stage and ops.decode_chunk consume.

        Every page's stored bytes are CRC-verified *here*, before the
        entry is built — the caller inserts the result into the shared
        decompress memo, so this is the cache-poisoning gate: corrupt
        bytes raise ChecksumError and nothing reaches the memo."""
        codec = Codec(chunk.codec)
        off0, _ = chunk.byte_range
        entry: dict[object, object] = {}
        if chunk.dict_page is not None:
            dp = chunk.dict_page
            data = raw[dp.offset - off0:dp.offset - off0 + dp.stored_size]
            verify_page(data, dp, where=f"{chunk.name} dict@{dp.offset}")
            entry["dict"] = decompress(data, codec, dp.uncompressed_size)
        for pi, pm in enumerate(chunk.pages):
            lo = pm.offset - off0
            data = raw[lo:lo + pm.stored_size]
            verify_page(data, pm, where=f"{chunk.name} page@{pm.offset}")
            entry[pi] = decompress(data, codec, pm.uncompressed_size)
        return entry

    def _fallback_payloads(self, chunk, name: str, raws
                           ) -> dict | None:
        """Pre-inflated page payloads for a fallback column, served from
        (and feeding) the chunk decompress memo — strings/float64 gzip
        chunks are exactly the host-decompress bottleneck the memo is
        for.  None → decode_chunk decompresses itself (NONE codec,
        device-cascade)."""
        memo_key = self._memo_key(chunk, name)
        if memo_key is None:
            return None
        memo = chunk_decompress_memo()
        hit = memo.get(memo_key)
        if hit is not None:
            return hit
        return memo.put(memo_key,
                        self._inflate_chunk_entry(chunk, raws[name]))

    def _demote_wide_delta(self, rg, slots: list[PageSlot], payloads
                           ) -> tuple[list[PageSlot], list[str]]:
        """Chunks whose min_delta exceeds int32 take the per-chunk path
        (mirrors the reference fallback, which is chunk-granular)."""
        bad: list[str] = []
        for s in slots:
            if s.column in bad:
                continue
            pm = rg.column(s.column).pages[s.page_index]
            man = self._manifest(rg, s, payloads)
            if abs(int(man["min_delta"].min(initial=0))) > ops._INT32_SAFE:
                bad.append(s.column)
        return [s for s in slots if s.column not in bad], bad

    def _payload_bytes(self, payloads, slot: PageSlot) -> bytes:
        p = payloads[(slot.column, slot.page_index)]
        if isinstance(p, tuple):
            raw, lo, size = p
            return raw[lo:lo + size]
        return p

    def _manifest(self, rg, slot: PageSlot, payloads) -> dict:
        key = (slot.column, slot.page_index, "man")
        man = payloads.get(key)
        if man is None:
            pm = rg.column(slot.column).pages[slot.page_index]
            man = build_delta_manifest(self._payload_bytes(payloads, slot),
                                       pm.n_values, pm.extra)
            payloads[key] = man
        return man

    # -- arena packing -----------------------------------------------------

    def _fill_arena(self, arena: np.ndarray, slots: Sequence[PageSlot],
                    payloads) -> None:
        """Pack page payload words into the preallocated uint32 arena.

        Uncompressed pages still sitting in the fetched row-group buffer are
        copied per *contiguous same-width run* (one reshape copy per run —
        for the common uniform-page chunk this is one copy per column, not
        one per page); materialized payloads copy row-by-row.
        """
        w = arena.shape[1]
        i, n = 0, len(slots)
        while i < n:
            p = payloads[(slots[i].column, slots[i].page_index)]
            if isinstance(p, tuple) and p[2] == w * 4:
                raw, lo, _ = p
                j = i + 1
                while j < n:
                    q = payloads[(slots[j].column, slots[j].page_index)]
                    if not (isinstance(q, tuple) and q[0] is raw
                            and q[2] == w * 4
                            and q[1] == lo + (j - i) * w * 4):
                        break
                    j += 1
                k = j - i
                arena[i:i + k] = np.frombuffer(
                    raw, dtype=np.uint32, count=k * w,
                    offset=lo).reshape(k, w)
                i = j
            else:
                data = self._payload_bytes(payloads, slots[i])
                words = np.frombuffer(data, dtype=np.uint32,
                                      count=len(data) // 4)
                arena[i, :words.shape[0]] = words
                i += 1

    # -- pallas group execution -------------------------------------------

    def _execute_group_pallas(self, group: DecodeGroup,
                              slots: list[PageSlot], rg, payloads,
                              per_col_parts, leases) -> None:
        enc = group.encoding
        if enc == Encoding.RLE_DICTIONARY:
            batch = self._dict_group_pallas(group, slots, rg, payloads,
                                            leases)
        elif enc == Encoding.DELTA_BINARY_PACKED:
            batch = self._delta_group_pallas(group, slots, rg, payloads)
        elif enc == Encoding.RLE:
            batch = self._rle_group_pallas(group, slots, rg, payloads)
        else:
            batch = self._bss_group_pallas(group, slots, rg, payloads,
                                           leases)
        self._scatter_batch(batch, slots, per_col_parts)

    @staticmethod
    def _scatter_batch(batch, slots: list[PageSlot], per_col_parts) -> None:
        """Slice group output rows back to columns.  Consecutive pages of
        one column compact in a single segment (the uniform-page fast path
        of ops._compact), keyed by their page range for ordered reassembly."""
        i, n = 0, len(slots)
        while i < n:
            col, p0 = slots[i].column, slots[i].page_index
            j = i + 1
            while (j < n and slots[j].column == col
                   and slots[j].page_index == p0 + (j - i)):
                j += 1
            counts = [s.n_values for s in slots[i:j]]
            per_col_parts[col][(p0, slots[j - 1].page_index)] = \
                ops._compact(batch[i:j], counts)
            i = j

    def _dict_group_pallas(self, group, slots, rg, payloads, leases):
        width = group.key[2]
        w_arena = max(
            -(-rg.column(s.column).pages[s.page_index].uncompressed_size
              // 4) for s in slots)
        arena, buf = self._arena_pool.take(
            (len(slots), max(w_arena, 1)), np.uint32)
        leases.append(buf)
        self._fill_arena(arena, slots, payloads)
        dicts: dict[str, dict_decode.CachedDictionary] = {}
        for s in slots:
            if s.column not in dicts:
                dicts[s.column] = self._device_dictionary(rg, s.column,
                                                          payloads)
        if len(dicts) == 1:   # single-column group: no dict duplication
            return ops.decode_dict_group_shared(
                arena, next(iter(dicts.values())).device, width)
        d_max = max(d.host.shape[0] for d in dicts.values())
        dtype = next(iter(dicts.values())).host.dtype
        dict_arena, dbuf = self._arena_pool.take((len(slots), d_max), dtype)
        leases.append(dbuf)
        for row, s in enumerate(slots):
            d = dicts[s.column].host
            dict_arena[row, :d.shape[0]] = d
        return ops.decode_dict_group(arena, dict_arena, width)

    def _device_dictionary(self, rg, name: str, payloads
                           ) -> dict_decode.CachedDictionary:
        """Decoded dictionary for one column chunk, served from the
        cross-row-group cache (kernels/dict_decode.py) keyed by
        (file token, column, dict-page offset) — repeated scans skip both
        the host PLAIN-decode and the host→device staging."""
        chunk = rg.column(name)
        dp = chunk.dict_page
        # "device" variant: stored narrowed (int64→int32, bool→uint8);
        # distinct from the "host" variant of _host_dictionary
        key = (self.cache_token, name, dp.offset, "device")
        entry = dict_decode.dict_cache_get(key)
        if entry is not None:
            return entry
        field = self.meta.schema.field(name)
        dictionary = decode_plain_page(payloads[(name, "dict")], dp.n_values,
                                       field, dp.extra)
        if field.physical == PhysicalType.INT64:
            dictionary = dictionary.astype(np.int32)
        elif field.physical == PhysicalType.BOOLEAN:
            dictionary = dictionary.astype(np.uint8)
        return dict_decode.dict_cache_put(
            key, np.ascontiguousarray(dictionary))

    def _delta_group_pallas(self, group, slots, rg, payloads):
        n_blocks = group.key[2]
        mans = [self._manifest(rg, s, payloads) for s in slots]
        pls = [self._payload_bytes(payloads, s) for s in slots]
        arrays = ops.delta_group_arrays(mans, pls, n_blocks)
        return ops.decode_delta_group(*arrays, n_blocks=n_blocks)

    def _rle_group_pallas(self, group, slots, rg, payloads):
        n_out, vdt_name = group.key[2], group.key[3]
        vdt = np.dtype(vdt_name)
        runs = []
        for s in slots:
            pm = rg.column(s.column).pages[s.page_index]
            p = self._payload_bytes(payloads, s)
            r = pm.extra["n_runs"]
            runs.append((
                np.frombuffer(p, dtype=vdt, count=r).astype(np.int32),
                np.frombuffer(p, dtype=np.int32, count=r,
                              offset=r * vdt.itemsize)))
        vals, counts = ops.rle_group_arrays(runs)
        return ops.decode_rle_group(vals, counts, n_out=n_out)

    def _bss_group_pallas(self, group, slots, rg, payloads, leases):
        stride = group.key[2]
        arena, buf = self._arena_pool.take((len(slots), 4 * stride),
                                           np.uint32)
        leases.append(buf)
        for row, s in enumerate(slots):
            pm = rg.column(s.column).pages[s.page_index]
            n = pm.n_values
            s_words = (n + (-n) % 4) // 4
            words = np.frombuffer(self._payload_bytes(payloads, s),
                                  dtype=np.uint32, count=4 * s_words)
            if s_words == stride:
                arena[row, :4 * stride] = words
            else:
                for plane in range(4):
                    arena[row, plane * stride:plane * stride + s_words] = \
                        words[plane * s_words:(plane + 1) * s_words]
        return ops.decode_bss_group(arena, stride)

    # -- host group execution ---------------------------------------------

    def _execute_group_host(self, group: DecodeGroup, slots: list[PageSlot],
                            rg, payloads, per_col_parts, leases) -> None:
        del leases  # host groups build exact-size numpy slabs, no arenas
        enc = group.encoding
        if enc == Encoding.RLE_DICTIONARY:
            self._dict_group_host(group, slots, rg, payloads, per_col_parts)
        elif enc == Encoding.DELTA_BINARY_PACKED:
            self._delta_group_host(slots, rg, payloads, per_col_parts)
        else:
            self._rle_group_host(group, slots, rg, payloads, per_col_parts)

    def _dict_group_host(self, group, slots, rg, payloads, per_col_parts):
        """One bitpack.unpack across every page of the group (all columns),
        then one dictionary gather per column — the per-page unpack overhead
        is what dominates host decode of many-page files."""
        width = group.key[2]
        words, g_offs, g_total = [], [], 0
        for s in slots:
            p = self._payload_bytes(payloads, s)
            w = np.frombuffer(p, dtype=np.uint32, count=len(p) // 4)
            words.append(w)
            g_offs.append(g_total)
            g_total += w.shape[0] // width
        slab = words[0] if len(words) == 1 else np.concatenate(words)
        codes = bitpack.unpack(slab, width, g_total * 32,
                               out_dtype=np.int64)
        for (s, goff) in zip(slots, g_offs):
            per_col_parts[s.column][(s.page_index, s.page_index)] = \
                codes[goff * 32:goff * 32 + s.n_values]

    def _delta_group_host(self, slots, rg, payloads, per_col_parts):
        """Manifest pass per page, then one gather+unpack per distinct
        miniblock width across the whole group; per-page cumsum assembles
        values (bit-identical to encodings.decode_delta_page)."""
        from repro.core.encodings import BLOCK, MB_GROUPS, MB_VALUES
        mans = [self._manifest(rg, s, payloads) for s in slots]
        base, total = [], 0
        for m in mans:
            base.append(total)
            total += m["words"].shape[0]
        slab = np.concatenate([m["words"] for m in mans]) if mans else \
            np.zeros(0, np.uint32)
        page_of, mb_widths, mb_offs = [], [], []
        for i, m in enumerate(mans):
            n_mb = m["n_blocks"] * 4
            page_of.append(np.full(n_mb, i, dtype=np.int64))
            mb_widths.append(m["mb_width"][:n_mb])
            mb_offs.append(m["mb_off"][:n_mb].astype(np.int64) + base[i])
        page_of = np.concatenate(page_of) if page_of else np.zeros(0, np.int64)
        mb_widths = np.concatenate(mb_widths) if mb_widths else \
            np.zeros(0, np.int64)
        mb_offs = np.concatenate(mb_offs) if mb_offs else np.zeros(0, np.int64)
        rel = np.zeros((max(page_of.shape[0], 1), MB_VALUES), dtype=np.uint64)
        for w in np.unique(mb_widths) if mb_widths.shape[0] else []:
            w = int(w)
            sel = np.flatnonzero(mb_widths == w)
            idx = mb_offs[sel][:, None] + np.arange(MB_GROUPS * w)[None, :]
            vals = bitpack.unpack(slab[idx].reshape(-1), w,
                                  sel.shape[0] * MB_VALUES)
            rel[sel] = vals.reshape(sel.shape[0], MB_VALUES)
        mb_of_page = np.concatenate([[0], np.cumsum(
            [m["n_blocks"] * 4 for m in mans])]).astype(np.int64)
        for i, (s, m) in enumerate(zip(slots, mans)):
            field = self.meta.schema.field(s.column)
            n = s.n_values
            n_blocks = m["n_blocks"]
            deltas = rel[mb_of_page[i]:mb_of_page[i + 1]].reshape(-1)[
                :n_blocks * BLOCK].astype(np.int64)
            deltas += np.repeat(m["min_delta"][:n_blocks], BLOCK)
            out = np.empty(n, dtype=np.int64)
            if n:
                out[0] = m["first_value"]
                if n > 1:
                    np.cumsum(deltas[:n - 1], out=out[1:])
                    out[1:] += m["first_value"]
            per_col_parts[s.column][(s.page_index, s.page_index)] = \
                out.astype(field.numpy_dtype)

    def _rle_group_host(self, group, slots, rg, payloads, per_col_parts):
        vdt = np.dtype(group.key[2])
        for s in slots:
            pm = rg.column(s.column).pages[s.page_index]
            field = self.meta.schema.field(s.column)
            p = self._payload_bytes(payloads, s)
            r = pm.extra["n_runs"]
            if r == 0:
                dt = (np.bool_ if field.physical == PhysicalType.BOOLEAN
                      else field.numpy_dtype)
                per_col_parts[s.column][(s.page_index, s.page_index)] = \
                    np.zeros(0, dtype=dt)
                continue
            vals = np.frombuffer(p, dtype=vdt, count=r)
            counts = np.frombuffer(p, dtype=np.int32, count=r,
                                   offset=r * vdt.itemsize)
            out = np.repeat(vals, counts)
            if field.physical == PhysicalType.BOOLEAN:
                out = out.astype(np.bool_)
            else:
                out = out.astype(field.numpy_dtype)
            per_col_parts[s.column][(s.page_index, s.page_index)] = out

    # -- scatter -----------------------------------------------------------

    def _assemble_column(self, chunk: ChunkMeta, field: Field,
                         parts: dict[tuple, object],
                         payloads) -> ops.DecodeResult:
        import jax.numpy as jnp
        ordered = [parts[k] for k in sorted(parts)]  # keys: page ranges
        on_device = self.backend == "pallas"
        if on_device:
            arr = ordered[0] if len(ordered) == 1 else jnp.concatenate(ordered)
            if (Encoding(chunk.encoding) == Encoding.RLE
                    and field.physical == PhysicalType.BOOLEAN):
                arr = arr.astype(jnp.uint8)
            logical = int(arr.dtype.itemsize) * chunk.n_values
        else:
            arr = ordered[0] if len(ordered) == 1 else np.concatenate(ordered)
            if Encoding(chunk.encoding) == Encoding.RLE_DICTIONARY:
                arr = self._host_dictionary(chunk, field, payloads)[arr]
            logical = int(np.dtype(field.numpy_dtype or np.int64).itemsize
                          * chunk.n_values)
        return ops.DecodeResult(
            array=arr, on_device=on_device, n_values=chunk.n_values,
            encoding=int(chunk.encoding), codec=int(chunk.codec),
            stored_bytes=chunk.stored_bytes, logical_bytes=int(logical))

    def _host_dictionary(self, chunk: ChunkMeta, field: Field, payloads):
        dp = chunk.dict_page
        key = (self.cache_token, chunk.name, dp.offset, "host")
        entry = dict_decode.dict_cache_get(key)
        if entry is None:
            raw = payloads[(chunk.name, "dict")]
            entry = dict_decode.dict_cache_put(
                key, decode_plain_page(raw, dp.n_values, field, dp.extra))
        return entry.host


# ---------------------------------------------------------------------------
# planner cache (per file footer + column selection + backend)
# ---------------------------------------------------------------------------

_PLANNER_CACHE: "OrderedDict[tuple, DecodePlanner]" = OrderedDict()
_PLANNER_CACHE_MAX = 64


def planner_for(path: str, meta: FileMeta, columns: Sequence[str],
                backend: str,
                fused_spec: "fused_mod.FusedSpec | None" = None
                ) -> DecodePlanner:
    # st_size + st_mtime_ns catch same-path rewrites whose footers would
    # otherwise collide (same rows / row groups / stored bytes) — a stale
    # plan would decode with the old file's page offsets.
    try:
        st = os.stat(path)
        stamp = (st.st_size, st.st_mtime_ns)
    except OSError:
        stamp = ()
    key = (path, tuple(columns), backend, meta.num_rows,
           len(meta.row_groups), meta.stored_bytes, stamp, fused_spec)
    planner = _PLANNER_CACHE.get(key)
    if planner is not None:
        _PLANNER_CACHE.move_to_end(key)
        return planner
    # cache_token omits the column selection: scanners over different
    # column subsets of one file share dictionary/decompress cache entries
    planner = DecodePlanner(meta, columns, backend,
                            cache_token=(path, stamp, meta.stored_bytes),
                            fused_spec=fused_spec)
    _PLANNER_CACHE[key] = planner
    while len(_PLANNER_CACHE) > _PLANNER_CACHE_MAX:
        _PLANNER_CACHE.popitem(last=False)
    return planner


def clear_planner_cache() -> None:
    _PLANNER_CACHE.clear()
