"""ScanService: shared-pool multi-scan scheduler (DESIGN.md §2.6).

The serving loop runs *many small scans* concurrently, but the PR-2
executor gave every ``run_overlapped`` call a private fetch thread and a
private decode pool — concurrent scans fought over cores, and decode
dispatched at whole-row-group granularity, so one slow column chunk
stalled its row group.  This module schedules the fetch → decompress →
decode path as one shared resource across scans (the Presto-on-GPU /
Data-Path-Fusion result):

  fetch    a shared fetch pool (``fetch_threads``, default ONE thread)
           issues each scan's coalesced per-RG reads, round-robin across
           active scans, gated by each scan's ``depth`` credits (the
           per-scan in-flight bound / OOM backpressure).  The single-
           thread default is deliberate — the paper's storage model
           treats the NVMe array as one shared channel whose bandwidth
           coalesced large reads already saturate — but high-latency
           *real* backends (network FS) want ``fetch_threads > 1`` so
           concurrent fragment scans overlap their blocking reads; the
           default path is bit-identical either way (pinned in tests);
  decode   ONE shared worker pool runs *per-chunk* work items — each
           DecodePlan group, fallback column, or decompress item of a row
           group is independently schedulable (``Scanner.decode_job``),
           with a join barrier before consume, so one slow gzip chunk no
           longer holds the whole row group, and items from different
           scans interleave fairly (round-robin dispatch);
  consume  each scan's caller thread takes its row groups strictly in
           plan order from a per-scan in-order queue (``ScanHandle``).

**Fairness & priority.**  Both the fetch pool and the decode workers
service scans in round-robin order, so N concurrent scans each make
progress instead of the first-submitted scan monopolizing the pool.
``submit(priority=k)`` groups scans into strict priority classes (lower k
served first; round-robin *within* a class): the dataset executor uses
this to bias the pool toward earliest-submitted fragments so fragment
results complete (and release their window slot) in plan order.  The
default priority 0 for every scan reduces exactly to the flat
round-robin.

**Multi-tenant weighted fair shares (DESIGN.md §11).**  ``submit(
tenant="gold")`` attributes the scan to a registered :class:`Tenant`.
Within a priority class that has any tenanted scan, dispatch switches
from flat rotation to *stride scheduling*: every fetch grant and every
row-group "open" dispatch charges the owning tenant ``1/weight`` of
virtual time, and the tenant with the smallest virtual time is served
first — a weight-4 tenant receives ~4x the decode slots of a weight-1
tenant under saturation, and every tenant's virtual time advances on
each grant, so no tenant starves.  Untenanted scans ride along as a
shared weight-1 virtual tenant; a class with *no* tenanted scans keeps
the legacy rotation bit-for-bit.  Admission control is per tenant:
``max_active`` bounds concurrently admitted scans, with
``on_limit="reject"`` raising :class:`AdmissionRejected` and
``"queue"`` blocking the submitter until a slot frees.  A tenant with
an ``slo_s`` latency target feeds the adaptive sizer: while its recent
mean scan latency misses the target, the policy asks for one extra
decode worker (capped at ``max_workers``).

**Delivered-result window.**  Cooperative in-flight sharing only helps
scans that truly overlap; ``ScanService(window_bytes=N)`` additionally
retains the most recently *delivered* shareable row groups in a
byte-capped LRU keyed by the same share identity, so a late-arriving
identical scan is served decoded columns with **no fetch and no
decode** even after the original scan finished.  Off by default
(``window_bytes=0``) — cold-start measurements and io_request pins stay
exact; the serving front end (serve/engine.py) turns it on.  Cold-scan
ladders clear it via ``clear_delivered_windows()``.

**Error isolation / cancellation.**  A failing work item (or fetch) marks
only its own scan: queued items of that scan are dropped, its handle
re-raises the first error, and every other scan is untouched.
``ScanHandle.cancel()`` does the same without an error.  The pool never
dies with a scan.

**Adaptive worker sizing.**  The pool resizes from observed per-stage
wall ratios over a sliding window of delivered row groups: decode-bound
streams (decode ≫ max(fetch, consume)) grow the pool toward
``cpu_count - 1``; fetch/consume-bound streams shrink it toward one
worker (idle decode threads only add GIL contention).  An explicit
``workers_hint`` (``run_overlapped(decode_workers=N)``) pins the floor at
N while that scan is active.

``run_overlapped`` (core/overlap.py) is a thin client of this service for
``decode_workers >= 1``; the private inline path survives behind
``decode_workers=0``.  The process-wide singleton is ``scan_service()``.
"""

from __future__ import annotations

import atexit
import os
import sys
import threading
import time
import weakref
from collections import OrderedDict, deque
from collections.abc import Callable, Sequence

from repro.core import trace
from repro.core.faults import DeadlineExceeded, is_retryable


class ScanCancelled(RuntimeError):
    """Raised by a ScanHandle whose scan was cancelled mid-stream."""


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` when a tenant with ``on_limit="reject"`` is
    already at its ``max_active`` admitted-scan bound."""


class Tenant:
    """Service-side state of one registered tenant (DESIGN.md §11).

    ``weight`` is the tenant's fair share: stride scheduling charges
    ``1/weight`` virtual time per dispatch, so relative dispatch rates
    under saturation converge to the weight ratio.  ``max_active``
    bounds concurrently admitted scans (None = unbounded) with
    ``on_limit`` picking the over-limit behavior (``"reject"`` raises
    :class:`AdmissionRejected`, ``"queue"`` blocks the submitter).
    ``slo_s`` is an optional per-scan latency target feeding the
    adaptive pool sizer."""

    __slots__ = ("name", "weight", "max_active", "on_limit", "slo_s",
                 "seq", "fetch_pass", "item_pass", "active",
                 "dispatches", "latencies")

    def __init__(self, name: str, weight: int = 1,
                 max_active: int | None = None, on_limit: str = "reject",
                 slo_s: float | None = None, seq: int = 0):
        if weight < 1:
            raise ValueError(f"tenant weight must be >= 1, got {weight}")
        if on_limit not in ("reject", "queue"):
            raise ValueError(f"on_limit must be 'reject' or 'queue', "
                             f"got {on_limit!r}")
        self.name = name
        self.weight = int(weight)
        self.max_active = max_active
        self.on_limit = on_limit
        self.slo_s = slo_s
        self.seq = seq                 # registration order (tiebreak)
        self.fetch_pass = 0.0          # stride virtual time, fetch grants
        self.item_pass = 0.0           # stride virtual time, RG dispatches
        self.active = 0                # admitted scans in service
        self.dispatches = 0            # row-group "open" dispatches won
        self.latencies: deque = deque(maxlen=16)   # recent scan walls (s)


# ---------------------------------------------------------------------------
# decode-worker CPU affinity (REPRO_DECODE_AFFINITY — carried ROADMAP lever)
# ---------------------------------------------------------------------------

_AFFINITY_ENV = "REPRO_DECODE_AFFINITY"
#: spec → outcome of the last pin attempt ("pinned" / "unsupported")
_affinity_status: dict[str, str] = {}


def _affinity_cpus(spec: str) -> list[int]:
    """CPUs named by an affinity spec: ``auto`` → every CPU this process
    may run on (workers stripe across them); else a comma list with
    ``lo-hi`` ranges (``0,2`` / ``0-3``), filtered to the allowed set."""
    avail = sorted(os.sched_getaffinity(0))
    if spec.lower() == "auto":
        return avail
    cpus: list[int] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "-" in part:
            lo, hi = part.split("-", 1)
            cpus.extend(range(int(lo), int(hi) + 1))
        else:
            cpus.append(int(part))
    allowed = set(avail)
    return [c for c in cpus if c in allowed]


def _apply_affinity(worker_idx: int) -> None:
    """Pin the calling decode worker to one CPU from the
    REPRO_DECODE_AFFINITY set (worker_idx stripes across it).  A no-op
    when the env var is unset/off, and *silently degrades* on platforms
    without sched_setaffinity or with an unparsable spec — pinning is an
    optimization, never a correctness requirement."""
    spec = os.environ.get(_AFFINITY_ENV, "").strip()
    if not spec or spec.lower() in ("0", "off", "none"):
        return
    try:
        cpus = _affinity_cpus(spec)
        if not cpus:
            raise ValueError(f"empty affinity set: {spec!r}")
        # pid 0 = the calling thread on Linux: each worker pins itself
        os.sched_setaffinity(0, {cpus[worker_idx % len(cpus)]})
        _affinity_status[spec] = "pinned"
    except (AttributeError, OSError, ValueError):
        _affinity_status[spec] = "unsupported"


def decode_affinity_mode() -> str:
    """The pinning in effect, for ScanMetrics: ``off`` when unset;
    ``<spec>:pinned`` once a worker pinned successfully;
    ``<spec>:unsupported`` when the platform refused;
    ``<spec>:configured`` when set but no pool worker has started yet."""
    spec = os.environ.get(_AFFINITY_ENV, "").strip()
    if not spec or spec.lower() in ("0", "off", "none"):
        return "off"
    return f"{spec}:{_affinity_status.get(spec, 'configured')}"


def default_max_workers() -> int:
    """Adaptive-pool ceiling: leave one core for consume/fetch.  Override
    with REPRO_SCAN_MAX_WORKERS."""
    env = os.environ.get("REPRO_SCAN_MAX_WORKERS")
    if env is not None:
        return max(1, int(env))
    return max(1, (os.cpu_count() or 2) - 1)


class OpaqueDecodeJob:
    """One-item decode job wrapping a ``decode_rg`` callable: the adapter
    for scanners without ``decode_job`` (test stubs) and for scanners
    whose ``decode_rg`` was instance-patched (tests/instrumentation),
    where the patched callable must keep owning the whole decode.  The
    single implementation of this shape — ``Scanner.decode_job`` reuses
    it (core/scan.py)."""

    def __init__(self, scanner, rg_index, raws):
        self.scanner = scanner
        self.rg_index = rg_index
        self.raws = raws
        self.cols = None

    def phase1_tasks(self):
        return []

    def phase2_tasks(self):
        return [self._decode]

    def _decode(self):
        self.cols, _ = self.scanner.decode_rg(self.rg_index, self.raws)

    def finalize(self):
        assert self.cols is not None
        return self.cols


class _RgJob:
    """One fetched row group moving through the per-chunk decode DAG:
    open → phase-1 items (decompress) → phase-2 items (groups/fallbacks)
    → finalize (join) → each subscriber scan's in-order done queue.

    **Cooperative scans**: identical concurrent scans (same file contents,
    column selection, decode backend, storage shape) *subscribe* to an
    already-in-flight job for a row group instead of fetching and decoding
    it again — the serving-loop case where N clients query the same hot
    file.  ``subscribers`` lists the (scan, seq) pairs awaiting this job's
    columns; the decoded results are delivered to all of them (read-only
    DecodeResults are safe to share)."""

    __slots__ = ("rg_index", "raws", "io_dt", "job", "pending",
                 "phase", "chunk_times", "p2_start", "key", "subscribers",
                 "failed", "enq_t")

    def __init__(self, seq_scan, seq: int, rg_index: int, raws,
                 io_dt: float, key):
        self.rg_index = rg_index
        self.raws = raws
        self.io_dt = io_dt
        self.job = None           # built by the "open" item
        self.pending = 0          # outstanding items of the current phase
        self.phase = 0            # 0=open, 1, 2, 3 (fused stage-B)
        self.chunk_times: list[float] = []
        self.p2_start = 0         # chunk_times index of the first phase-2
                                  # item (the phase barrier, for the model)
        self.enq_t = 0.0          # when the current phase's items were
                                  # queued (trace queue-wait histogram)
        self.key = key            # sharing identity, None → not shareable
        self.subscribers: list[tuple] = [(seq_scan, seq)]
        self.failed = False       # an item of this job raised; queued and
                                  # in-flight siblings must stand down

    def live_scan(self):
        """First subscriber scan still interested in this job, or None."""
        for scan, _ in self.subscribers:
            if not scan.dead:
                return scan
        return None


def _share_key(scanner) -> tuple | None:
    """Identity under which two scans may share fetch+decode work: file
    *contents* (the planner cache token carries path + size + mtime),
    column selection, decode backend, and the storage model (its kind and
    timing parameters — a sim-backend scan must not inherit a real
    backend's io_dt or vice versa).  None → never share (no planner, or an
    instance-patched fetch/decode that sharing would bypass)."""
    planner = getattr(scanner, "planner", None)
    if planner is None:
        return None
    if ("decode_rg" in getattr(scanner, "__dict__", {})
            or "fetch_rg" in getattr(scanner, "__dict__", {})):
        return None
    if getattr(scanner, "fault_plan", None) is not None:
        # fault-injection scans exist to exercise the real fetch+decode
        # path: they must neither reuse a clean scan's work (skipping
        # the injection) nor publish their own into the shared window
        return None
    storage = getattr(scanner, "storage", None)
    return (planner.cache_token,
            tuple(scanner.columns),
            scanner.decode_backend,
            getattr(storage, "kind", "real"),
            getattr(storage, "n_lanes", None),
            getattr(storage, "lane_bandwidth", None),
            getattr(storage, "latency", None),
            getattr(scanner, "coalesce_gap", None),
            getattr(scanner, "fused_spec", None))


class _ScanState:
    """Service-side state of one submitted scan."""

    def __init__(self, service: "ScanService", scanner, plan: list[int],
                 depth: int, workers_hint: int | None, label: str,
                 priority: int = 0, retries: int = 3,
                 deadline: float | None = None,
                 tenant: Tenant | None = None):
        self.scanner = scanner
        self.tenant = tenant           # owning Tenant, None = untenanted
        self.t_submit = time.monotonic()
        self.plan = plan
        self.depth = max(1, depth)
        self.workers_hint = workers_hint
        self.label = label
        self.priority = priority
        # fault-recovery state (DESIGN.md §6): a transiently failed row
        # group (decode worker died, refetchable corruption) is requeued
        # for a fresh fetch+decode while budget lasts; ``refetch`` seqs
        # keep holding their in-flight credit (released only on ack), so
        # a retry can never over-subscribe the scan's depth bound.
        self.retries_left = max(0, retries)
        self.deadline = (None if deadline is None
                         else time.monotonic() + deadline)
        self.refetch: deque = deque()
        self.share_key = _share_key(scanner)
        self.shared_rgs = 0            # RGs satisfied by cooperative jobs
        self.workers_seen = 1          # max pool width while this scan ran
        self.credits = self.depth      # fetch permits (in-flight RG bound)
        self.next_fetch = 0            # next plan position to fetch
        self.ready: deque = deque()    # work items ready for the pool
        self.done: dict[int, tuple] = {}
        self.error: BaseException | None = None
        self.cancelled = False
        self.finished = False
        # stage wall spans (first start → last end) for RunReport
        self.fetch_span = [float("inf"), 0.0]
        self.decode_span = [float("inf"), 0.0]
        self.done_cv = threading.Condition(service._lock)

    @property
    def dead(self) -> bool:
        return self.error is not None or self.cancelled or self.finished

    def past_deadline(self) -> bool:
        return (self.deadline is not None
                and time.monotonic() > self.deadline)

    def span(self, which: str) -> float:
        lo, hi = self.fetch_span if which == "fetch" else self.decode_span
        return max(0.0, hi - lo) if hi else 0.0


class ScanHandle:
    """Client side of one scan: iterate to receive
    ``(rg_index, cols, io_dt, dec_dt, chunk_times, p2_start)`` strictly in
    plan order (``chunk_times`` lists the RG's decode item walls in
    completion order — open, phase-1 items, transition, phase-2 items,
    finalize — and ``p2_start`` indexes the first phase-2 item, the
    barrier the modeled schedule must honor).  Advancing the iterator
    *acks* the previous row group — releasing its in-flight credit and
    reporting its consume time to the
    adaptive sizer — so call ``next`` only after consuming.  ``cancel()``
    stops the scan without poisoning the pool."""

    def __init__(self, service: "ScanService", scan: _ScanState):
        self._svc = service
        self._scan = scan
        self._next_seq = 0
        self._t_delivered: float | None = None
        self._last_item: tuple | None = None

    def __iter__(self) -> "ScanHandle":
        return self

    def __next__(self) -> tuple:
        svc, scan = self._svc, self._scan
        with svc._lock:
            if self._t_delivered is not None:
                svc._ack_locked(scan, self._last_item,
                                time.perf_counter() - self._t_delivered)
                self._t_delivered = None
            if self._next_seq >= len(scan.plan) and scan.error is None:
                svc._finish_scan_locked(scan)
                raise StopIteration
            while (self._next_seq not in scan.done and not scan.dead):
                if scan.past_deadline():
                    svc._deadline_fail_locked(scan)
                    break
                scan.done_cv.wait(timeout=0.1)
            if scan.error is not None or scan.cancelled:
                err, cancelled = scan.error, scan.cancelled
                svc._finish_scan_locked(scan)
                if err is not None:
                    raise err
                if cancelled:
                    raise ScanCancelled(f"scan {scan.label} cancelled")
            item = scan.done.pop(self._next_seq)
        self._next_seq += 1
        self._t_delivered = time.perf_counter()
        self._last_item = item
        return item

    def cancel(self) -> None:
        """Idempotent: safe to call any number of times, from ``close``,
        ``__del__``, or interpreter-shutdown (atexit) paths — a finished
        scan short-circuits without touching the service."""
        scan = self._scan
        if scan.finished:
            return
        try:
            with self._svc._lock:
                if not scan.finished:
                    scan.cancelled = True
                    self._svc._finish_scan_locked(scan)
        except Exception:
            # during interpreter finalization the service's threads and
            # condition variables may already be torn down; the scan dies
            # with the process, so there is nothing left to release
            if not sys.is_finalizing():
                raise

    # A handle abandoned before exhaustion would otherwise leak its scan
    # registration (round-robin slot, pinned decoded RGs, fetch credits)
    # in the process-wide service for the life of the process — close on
    # scope exit and as a GC safety net.
    close = cancel

    def __enter__(self) -> "ScanHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            if not self._scan.finished:
                self.close()
        except Exception:
            pass

    @property
    def workers(self) -> int:
        """Pool width to report/model for this scan: the explicit hint when
        given, else the widest pool observed *while the scan ran* (the
        pool may resize after the scan finishes)."""
        if self._scan.workers_hint:
            return self._scan.workers_hint
        return max(1, self._scan.workers_seen)

    def stage_walls(self) -> dict[str, float]:
        return {"fetch": self._scan.span("fetch"),
                "decode": self._scan.span("decode")}

    @property
    def shared_rgs(self) -> int:
        """Row groups this scan received from another scan's in-flight
        job (cooperative scans) instead of fetching + decoding itself."""
        return self._scan.shared_rgs


class ScanService:
    """One shared fetch thread + one shared decode pool for all scans."""

    def __init__(self, workers: int | None = None, adaptive: bool = True,
                 max_workers: int | None = None, resize_every: int = 8,
                 fetch_threads: int = 1, device=None,
                 window_bytes: int = 0):
        self._lock = threading.RLock()
        self._work_cv = threading.Condition(self._lock)
        self._fetch_cv = threading.Condition(self._lock)
        self._admit_cv = threading.Condition(self._lock)
        self._scans: list[_ScanState] = []
        # multi-tenant front end (DESIGN.md §11): registered tenants,
        # the virtual weight-1 tenant untenanted scans charge when they
        # share a priority class with tenanted ones, and the delivered-
        # result window — a byte-capped LRU of recently delivered
        # shareable row groups (off at 0, cold paths stay exact)
        self._tenants: dict[str, Tenant] = {}
        self._default_tenant = Tenant("-", weight=1, seq=-1)
        self.window_bytes = max(0, int(window_bytes))
        self._window: OrderedDict[tuple, tuple] = OrderedDict()
        self._window_nbytes = 0
        self.window_hits = 0
        self._rr = 0               # decode round-robin cursor
        self._fetch_rr = 0         # fetch round-robin cursor
        self._inflight: dict[tuple, _RgJob] = {}   # cooperative-scan jobs
        self.shared_rgs = 0        # total RGs served by subscription
        self.adaptive = adaptive
        self.max_workers = max_workers or default_max_workers()
        # the paper's one-channel NVMe model wants exactly one fetch
        # thread (the default); >1 overlaps blocking reads of concurrent
        # scans on high-latency real backends (network FS / many files)
        self.fetch_threads = max(1, fetch_threads)
        # multi-device sharding (dataset/executor.py): a per-device
        # service runs its decode workers under jax.default_device(device)
        # so launches land device-resident; None keeps jax's default
        self.device = device
        # _policy is what the adaptive sizer asks for; the effective target
        # additionally honors active scans' explicit workers hints
        self._policy = max(1, workers) if workers else 1
        self._target = self._policy
        self._n_workers = 0
        self._shrink = 0           # workers asked to retire
        self._shutdown = False
        self._fetch_pool: list[threading.Thread] = []
        self._threads: list[threading.Thread] = []
        # adaptive window accumulators (delivered-RG stage times)
        self._win = {"io": 0.0, "dec": 0.0, "cons": 0.0, "rgs": 0}
        self.resize_every = max(1, resize_every)
        self.resize_events: list[int] = []   # pool sizes after each resize
        _ALL_SERVICES.add(self)

    # -- public API ---------------------------------------------------------

    def register_tenant(self, name: str, weight: int = 1,
                        max_active: int | None = None,
                        on_limit: str = "reject",
                        slo_s: float | None = None) -> Tenant:
        """Register (or re-configure) a tenant.  ``submit(tenant=name)``
        with an unregistered name auto-registers it at weight 1,
        unbounded — explicit registration is how a tenant gets a weight,
        an admission bound, or an SLO."""
        with self._lock:
            ten = self._tenants.get(name)
            if ten is None:
                ten = Tenant(name, weight=weight, max_active=max_active,
                             on_limit=on_limit, slo_s=slo_s,
                             seq=len(self._tenants))
                self._tenants[name] = ten
            else:
                Tenant(name, weight=weight, on_limit=on_limit)  # validate
                ten.weight = int(weight)
                ten.max_active = max_active
                ten.on_limit = on_limit
                ten.slo_s = slo_s
            return ten

    def tenant(self, name: str) -> Tenant | None:
        with self._lock:
            return self._tenants.get(name)

    def _tenant_locked(self, name: str) -> Tenant:
        ten = self._tenants.get(name)
        if ten is None:
            ten = Tenant(name, seq=len(self._tenants))
            self._tenants[name] = ten
        return ten

    def clear_delivered_window(self) -> None:
        """Drop every retained delivered row group (cold-scan ladders:
        a cleared window forces real refetch + redecode)."""
        with self._lock:
            self._window.clear()
            self._window_nbytes = 0

    @property
    def window_entries(self) -> int:
        with self._lock:
            return len(self._window)

    def submit(self, scanner, row_groups: Sequence[int] | None = None,
               predicate_stats=None, depth: int = 2,
               workers_hint: int | None = None,
               label: str = "scan", priority: int = 0,
               retries: int = 3,
               deadline: float | None = None,
               tenant: str | None = None) -> ScanHandle:
        """Register one scan; returns its in-order consume handle.
        ``priority`` selects the scan's strict service class (lower is
        served first; round-robin within a class).  ``retries`` is the
        scan's transient-failure budget (requeued row groups across the
        whole scan); ``deadline`` is a whole-scan wall budget in seconds —
        once exceeded the scan fails with DeadlineExceeded (never
        retried).  ``tenant`` attributes the scan to a registered tenant
        for weighted fair scheduling and admission control (an unknown
        name auto-registers at weight 1, unbounded); at the tenant's
        ``max_active`` bound this either raises
        :class:`AdmissionRejected` or blocks until a slot frees,
        per its ``on_limit``."""
        plan = list(scanner.plan(predicate_stats, row_groups))
        with self._lock:
            if self._shutdown:
                raise RuntimeError("ScanService is shut down")
            ten = self._admit_locked(tenant)
            scan = _ScanState(self, scanner, plan, depth, workers_hint,
                              label, priority=priority, retries=retries,
                              deadline=deadline, tenant=ten)
            self._scans.append(scan)
            self._ensure_threads_locked()
            self._retarget_locked()
            scan.workers_seen = max(1, self.pool_size)
            self._fetch_cv.notify_all()
        return ScanHandle(self, scan)

    def _admit_locked(self, tenant: str | None) -> Tenant | None:
        """Admission control: charge one active-scan slot to the tenant,
        rejecting or queueing at its ``max_active`` bound.  An idle
        tenant re-joins the stride clock at the minimum active virtual
        time, so banked idleness can never become a dispatch burst."""
        if tenant is None:
            return None
        ten = self._tenant_locked(tenant)
        reg = trace.registry()
        if ten.max_active is not None and ten.active >= ten.max_active:
            if ten.on_limit == "reject":
                reg.counter_inc("scheduler.admission_rejects")
                raise AdmissionRejected(
                    f"tenant {ten.name}: {ten.active} active scans at "
                    f"max_active={ten.max_active}")
            reg.counter_inc("scheduler.admission_queued")
            while ten.active >= ten.max_active and not self._shutdown:
                self._admit_cv.wait(timeout=0.1)
            if self._shutdown:
                raise RuntimeError("ScanService is shut down")
        if ten.active == 0:
            actives = [t for t in self._tenants.values() if t.active > 0]
            if actives:
                ten.fetch_pass = max(ten.fetch_pass,
                                     min(t.fetch_pass for t in actives))
                ten.item_pass = max(ten.item_pass,
                                    min(t.item_pass for t in actives))
        ten.active += 1
        reg.gauge_set(f"scheduler.tenant_depth.{ten.name}", ten.active)
        return ten

    @property
    def pool_size(self) -> int:
        return self._n_workers - self._shrink

    @property
    def active_scans(self) -> int:
        with self._lock:
            return len(self._scans)

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            # cancel every active scan: workers/fetch are about to exit, so
            # an un-cancelled consumer would wait on done_cv forever
            for scan in list(self._scans):
                scan.cancelled = True
                scan.done_cv.notify_all()
            self._work_cv.notify_all()
            self._fetch_cv.notify_all()
            self._admit_cv.notify_all()
        for t in self._fetch_pool + self._threads:
            t.join(timeout=5.0)

    # -- thread management --------------------------------------------------

    def _ensure_threads_locked(self) -> None:
        while len(self._fetch_pool) < self.fetch_threads:
            t = threading.Thread(
                target=self._fetch_loop, daemon=True,
                name=f"scan-service-fetch-{len(self._fetch_pool)}")
            self._fetch_pool.append(t)
            t.start()
        self._spawn_to_target_locked()

    def _spawn_to_target_locked(self) -> None:
        while self._n_workers - self._shrink < self._target:
            if self._shrink > 0:     # un-retire instead of spawning
                self._shrink -= 1
                continue
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 args=(len(self._threads),),
                                 name=f"scan-service-{len(self._threads)}")
            self._n_workers += 1
            self._threads.append(t)
            t.start()

    def _retarget_locked(self) -> None:
        """Recompute the effective pool target: the adaptive policy value
        (capped at max_workers), floored by any active scan's explicit
        workers hint, never below one."""
        hints = [s.workers_hint for s in self._scans if s.workers_hint]
        self._target = max(min(self._policy, self.max_workers),
                           *(hints or [1]), 1)
        if self._target > self._n_workers - self._shrink:
            self._spawn_to_target_locked()
        elif self._target < self._n_workers - self._shrink:
            self._shrink = self._n_workers - self._target
            self._work_cv.notify_all()

    def _resize_window_locked(self) -> None:
        w = self._win
        if w["rgs"] < self.resize_every:
            return
        if self.adaptive:
            # observed per-stage wall ratio over the window: how many decode
            # servers the stream can keep busy against its slower of
            # fetch/consume.  decode-bound → grow toward cpu_count-1;
            # fetch/consume-bound → shrink toward 1.
            bound = max(w["io"], w["cons"], 1e-9)
            self._policy = max(1, int(round(w["dec"] / bound)))
            # SLO-aware sizing (DESIGN.md §11): an active tenant whose
            # recent mean scan latency misses its target asks for one
            # extra decode worker on top of the ratio policy
            for t in self._tenants.values():
                if (t.slo_s is not None and t.active > 0 and t.latencies
                        and (sum(t.latencies) / len(t.latencies)
                             > t.slo_s)):
                    self._policy = min(self.max_workers, self._policy + 1)
                    trace.registry().counter_inc("scheduler.slo_boosts")
                    break
        self._win = {"io": 0.0, "dec": 0.0, "cons": 0.0, "rgs": 0}
        self._retarget_locked()
        self.resize_events.append(self._target)
        reg = trace.registry()
        reg.gauge_set("scheduler.pool_target", self._target)
        reg.counter_inc("scheduler.resizes")

    # -- fetch stage --------------------------------------------------------

    def _service_order_locked(self, cursor: int, which: str = "fetch"
                              ) -> list[tuple[_ScanState, int]]:
        """Active scans in service order: ascending priority class, with
        the round-robin rotation (by ``cursor``) applied *within* each
        class.  Each entry carries the scan's rotation offset inside its
        own class — what the cursor must advance by when that scan is
        chosen, so scans skipped in *other* classes never skew a class's
        rotation.  All-default-priority workloads reduce to the flat
        rotated list (offset == list position) the pre-priority scheduler
        iterated.

        A class containing any *tenanted* scan switches to weighted fair
        ordering instead (``_fair_order_locked``); an all-untenanted
        class keeps this legacy rotation bit-for-bit."""
        by_prio: dict[int, list[_ScanState]] = {}
        for s in self._scans:
            by_prio.setdefault(s.priority, []).append(s)
        out: list[tuple[_ScanState, int]] = []
        for prio in sorted(by_prio):
            cls = by_prio[prio]
            if any(s.tenant is not None for s in cls):
                out.extend(self._fair_order_locked(cls, cursor, which))
                continue
            k = cursor % len(cls)
            out.extend((scan, off)
                       for off, scan in enumerate(cls[k:] + cls[:k]))
        return out

    def _fair_order_locked(self, cls: list[_ScanState], cursor: int,
                           which: str) -> list[tuple[_ScanState, int]]:
        """Stride order for one priority class: tenants ascend by their
        virtual time (``fetch_pass`` or ``item_pass`` — fetch grants and
        decode dispatches are charged separately), registration order
        breaking ties; scans rotate round-robin *within* a tenant via
        ``cursor`` exactly like the legacy per-class rotation.
        Untenanted scans charge the shared weight-1 virtual tenant."""
        groups: dict[int, list[_ScanState]] = {}
        tenants: dict[int, Tenant] = {}
        order: list[Tenant] = []
        for s in cls:
            t = s.tenant if s.tenant is not None else self._default_tenant
            if id(t) not in groups:
                groups[id(t)] = []
                tenants[id(t)] = t
                order.append(t)
        # group scans after discovery so per-tenant lists keep submit order
        for s in cls:
            t = s.tenant if s.tenant is not None else self._default_tenant
            groups[id(t)].append(s)
        attr = "fetch_pass" if which == "fetch" else "item_pass"
        order.sort(key=lambda t: (getattr(t, attr), t.seq))
        out: list[tuple[_ScanState, int]] = []
        for t in order:
            tl = groups[id(t)]
            k = cursor % len(tl)
            out.extend((scan, off)
                       for off, scan in enumerate(tl[k:] + tl[:k]))
        return out

    def _next_fetch_locked(self
                           ) -> tuple[_ScanState, int, bool, bool] | None:
        """Next (scan, seq, subscribed, is_retry) to fetch, priority-
        ordered round-robin across scans with fetch credit.  When an
        identical job for that row group is already in flight (cooperative
        scans), the scan subscribes to it instead — no fetch, no decode,
        the credit stays held until the delivered RG is acked like any
        other.  ``refetch`` seqs (transient-failure requeues) are served
        before new fetch-ahead, already hold their credit, and never
        share — a retry exists to pull *fresh* bytes."""
        n = len(self._scans)
        for scan, off in self._service_order_locked(self._fetch_rr,
                                                    "fetch"):
            if scan.dead:
                continue
            if scan.refetch:
                self._fetch_rr = (self._fetch_rr + off + 1) % max(1, n)
                self._charge_fetch_locked(scan)
                return scan, scan.refetch.popleft(), False, True
            if scan.credits <= 0 or scan.next_fetch >= len(scan.plan):
                continue
            self._fetch_rr = (self._fetch_rr + off + 1) % max(1, n)
            self._charge_fetch_locked(scan)
            scan.credits -= 1
            seq = scan.next_fetch
            scan.next_fetch += 1
            if scan.share_key is not None:
                key = (scan.share_key, scan.plan[seq])
                job = self._inflight.get(key)
                if job is not None:
                    job.subscribers.append((scan, seq))
                    scan.shared_rgs += 1
                    self.shared_rgs += 1
                    return scan, seq, True, False
                if self._window_deliver_locked(scan, seq, key):
                    return scan, seq, True, False
            return scan, seq, False, False
        return None

    def _charge_fetch_locked(self, scan: _ScanState) -> None:
        """Stride accounting: one fetch grant advances the owning
        tenant's fetch-side virtual time by ``1/weight``."""
        ten = scan.tenant if scan.tenant is not None \
            else self._default_tenant
        ten.fetch_pass += 1.0 / ten.weight

    def _window_deliver_locked(self, scan: _ScanState, seq: int,
                               key: tuple) -> bool:
        """Serve one row group from the delivered-result window: the
        retained decoded columns go straight to the scan's in-order done
        queue — no fetch, no decode, the held credit releases on ack
        like any delivery."""
        if self.window_bytes <= 0:
            return False
        hit = self._window.get(key)
        if hit is None:
            return False
        self._window.move_to_end(key)
        cols, io_dt, dec_dt, chunk_times, p2_start, _nb = hit
        scan.done[seq] = (scan.plan[seq], cols, io_dt, dec_dt,
                          list(chunk_times), p2_start)
        scan.shared_rgs += 1
        self.shared_rgs += 1
        self.window_hits += 1
        trace.registry().counter_inc("scheduler.window_hits")
        tr = trace.active()
        if tr is not None:
            tr.instant("window_hit", "io", scan=scan.label,
                       rg=scan.plan[seq],
                       **({"tenant": scan.tenant.name}
                          if scan.tenant is not None else {}))
        scan.done_cv.notify_all()
        return True

    def _window_store_locked(self, key: tuple, cols, io_dt: float,
                             dec_dt: float, chunk_times: list[float],
                             p2_start: int) -> None:
        """Retain one delivered shareable row group, evicting LRU
        entries past the byte cap (decoded payload bytes)."""
        nb = 0
        try:
            for c in cols.values():
                arr = getattr(c, "array", None)
                nb += int(getattr(arr, "nbytes", 0) or 0)
        except AttributeError:
            pass
        nb = max(1, nb)
        if nb > self.window_bytes:
            return                      # larger than the whole window
        old = self._window.pop(key, None)
        if old is not None:
            self._window_nbytes -= old[5]
        self._window[key] = (cols, io_dt, dec_dt, list(chunk_times),
                             p2_start, nb)
        self._window_nbytes += nb
        while self._window_nbytes > self.window_bytes and self._window:
            _, evicted = self._window.popitem(last=False)
            self._window_nbytes -= evicted[5]
            trace.registry().counter_inc("scheduler.window_evictions")

    def _fetch_loop(self) -> None:
        while True:
            with self._lock:
                if self._shutdown:
                    return
                got = self._next_fetch_locked()
                if got is None:
                    self._fetch_cv.wait(timeout=0.1)
                    continue
            scan, seq, subscribed, is_retry = got
            if subscribed:
                continue
            if scan.past_deadline():
                self._deadline_fail(scan)
                continue
            t0 = time.perf_counter()
            try:
                raws, io_dt = scan.scanner.fetch_rg(scan.plan[seq])
            except BaseException as e:
                self._handle_failure(e, [(scan, seq)], None)
                continue
            t1 = time.perf_counter()
            tr = trace.active()
            if tr is not None:
                tr.complete("fetch", "io", t0, t1, scan=scan.label,
                            rg=scan.plan[seq], io_dt=io_dt, retry=is_retry,
                            **({"tenant": scan.tenant.name}
                               if scan.tenant is not None else {}))
                trace.registry().observe("scheduler.fetch_wall_s", t1 - t0)
            with self._lock:
                scan.fetch_span[0] = min(scan.fetch_span[0], t0)
                scan.fetch_span[1] = max(scan.fetch_span[1], t1)
                # the adaptive window compares *host* stage walls, so it
                # accumulates the measured fetch time here — io_dt may be
                # simulated (sim backend) and would dwarf the real cost
                self._win["io"] += t1 - t0
                if scan.dead:
                    continue
                # retried row groups never re-register for sharing: their
                # purpose is fresh bytes decoded from scratch
                key = (None if scan.share_key is None or is_retry
                       else (scan.share_key, scan.plan[seq]))
                rgjob = _RgJob(scan, seq, scan.plan[seq], raws, io_dt, key)
                rgjob.enq_t = t1
                if key is not None and key not in self._inflight:
                    # two fetch-pool threads may race the same key for
                    # different scans; first registration wins (the loser
                    # just decodes its own copy — duplicated work, never
                    # wrong results)
                    self._inflight[key] = rgjob
                scan.ready.append(("open", rgjob, None))
                self._work_cv.notify()

    # -- decode stage -------------------------------------------------------

    def _next_item_locked(self, prefer: _ScanState | None
                          ) -> tuple[_ScanState, tuple] | None:
        """Next work item, priority-ordered fair round-robin across scans
        at *row-group* granularity: a worker that just ran an item of
        ``prefer`` keeps
        draining that scan (its in-flight RG finishes and delivers before
        the pool switches away — decode locality, and consumers
        desynchronize instead of bursting), and the round-robin cursor
        advances only at job boundaries."""
        if (prefer is not None and not prefer.dead and prefer.ready
                and prefer in self._scans):
            item = prefer.ready.popleft()
            self._charge_dispatch_locked(prefer, item)
            return prefer, item
        n = len(self._scans)
        for scan, off in self._service_order_locked(self._rr, "item"):
            while scan.ready:
                item = scan.ready.popleft()
                if item[1].live_scan() is None or item[1].failed:
                    continue   # no subscriber left / job failed — drop it
                self._rr = (self._rr + off + 1) % max(1, n)
                self._charge_dispatch_locked(scan, item)
                return scan, item
        return None

    def _charge_dispatch_locked(self, scan: _ScanState,
                                item: tuple) -> None:
        """Stride accounting at row-group granularity: winning a decode
        slot for an "open" item (a fresh row group entering the pool)
        advances the owning tenant's item-side virtual time by
        ``1/weight`` and counts one dispatch — the share the fairness
        tests measure.  Continuation items of an already-open row group
        are never re-charged."""
        if item[0] != "open":
            return
        ten = scan.tenant if scan.tenant is not None \
            else self._default_tenant
        ten.item_pass += 1.0 / ten.weight
        ten.dispatches += 1

    def _worker_loop(self, worker_idx: int = 0) -> None:
        _apply_affinity(worker_idx)
        if self.device is not None:
            import jax
            with jax.default_device(self.device):
                self._worker_loop_inner()
        else:
            self._worker_loop_inner()

    def _worker_loop_inner(self) -> None:
        prefer: _ScanState | None = None
        while True:
            with self._lock:
                got = None
                while got is None:
                    if self._shutdown:
                        return
                    if self._shrink > 0:
                        self._shrink -= 1
                        self._n_workers -= 1
                        return
                    got = self._next_item_locked(prefer)
                    if got is None:
                        prefer = None
                        self._work_cv.wait(timeout=0.2)
            scan, item = got
            try:
                delivered = self._run_item(scan, item)
                prefer = None if delivered else scan
            except BaseException as e:  # noqa: BLE001 — isolated per scan
                prefer = None
                # a failing item affects exactly the scans sharing its job
                # (usually one); the pool and every other scan live on.
                # Transient failures requeue the row group for a fresh
                # fetch within each subscriber's retry budget; the rest
                # fail their scan.
                self._handle_failure(e, list(item[1].subscribers), item[1])

    def _run_item(self, scan: _ScanState, item: tuple) -> bool:
        """Execute one work item; returns True when it completed (and
        delivered) its whole row-group job."""
        kind, rgjob, fn = item
        if rgjob.failed:
            return False
        live = rgjob.live_scan()
        if live is not None and live.past_deadline():
            raise DeadlineExceeded(
                f"scan {live.label}: deadline exceeded")
        t0 = time.perf_counter()
        tr = trace.active()
        if tr is not None and rgjob.enq_t:
            trace.registry().observe("scheduler.queue_wait_s",
                                     max(0.0, t0 - rgjob.enq_t))
        if kind == "open":
            rgjob.job = self._job_for(scan.scanner, rgjob.rg_index,
                                      rgjob.raws)
            tasks = list(rgjob.job.phase1_tasks())
            rgjob.phase = 1
            self._note_item(scan, rgjob, t0, "open")
            return self._enqueue_phase(scan, rgjob, tasks)
        if kind == "task":
            fn()
            self._note_item(scan, rgjob, t0,
                            {1: "decompress", 2: "decode"}.get(rgjob.phase,
                                                               "fused"))
            with self._lock:
                if rgjob.failed:
                    return False   # a sibling item failed concurrently
                rgjob.pending -= 1
                if rgjob.pending > 0:
                    return False
            return self._advance(scan, rgjob)
        raise AssertionError(kind)

    def _enqueue_phase(self, scan: _ScanState, rgjob: _RgJob,
                       tasks: list[Callable[[], None]]) -> bool:
        """Queue one phase's items, or fall through to the next phase /
        finalize when the phase is empty.  Continuation items go to the
        *front* of the scan's queue, ahead of later row groups' "open"
        items — an in-flight RG always finishes before the next one
        starts, so in-order delivery is never starved by fetch-ahead."""
        if not tasks:
            return self._advance(scan, rgjob)
        with self._lock:
            rgjob.pending = len(tasks)
            rgjob.enq_t = time.perf_counter()
            target = rgjob.live_scan()   # a subscriber may have died
            if target is None:
                return False
            for fn in reversed(tasks):
                target.ready.appendleft(("task", rgjob, fn))
            self._work_cv.notify_all()
        return False

    def _advance(self, scan: _ScanState, rgjob: _RgJob) -> bool:
        """Phase transition on the worker that drained the previous phase:
        1 → build+queue phase-2 items; 2 → queue fused phase-3 items when
        the job has any (late materialization); else finalize (join) and
        deliver."""
        if rgjob.failed:
            return False
        if rgjob.phase == 1:
            t0 = time.perf_counter()
            tasks = list(rgjob.job.phase2_tasks())
            rgjob.phase = 2
            self._note_item(scan, rgjob, t0, "transition")
            rgjob.p2_start = len(rgjob.chunk_times)
            return self._enqueue_phase(scan, rgjob, tasks)
        if rgjob.phase == 2:
            getter = getattr(rgjob.job, "phase3_tasks", None)
            tasks = list(getter()) if getter is not None else []
            rgjob.phase = 3
            if tasks:
                # the fused stage needs every phase-2 column decoded; the
                # modeled schedule treats the whole decode as one serial
                # span for such jobs (p2_start = 0 — conservative)
                t0 = time.perf_counter()
                self._note_item(scan, rgjob, t0, "transition")
                rgjob.p2_start = 0
                return self._enqueue_phase(scan, rgjob, tasks)
            # empty: fall straight through to finalize with NO extra
            # chunk-time item, so unfused accounting is untouched
        t0 = time.perf_counter()
        cols = rgjob.job.finalize()
        self._note_item(scan, rgjob, t0, "finalize")
        dec_dt = sum(rgjob.chunk_times)
        with self._lock:
            # decode side of the adaptive window accrues ONCE per job here
            # — a cooperative job has many subscribers but ran one decode
            self._win["dec"] += dec_dt
            if (rgjob.key is not None
                    and self._inflight.get(rgjob.key) is rgjob):
                self._inflight.pop(rgjob.key)
                if self.window_bytes > 0:
                    # delivered-result window: retain the decoded columns
                    # under the same share identity, so an identical scan
                    # arriving after this one finishes still reuses them
                    self._window_store_locked(rgjob.key, cols, rgjob.io_dt,
                                              dec_dt,
                                              list(rgjob.chunk_times),
                                              rgjob.p2_start)
            for sub, seq in rgjob.subscribers:
                if sub.dead:
                    continue
                sub.done[seq] = (rgjob.rg_index, cols, rgjob.io_dt,
                                 dec_dt, list(rgjob.chunk_times),
                                 rgjob.p2_start)
                sub.done_cv.notify_all()
        return True

    def _note_item(self, scan: _ScanState, rgjob: _RgJob,
                   t0: float, kind: str = "item") -> None:
        t1 = time.perf_counter()
        tr = trace.active()
        if tr is not None:
            tr.complete(kind, "decode", t0, t1, scan=scan.label,
                        rg=rgjob.rg_index,
                        **({"tenant": scan.tenant.name}
                           if scan.tenant is not None else {}))
        with self._lock:
            rgjob.chunk_times.append(t1 - t0)
            for sub, _ in rgjob.subscribers:
                sub.decode_span[0] = min(sub.decode_span[0], t0)
                sub.decode_span[1] = max(sub.decode_span[1], t1)

    @staticmethod
    def _job_for(scanner, rg_index: int, raws):
        mk = getattr(scanner, "decode_job", None)
        if mk is not None:
            return mk(rg_index, raws)
        return OpaqueDecodeJob(scanner, rg_index, raws)

    # -- completion / failure ----------------------------------------------

    def _ack_locked(self, scan: _ScanState, item: tuple | None,
                    consume_dt: float) -> None:
        scan.credits += 1
        if trace.active() is not None:
            trace.registry().observe("scheduler.credits_on_ack",
                                     scan.credits)
        scan.workers_seen = max(scan.workers_seen, self.pool_size)
        if item is not None:
            # consume is per-consumer; fetch accrued at fetch time and
            # decode at delivery time (once per job — cooperative jobs
            # have many subscribers but ran one decode), all measured
            # host walls, never simulated io_dt
            self._win["cons"] += consume_dt
            self._win["rgs"] += 1
            self._resize_window_locked()
        self._fetch_cv.notify_all()

    def _migrate_items_locked(self, scan: _ScanState) -> None:
        """Re-home queued items whose jobs other scans still subscribe to
        (cooperative scans) before this scan's queue is torn down."""
        moved = False
        n = len(scan.ready)
        for _ in range(n):
            item = scan.ready.popleft()
            target = item[1].live_scan()
            if target is not None and target is not scan:
                target.ready.append(item)
                moved = True
        if moved:
            self._work_cv.notify_all()

    def _purge_inflight_locked(self) -> None:
        """Drop in-flight shared jobs nobody subscribes to anymore, so a
        future scan cannot join a job whose items were discarded."""
        for key in [k for k, j in self._inflight.items()
                    if j.live_scan() is None]:
            self._inflight.pop(key)

    def _fail_scan(self, scan: _ScanState, exc: BaseException) -> None:
        with self._lock:
            if scan.error is None and not scan.finished:
                scan.error = exc
            self._migrate_items_locked(scan)
            scan.ready.clear()
            self._purge_inflight_locked()
            scan.done_cv.notify_all()
            self._fetch_cv.notify_all()

    def _deadline_fail(self, scan: _ScanState) -> None:
        with self._lock:
            self._deadline_fail_locked(scan)

    def _deadline_fail_locked(self, scan: _ScanState) -> None:
        """Expire one scan's whole-scan deadline: counted as a timeout,
        never retried (the deadline IS the budget)."""
        if scan.dead:
            return
        cf = getattr(scan.scanner, "count_fault", None)
        if cf is not None:
            cf(timeouts=1)
        tr = trace.active()
        if tr is not None:
            tr.instant("deadline", "fault", scan=scan.label)
        self._fail_scan(scan, DeadlineExceeded(
            f"scan {scan.label}: deadline exceeded"))

    def _handle_failure(self, exc: BaseException,
                        subscribers: list[tuple["_ScanState", int]],
                        rgjob: "_RgJob | None") -> None:
        """Route one failed fetch (``rgjob`` None) or decode item to its
        subscriber scans (DESIGN.md §6).  Transient failures *requeue* the
        row group for a fresh fetch + decode within the scan's retry
        budget — evicting anything the failed attempt pushed into the
        shared caches first, so a retry always decodes fresh bytes.
        Everything else permanently fails that scan only: its shared-cache
        entries are evicted (no poisoning), its queued items drop, and the
        pool and every other scan live on."""
        with self._lock:
            if rgjob is not None:
                if rgjob.failed:
                    return   # a concurrent sibling item already routed it
                rgjob.failed = True
                if (rgjob.key is not None
                        and self._inflight.get(rgjob.key) is rgjob):
                    self._inflight.pop(rgjob.key)
            for scan, seq in subscribers:
                if scan.dead:
                    continue
                if scan.past_deadline():
                    self._deadline_fail_locked(scan)
                    continue
                if isinstance(exc, DeadlineExceeded):
                    # this scan's own deadline is fine (checked above): a
                    # cooperative sibling's budget expired and killed the
                    # shared job — not this scan's fault, requeue free
                    retryable = True
                else:
                    retryable = is_retryable(exc)
                    rd = getattr(scan.scanner, "retry_decode", None)
                    if rd is not None:
                        # counts checksum/timeout once and evicts this
                        # RG's shared-cache entries (retry or not)
                        retryable = rd(scan.plan[seq], exc) and retryable
                if retryable and scan.retries_left > 0:
                    scan.retries_left -= 1
                    cf = getattr(scan.scanner, "count_fault", None)
                    if cf is not None:
                        cf(retries=1)
                    # the seq keeps holding its in-flight credit (released
                    # only on ack), so the retry cannot over-subscribe the
                    # scan's depth bound
                    scan.refetch.append(seq)
                    tr = trace.active()
                    if tr is not None:
                        tr.instant("requeue", "fault", scan=scan.label,
                                   rg=scan.plan[seq],
                                   error=type(exc).__name__)
                    trace.registry().counter_inc("scheduler.requeues")
                    continue
                # permanent: drop every shared-cache entry this scan's
                # planner may have populated, then fail it in isolation
                planner = getattr(scan.scanner, "planner", None)
                if planner is not None:
                    try:
                        planner.evict_file()
                    except Exception:
                        pass
                self._fail_scan(scan, exc)
            self._fetch_cv.notify_all()

    def _finish_scan_locked(self, scan: _ScanState) -> None:
        if scan.finished:
            return
        scan.finished = True
        ten = scan.tenant
        if ten is not None:
            # release the admission slot and record the scan's wall for
            # the SLO-aware sizer; queued submitters wake here
            ten.active = max(0, ten.active - 1)
            ten.latencies.append(time.monotonic() - scan.t_submit)
            trace.registry().gauge_set(
                f"scheduler.tenant_depth.{ten.name}", ten.active)
            self._admit_cv.notify_all()
        self._migrate_items_locked(scan)
        scan.ready.clear()
        scan.done.clear()
        self._purge_inflight_locked()
        if scan in self._scans:
            self._scans.remove(scan)
        self._rr = 0 if not self._scans else self._rr % len(self._scans)
        self._fetch_rr = 0 if not self._scans else \
            self._fetch_rr % len(self._scans)
        self._retarget_locked()
        scan.done_cv.notify_all()
        self._fetch_cv.notify_all()


# ---------------------------------------------------------------------------
# process-wide singleton
# ---------------------------------------------------------------------------

#: every live ScanService, for process-wide cache clears (cold ladders)
_ALL_SERVICES: "weakref.WeakSet[ScanService]" = weakref.WeakSet()

_SERVICE: ScanService | None = None
_SERVICE_LOCK = threading.Lock()


def clear_delivered_windows() -> None:
    """Clear the delivered-result window of every live ScanService —
    the cold-scan ladders' guarantee that each round refetches and
    redecodes for real (tests/test_system.py, bench_encoding,
    bench_compression, tools/chaos_check.py)."""
    for svc in list(_ALL_SERVICES):
        try:
            svc.clear_delivered_window()
        except Exception:
            pass


def scan_service() -> ScanService:
    """The process-wide ScanService every run_overlapped/q6/q12 call
    shares (created on first use)."""
    global _SERVICE
    with _SERVICE_LOCK:
        if _SERVICE is None:
            _SERVICE = ScanService()
        return _SERVICE


def shutdown_scan_service() -> None:
    """Tear down the singleton (tests, atexit); idempotent — the next
    scan_service() call builds a fresh one."""
    global _SERVICE
    with _SERVICE_LOCK:
        if _SERVICE is not None:
            _SERVICE.shutdown()
            _SERVICE = None


@atexit.register
def _shutdown_at_exit() -> None:
    # Interpreter-shutdown net: tear the singleton down while its threads
    # and condition variables are still joinable, so abandoned ScanHandles
    # collected during final GC find a finished service instead of racing
    # a half-torn-down interpreter (their cancel() additionally guards on
    # sys.is_finalizing for handles that outlive even this hook).
    try:
        shutdown_scan_service()
    except Exception:
        pass
